"""Declarative resource registry with watch semantics (API-server analogue).

Kubernetes is "a declarative system — you supply the representation of the
desired state ... and the system determines the sequence of commands to
transition to this desired state" (paper §2.2).  The registry stores BridgeJob
CRs, versions every mutation, and delivers (event, object) pairs to watchers —
the substrate the operator's reconcile loop runs on.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from repro.core.resource import BridgeJob, BridgeJobSpec, ValidationError

Event = Tuple[str, BridgeJob]  # ("ADDED"|"MODIFIED"|"DELETED", job)


class ResourceRegistry:
    def __init__(self) -> None:
        self._objects: Dict[str, BridgeJob] = {}
        self._lock = threading.RLock()
        self._watchers: List["queue.Queue[Event]"] = []
        self._version = 0

    # -- CRUD (kubectl analogue) -------------------------------------------

    def create(self, job: BridgeJob) -> BridgeJob:
        job.spec.validate()
        with self._lock:
            if job.uid in self._objects:
                raise ValidationError(f"{job.uid} already exists")
            self._version += 1
            job.resource_version = self._version
            self._objects[job.uid] = job
            self._notify("ADDED", job)
        return job

    def get(self, name: str, namespace: str = "default") -> Optional[BridgeJob]:
        with self._lock:
            return self._objects.get(f"{namespace}/{name}")

    def list(self, namespace: Optional[str] = None) -> List[BridgeJob]:
        with self._lock:
            return [j for j in self._objects.values()
                    if namespace is None or j.namespace == namespace]

    def update_spec(self, name: str, mutate: Callable[[BridgeJobSpec], BridgeJobSpec],
                    namespace: str = "default") -> BridgeJob:
        """Replace the spec (e.g. set kill=True, resize an array) and notify
        watchers.  A genuine spec change bumps ``metadata.generation`` so the
        reconciler can report convergence via ``status.observedGeneration``;
        a no-op mutation bumps only the resource version."""
        with self._lock:
            job = self._require(name, namespace)
            new_spec = mutate(job.spec)
            new_spec.validate()
            if new_spec != job.spec:
                job.generation += 1
            job.spec = new_spec
            self._version += 1
            job.resource_version = self._version
            self._notify("MODIFIED", job)
            return job

    def update_status(self, name: str, namespace: str = "default",
                      **fields) -> BridgeJob:
        with self._lock:
            job = self._require(name, namespace)
            for k, v in fields.items():
                if not hasattr(job.status, k):
                    raise AttributeError(f"BridgeJobStatus has no field {k!r}")
                setattr(job.status, k, v)
            self._version += 1
            job.resource_version = self._version
            self._notify("MODIFIED", job)
            return job

    def delete(self, name: str, namespace: str = "default") -> None:
        """Mark deleted; the operator finalizes (GCs pod/configmap) then purges."""
        with self._lock:
            job = self._require(name, namespace)
            job.deleted = True
            self._version += 1
            job.resource_version = self._version
            self._notify("DELETED", job)

    def purge(self, name: str, namespace: str = "default") -> None:
        with self._lock:
            self._objects.pop(f"{namespace}/{name}", None)

    # -- watch ---------------------------------------------------------------

    def watch(self, include_existing: bool = True) -> "queue.Queue[Event]":
        q: "queue.Queue[Event]" = queue.Queue()
        with self._lock:
            if include_existing:
                for job in self._objects.values():
                    q.put(("ADDED", job))
            self._watchers.append(q)
        return q

    def unwatch(self, q: "queue.Queue[Event]") -> None:
        with self._lock:
            if q in self._watchers:
                self._watchers.remove(q)

    # -- internals -------------------------------------------------------------

    def _require(self, name: str, namespace: str) -> BridgeJob:
        job = self._objects.get(f"{namespace}/{name}")
        if job is None:
            raise KeyError(f"BridgeJob {namespace}/{name} not found")
        return job

    def _notify(self, event: str, job: BridgeJob) -> None:
        for q in self._watchers:
            q.put((event, job))
