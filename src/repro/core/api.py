"""``Bridge`` — the one client facade over the whole control plane.

Before this module existed, every consumer (tests, examples, the scheduler,
the pipeline engine) hand-assembled registry + statestore + secrets +
objectstore + directory.  ``Bridge`` wires them once and exposes the verbs a
client actually needs:

    bridge = Bridge.from_env(env)            # or Bridge(registry=..., ...)
    handle = bridge.submit("train", spec)    # spec, v1alpha1 dict, or v1beta1 dict
    for status in handle.watch():            # status stream until terminal
        ...
    job = handle.wait(timeout=60)
    handle.cancel()
    files = handle.outputs()                 # S3-uploaded outputs, by name

The facade is deliberately operator-free: it only talks to the declarative
stores (create/patch CRs, read status, fetch objects), exactly like kubectl.
Whatever reconciler is running — the in-process ``BridgeOperator`` or a
future distributed one — clients are unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import (Callable, Dict, FrozenSet, Iterator, Mapping, Optional,
                    Type)

from repro.core.backends import base as B
from repro.core.objectstore import ObjectStore
from repro.core.registry import ResourceRegistry
from repro.core.resource import (ArraySpec, BridgeJob, BridgeJobSpec,
                                 BridgeJobStatus, ValidationError,
                                 spec_from_dict)
from repro.core.rest import ResourceManagerDirectory
from repro.core.secrets import SecretStore
from repro.core.statestore import StateStore, is_results_key


@dataclass(frozen=True)
class JobHandle:
    """A client-side reference to one BridgeJob CR (array or single)."""
    bridge: "Bridge"
    name: str
    namespace: str = "default"

    def job(self) -> Optional[BridgeJob]:
        return self.bridge.registry.get(self.name, self.namespace)

    def status(self) -> BridgeJobStatus:
        job = self.job()
        if job is None:
            raise KeyError(f"BridgeJob {self.namespace}/{self.name} not found")
        return job.status

    def wait(self, timeout: float = 30.0) -> BridgeJob:
        return self.bridge.wait(self.name, self.namespace, timeout=timeout)

    def watch(self, timeout: float = 30.0,
              poll: float = 0.01) -> Iterator[BridgeJobStatus]:
        return self.bridge.watch(self.name, self.namespace,
                                 timeout=timeout, poll=poll)

    def cancel(self) -> None:
        self.bridge.cancel(self.name, self.namespace)

    def patch(self, mutate: Callable[[BridgeJobSpec], BridgeJobSpec]) -> "JobHandle":
        """Patch the live CR's mutable spec fields (see ``Bridge.patch``)."""
        return self.bridge.patch(self.name, mutate, self.namespace)

    def scale(self, count: int) -> "JobHandle":
        """Resize a running job array to ``count`` indices (elastic arrays);
        the operator submits/cancels exactly the delta."""
        return self.bridge.scale(self.name, count, self.namespace)

    def wait_reconciled(self, timeout: float = 30.0) -> BridgeJob:
        """Block until ``status.observedGeneration`` catches up with
        ``metadata.generation`` (the last patch is fully applied) or the job
        turns terminal."""
        return self.bridge.wait_reconciled(self.name, self.namespace,
                                           timeout=timeout)

    def placements(self) -> list:
        """Sharded placement: the job's per-slice status — one dict per
        slice ({slice, resourceURL, image, indices, state}).  Empty for
        single-resource (unsliced) jobs.

        Degradation and failover observability (slice failover, see
        ``spec.placement.failover``): a slice mid-outage additionally
        carries {failures, lastError, outageSeconds}; a slice whose
        resource failed the failover policy is reported with
        ``state: "LOST"`` plus ``migratedTo`` (the endpoints its
        unfinished indices evacuated to) and keeps listing only the
        terminal indices whose results it still holds."""
        return [dict(p) for p in self.status().placements]

    def outputs(self) -> Dict[str, bytes]:
        return self.bridge.outputs(self.name, self.namespace)

    def delete(self) -> None:
        self.bridge.delete(self.name, self.namespace)


class Bridge:
    """One object that wires the control-plane stores together, once."""

    def __init__(self, registry: ResourceRegistry, statestore: StateStore,
                 secrets: SecretStore, objectstore: ObjectStore,
                 directory: ResourceManagerDirectory,
                 adapters: Optional[Mapping[str, Type[B.ResourceAdapter]]] = None):
        if adapters is None:
            from repro.core.operator import default_adapters
            adapters = default_adapters()
        self.registry = registry
        self.statestore = statestore
        self.secrets = secrets
        self.s3 = objectstore
        self.directory = directory
        self.adapters: Dict[str, Type[B.ResourceAdapter]] = dict(adapters)

    @classmethod
    def from_env(cls, env) -> "Bridge":
        """Wrap an already-wired ``BridgeEnvironment``."""
        return cls(env.registry, env.statestore, env.secrets, env.s3,
                   env.directory, env.adapters)

    # -- the client verbs --------------------------------------------------

    def submit(self, name: str, spec, namespace: str = "default") -> JobHandle:
        """Create a BridgeJob CR.  ``spec`` may be a ``BridgeJobSpec`` or a
        spec dict in either API version (the conversion layer normalizes)."""
        if isinstance(spec, dict):
            if "spec" in spec or "apiVersion" in spec:  # a full CR document
                doc = dict(spec)
                doc.setdefault("metadata", {"name": name,
                                            "namespace": namespace})
                job = BridgeJob.from_dict(doc)
                job.name, job.namespace = name, namespace
                spec = job.spec
            else:
                spec = spec_from_dict(spec)
        self.registry.create(BridgeJob(name=name, spec=spec,
                                       namespace=namespace))
        return JobHandle(self, name, namespace)

    def handle(self, name: str, namespace: str = "default") -> JobHandle:
        return JobHandle(self, name, namespace)

    # -- BridgeService (long-running serving workloads) --------------------

    def submit_service(self, name: str, spec,
                       namespace: str = "default"):
        """Create a BridgeService CR.  ``spec`` may be a
        ``BridgeServiceSpec`` or a v1beta1 spec dict; returns a
        ``ServiceHandle`` (scale / wait_ready / autoscale_status / router).
        With ``spec.autoscale`` set, the replica count is load-driven: the
        handle's routers publish load reports and the control plane scales
        within ``[minReplicas, maxReplicas]`` — a manual ``scale()`` then
        just resets the baseline the autoscaler moves from."""
        from repro.core.resource import (BridgeService, BridgeServiceSpec,
                                         service_spec_from_dict)
        from repro.core.router import ServiceHandle
        if isinstance(spec, dict):
            spec = service_spec_from_dict(spec)
        if not isinstance(spec, BridgeServiceSpec):
            raise ValidationError(
                f"submit_service wants a BridgeServiceSpec, got "
                f"{type(spec).__name__}")
        self.registry.create(BridgeService(name=name, spec=spec,
                                           namespace=namespace))
        return ServiceHandle(self, name, namespace)

    def service(self, name: str, namespace: str = "default"):
        """A ``ServiceHandle`` over an existing BridgeService CR."""
        from repro.core.router import ServiceHandle
        return ServiceHandle(self, name, namespace)

    def wait(self, name: str, namespace: str = "default",
             timeout: float = 30.0) -> BridgeJob:
        """Block until the job reaches a terminal state."""
        deadline = time.time() + timeout
        job = None
        while time.time() < deadline:
            job = self.registry.get(name, namespace)
            if job is not None and job.status.terminal():
                return job
            time.sleep(0.01)
        raise TimeoutError(
            f"BridgeJob {namespace}/{name} not terminal after {timeout}s "
            f"(state={job.status.state if job else '?'})")

    def watch(self, name: str, namespace: str = "default",
              timeout: float = 30.0,
              poll: float = 0.01) -> Iterator[BridgeJobStatus]:
        """Yield a status snapshot on every observed change, ending with the
        terminal one (kubectl get -w analogue)."""
        deadline = time.time() + timeout
        last: Optional[tuple] = None
        while time.time() < deadline:
            job = self.registry.get(name, namespace)
            if job is not None:
                key = (job.status.state, job.status.message,
                       job.status.job_id, tuple(sorted(
                           job.status.index_states.items())))
                if key != last:
                    last = key
                    yield job.status
                if job.status.terminal():
                    return
            time.sleep(poll)
        raise TimeoutError(f"watch on {namespace}/{name} timed out")

    def cancel(self, name: str, namespace: str = "default") -> None:
        """User-facing kill signal: update the CR (paper §5.1)."""
        self.registry.update_spec(
            name, lambda s: dataclasses.replace(s, kill=True), namespace)

    # -- elastic arrays: spec patches on a live CR -------------------------

    def patch(self, name: str,
              mutate: Callable[[BridgeJobSpec], BridgeJobSpec],
              namespace: str = "default") -> JobHandle:
        """Patch MUTABLE spec fields of a live CR (kubectl patch analogue).

        Only ``spec.array`` (count + indexed_params) and ``spec.kill`` are
        mutable after creation; changing anything else — or patching a
        terminal CR — raises ``ValidationError``.  Every accepted patch bumps
        ``metadata.generation``; the reconciler reports convergence through
        ``status.observedGeneration`` (await it via ``wait_reconciled``).
        """
        if self.registry.get(name, namespace) is None:
            raise KeyError(f"BridgeJob {namespace}/{name} not found")

        def guarded(spec: BridgeJobSpec) -> BridgeJobSpec:
            # runs under the registry lock (update_spec holds it; the re-get
            # re-enters the RLock), so a patch racing the job's terminal
            # transition is rejected atomically, not silently accepted
            cur = self.registry.get(name, namespace)
            if cur is not None and cur.status.terminal():
                raise ValidationError(
                    f"cannot patch terminal BridgeJob {namespace}/{name} "
                    f"({cur.status.state})")
            new = mutate(spec)
            if dataclasses.replace(new, array=spec.array,
                                   kill=spec.kill) != spec:
                raise ValidationError(
                    "only spec.array and spec.kill are mutable on a live "
                    "BridgeJob")
            return new

        self.registry.update_spec(name, guarded, namespace)
        return self.handle(name, namespace)

    def scale(self, name: str, count: int,
              namespace: str = "default") -> JobHandle:
        """Resize a live array to ``count`` indices.  ``indexed_params`` (if
        used) is truncated / padded with empty overlays to match; the
        operator then submits or cancels exactly the delta — scale-down
        cancels the highest indices first."""
        if count < 1:
            raise ValidationError("array count must be >= 1")

        def mutate(s: BridgeJobSpec) -> BridgeJobSpec:
            arr = s.array or ArraySpec()
            params = list(arr.indexed_params)
            if params:
                params = (params + [{} for _ in
                                    range(count - len(params))])[:count]
            return dataclasses.replace(
                s, array=ArraySpec(count=count, indexed_params=params))

        return self.patch(name, mutate, namespace)

    def wait_reconciled(self, name: str, namespace: str = "default",
                        timeout: float = 30.0) -> BridgeJob:
        """Block until ``status.observedGeneration >= metadata.generation``
        (the last spec patch is fully applied) or the job turns terminal."""
        deadline = time.time() + timeout
        while True:  # always check at least once, even with timeout <= 0
            job = self.registry.get(name, namespace)
            if job is None:
                # absent (or deleted mid-wait): it can never reconcile —
                # fail fast like patch/scale instead of burning the timeout
                raise KeyError(f"BridgeJob {namespace}/{name} not found")
            if (job.status.observed_generation >= job.generation
                    or job.status.terminal()):
                return job
            if time.time() >= deadline:
                break
            time.sleep(0.01)
        raise TimeoutError(
            f"BridgeJob {namespace}/{name} not reconciled after {timeout}s "
            f"(generation={job.generation}, observed="
            f"{job.status.observed_generation})")

    def delete(self, name: str, namespace: str = "default") -> None:
        self.registry.delete(name, namespace)

    def outputs(self, name: str, namespace: str = "default") -> Dict[str, bytes]:
        """Fetch the job's S3-uploaded outputs, keyed by object key."""
        try:
            cm = self.statestore.get(f"{namespace}/{name}-bridge-cm").data
        except KeyError:
            return {}
        out: Dict[str, bytes] = {}
        refs = [r for r in cm.get("outputs", "").split(",") if r]
        # results keys may be slice-namespaced (sharded placement) or legacy
        for key in [k for k in cm if is_results_key(k)]:
            if cm[key]:
                refs.append(cm[key])
        for ref in refs:
            bucket, key = ObjectStore.parse_ref(ref)
            out[key] = self.s3.get(bucket, key)
        return out

    # -- capability + adapter plumbing (scheduler, tooling) ----------------

    def adapter_type(self, image: str) -> Type[B.ResourceAdapter]:
        return B.resolve_adapter(self.adapters, image)

    def capabilities(self, image: str) -> FrozenSet[B.Capability]:
        """The typed capability set the controller image advertises."""
        return self.adapter_type(image).capabilities

    def connect_adapter(self, resourceURL: str, image: str,
                        resourcesecret: str) -> B.ResourceAdapter:
        """Instantiate the adapter a controller pod for this target would
        use: mount the secret, connect, resolve by image."""
        token = self.secrets.mount(resourcesecret).get("token", "")
        client = self.directory.connect(resourceURL, token)
        return self.adapter_type(image)(client)
