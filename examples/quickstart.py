"""Quickstart: submit a job to a simulated SLURM cluster through the Bridge
client facade, exactly like the paper's Fig. 1 yaml, and watch it complete.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import BridgeEnvironment


def main() -> None:
    with BridgeEnvironment(default_duration=0.3) as env:
        # the Fig. 1 BridgeJob, as a spec
        env.s3.put("mys3bucket", "slurmbatch.sh",
                   b"#!/bin/bash\n#SBATCH -N1\nsrun ./simulate\n")
        spec = env.make_spec(
            "slurm",
            script="mys3bucket:slurmbatch.sh", scriptlocation="s3",
            jobproperties={
                "NodesNumber": "1", "Queue": "V100", "Tasks": "2",
                "slurmJobName": "test",
                "ErrorFileName": "slurmjob.err",
                "OutputFileName": "slurmjob.out",
            },
            updateinterval=0.05,
        )
        handle = env.bridge.submit("slurmjob-test", spec)
        print("BridgeJob created; operator reconciling...")
        for status in handle.watch(timeout=60):
            print(f"  status={status.state:10s} remote_id={status.job_id!r}")
        job = handle.job()
        print(f"final: {job.status.state}, "
              f"ran {job.status.end_time - job.status.start_time:.2f}s "
              f"on the external resource")
        assert job.status.state == "DONE"


if __name__ == "__main__":
    main()
