"""Multi-device correctness checks (run with forced host devices).

Invoked by tests/test_parallel.py as a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main test
process keeps its single-device view.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

assert len(jax.devices()) == 8, jax.devices()


def check_ep_matches_dropping():
    """moe_ep_shard_map == moe_dropping (same capacity semantics)."""
    from repro.configs.base import MoEConfig, get_smoke_config
    from repro.models import moe as MOE
    from repro.models.transformer import model_defs
    from repro.models.params import init_params
    from repro.parallel.ep import ep_mesh, moe_ep_shard_map

    cfg = get_smoke_config(
        "moonshot-v1-16b-a3b",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared_experts=0, capacity_factor=4.0))
    defs = MOE.moe_defs(cfg)
    p = init_params(jax.random.PRNGKey(0), defs)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)

    ref_out, _ = MOE.moe_dropping(p, x, cfg)
    # aux oracle: load-balance stats are computed PER DP SHARD then averaged
    # (GShard group semantics) — not equal to the whole-batch statistic
    ref_aux = np.mean([float(MOE.moe_dropping(p, x[i:i + 2], cfg)[1])
                       for i in (0, 2)])

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with ep_mesh(mesh):
        ep_out, ep_aux = jax.jit(
            lambda p, x: moe_ep_shard_map(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(ref_out), np.asarray(ep_out),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(ref_aux, float(ep_aux), rtol=1e-4)

    # differentiability
    with ep_mesh(mesh):
        g = jax.jit(jax.grad(
            lambda p, x: moe_ep_shard_map(p, x, cfg)[0].sum()))(p, x)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("OK ep_matches_dropping")


def check_pipeline_apply():
    from repro.parallel.pipeline import pipeline_apply, stack_stage_params

    mesh = jax.make_mesh((4,), ("pod",))
    d, L, b = 16, 8, 8
    ws = jax.random.normal(jax.random.PRNGKey(0), (L, d, d), jnp.float32) * 0.2
    x = jax.random.normal(jax.random.PRNGKey(1), (b, d), jnp.float32)

    ref = x
    for i in range(L):
        ref = jnp.tanh(ref @ ws[i])

    def stage_fn(params, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, params["w"])
        return y

    stage_params = {"w": stack_stage_params(ws, 4)}
    out = jax.jit(lambda sp, x: pipeline_apply(stage_fn, sp, x, mesh,
                                               axis="pod", n_micro=4))(
        stage_params, x)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-5, atol=1e-6)
    print("OK pipeline_apply")


def check_compressed_mean():
    from repro.optim.compression import compressed_mean

    mesh = jax.make_mesh((8,), ("dp",))
    xs = jax.random.normal(jax.random.PRNGKey(2), (8, 128), jnp.float32)
    errs = jnp.zeros((8, 128), jnp.float32)

    def f(x, e):
        return compressed_mean(x, e, "dp")

    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    mean, new_err = jax.jit(shard_map(
        f, mesh, in_specs=(P("dp"), P("dp")),
        out_specs=(P("dp"), P("dp")), check_vma=False))(xs, errs)
    exact = jnp.mean(xs, axis=0)
    got = np.asarray(mean)[0]  # every shard holds the same mean
    for i in range(8):
        np.testing.assert_allclose(np.asarray(mean)[i], got)
    amax = float(jnp.max(jnp.abs(xs)))
    tol = 2 * amax / 127  # two quantization stages
    assert np.max(np.abs(got - np.asarray(exact))) < tol
    print("OK compressed_mean")


def check_sharded_train_step():
    """pjit train step on a (2,4) mesh for three families."""
    from repro.compat import jit_sharded, use_mesh
    from repro.configs.base import ShapeConfig, get_smoke_config
    from repro.data import DataConfig, SyntheticDataset, with_frontend_stubs
    from repro.steps import make_train_step
    from repro.models.params import init_params
    from repro.models.transformer import model_defs
    from repro.optim import adamw_init
    from repro.sharding import to_shardings

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape = ShapeConfig("t", 16, 4, "train")
    for arch in ("gemma-2b", "moonshot-v1-16b-a3b", "hymba-1.5b"):
        cfg = get_smoke_config(arch, n_heads=4, n_kv_heads=4)
        bundle = make_train_step(cfg, mesh, shape, zero1=True, remat=True)
        ds = SyntheticDataset(DataConfig(cfg.vocab, shape.seq_len,
                                         shape.global_batch))
        batch = {k: jnp.asarray(v) for k, v in
                 with_frontend_stubs(ds.batch(0), cfg).items()}
        defs = model_defs(cfg, max_seq=shape.seq_len)
        params = init_params(jax.random.PRNGKey(0), defs)
        from repro.optim import adamw_init
        opt = adamw_init(params)
        with use_mesh(mesh):
            jf = jit_sharded(bundle.fn, mesh,
                             in_shardings=bundle.in_shardings,
                             out_shardings=bundle.out_shardings)
            new_p, new_o, metrics = jf(params, opt, batch)
            loss = float(metrics["loss"])
        assert np.isfinite(loss), (arch, loss)
        print(f"OK sharded_train_step {arch} loss={loss:.3f}")


def check_ep_gather_matches_dropping():
    """moe_ep_gather == moe_dropping (same capacity semantics, zero-matmul
    dispatch)."""
    from repro.configs.base import MoEConfig, get_smoke_config
    from repro.models import moe as MOE
    from repro.models.params import init_params
    from repro.parallel.ep import ep_mesh, moe_ep_gather

    cfg = get_smoke_config(
        "moonshot-v1-16b-a3b",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared_experts=0, capacity_factor=4.0))
    defs = MOE.moe_defs(cfg)
    p = init_params(jax.random.PRNGKey(0), defs)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    ref_out, _ = MOE.moe_dropping(p, x, cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with ep_mesh(mesh):
        ep_out, ep_aux = jax.jit(
            lambda p, x: moe_ep_gather(p, x, cfg))(p, x)
        g = jax.jit(jax.grad(
            lambda p, x: moe_ep_gather(p, x, cfg)[0].sum()))(p, x)
    np.testing.assert_allclose(np.asarray(ref_out), np.asarray(ep_out),
                               rtol=2e-4, atol=2e-5)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
    # padded-expert variant: same output, weights padded 8 -> 12
    import dataclasses
    cfg_p = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts_padded=12))
    defs_p = MOE.moe_defs(cfg_p)
    p_pad = init_params(jax.random.PRNGKey(0), defs_p)
    # copy the REAL experts' weights so outputs are comparable
    for kname in ("w1", "w2", "w3"):
        if kname in p:
            p_pad[kname] = p_pad[kname].at[:8].set(p[kname])
    with ep_mesh(mesh):
        pad_out, _ = jax.jit(lambda p, x: moe_ep_gather(p, x, cfg_p))(p_pad, x)
    np.testing.assert_allclose(np.asarray(ref_out), np.asarray(pad_out),
                               rtol=2e-4, atol=2e-5)
    print("OK ep_gather_matches_dropping")




def check_checkpoint_reshard_on_load():
    """Elastic restart: save under mesh (2,4), restore under mesh (4,2) and
    (8,1) — shardings change, values don't (reshard-on-load)."""
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint import CheckpointManager
    from repro.core.objectstore import ObjectStore

    store = ObjectStore()
    mgr = CheckpointManager(store, "ck", "elastic")
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
    w_a = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
    mgr.save(3, {"w": w_a})

    for shape, axes, spec in (((4, 2), ("data", "model"), P("model", "data")),
                              ((8, 1), ("data", "model"), P("data", None))):
        mesh_b = jax.make_mesh(shape, axes)
        sh = {"w": NamedSharding(mesh_b, spec)}
        restored, _ = mgr.restore(3, {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)},
                                  shardings=sh)
        assert restored["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
    print("OK checkpoint_reshard_on_load")

if __name__ == "__main__":
    names = sys.argv[1:] or ["ep", "pipeline", "compressed", "train"]
    if "ep" in names:
        check_ep_matches_dropping()
    if "pipeline" in names:
        check_pipeline_apply()
    if "compressed" in names:
        check_compressed_mean()
    if "ep_gather" in names or not sys.argv[1:]:
        check_ep_gather_matches_dropping()
    if "reshard" in names or not sys.argv[1:]:
        check_checkpoint_reshard_on_load()
    if "train" in names:
        check_sharded_train_step()
    print("ALL PARALLEL CHECKS OK")
