"""Prefill / single-token decode with KV + recurrent-state caches.

Cache layouts (layer-major leading dim so lax.scan can carry them):
  dense/vlm/moe : {"k","v": (L,B,M,Hkv,Dh), "pos": (B,)}
  hybrid        : + {"conv": (L,B,k-1,di), "ssm": (L,B,di,n)}
  encdec        : + {"cross_k","cross_v": (L,B,F,H,Dh)} (fixed after prefill)
  ssm (xlstm)   : {"blocks": [per-layer state dicts], "pos": (B,)}

``window > 0`` uses a circular KV buffer of size ``window`` (sub-quadratic
long-context mode for hybrid archs).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.transformer import xlstm_layer_kinds

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, window: int = 0) -> Dict[str, Any]:
    dt = L.adtype(cfg)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    m = min(window, max_len) if window else max_len
    if cfg.family == "ssm":
        blocks = []
        for kind in xlstm_layer_kinds(cfg):
            blocks.append(
                XL.init_mlstm_state(cfg, batch) if kind == "mlstm"
                else XL.init_slstm_state(cfg, batch)
            )
        return {"blocks": blocks, "pos": jnp.zeros((batch,), jnp.int32)}
    cache: Dict[str, Any] = {
        "k": jnp.zeros((cfg.n_layers, batch, m, hkv, hd), dt),
        "v": jnp.zeros((cfg.n_layers, batch, m, hkv, hd), dt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.family == "hybrid":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        cache["conv"] = jnp.zeros((cfg.n_layers, batch, s.d_conv - 1, di), dt)
        cache["ssm"] = jnp.zeros((cfg.n_layers, batch, di, s.d_state), jnp.float32)
    if cfg.family == "encdec":
        cache["cross_k"] = jnp.zeros((cfg.n_layers, batch, cfg.enc_frames, hkv, hd), dt)
        cache["cross_v"] = jnp.zeros((cfg.n_layers, batch, cfg.enc_frames, hkv, hd), dt)
    return cache


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, window: int = 0):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, window))


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(params: Params, cfg: ModelConfig, batch_inputs: Dict[str, jax.Array],
            max_len: int, window: int = 0) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the full prompt, returning (last-token logits, filled cache)."""
    if cfg.family == "ssm":
        return _prefill_xlstm(params, cfg, batch_inputs)
    if cfg.family == "encdec":
        return _prefill_encdec(params, cfg, batch_inputs, max_len)
    from repro.models.transformer import _apply_block, _embed_inputs

    x, positions, _ = _embed_inputs(params, cfg, batch_inputs)
    b, s, _ = x.shape
    body = functools.partial(_apply_block, positions=positions, cfg=cfg,
                             window=window, want_kv=True)
    if isinstance(params["blocks"], list):  # unrolled stacks
        per_layer = []
        for lp in params["blocks"]:
            x, o = body(lp, x)
            per_layer.append(o)
        ks = jnp.stack([o[0] for o in per_layer])
        vs = jnp.stack([o[1] for o in per_layer])
        outs = (ks, vs,
                tuple(jnp.stack([o[2][i] for o in per_layer])
                      for i in range(len(per_layer[0][2]))),
                jnp.stack([o[3] for o in per_layer]))
    else:
        x, outs = jax.lax.scan(lambda c, lp: body(lp, c), x, params["blocks"])
    ks, vs = outs[0], outs[1]  # (L,B,S,Hkv,Dh)
    cache = init_cache(cfg, b, max_len, window)
    m = cache["k"].shape[2]
    if s >= m:
        cache["k"] = ks[:, :, -m:]
        cache["v"] = vs[:, :, -m:]
    else:
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], ks, 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vs, 0, axis=2)
    if cfg.family == "hybrid":
        conv, ssm_h = outs[2]
        cache["conv"], cache["ssm"] = conv, ssm_h
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)
    return logits, cache


def _prefill_xlstm(params, cfg, batch_inputs):
    tokens = batch_inputs["tokens"]
    x = L.embed_tokens(params["embed"], tokens, cfg)
    b, s, _ = x.shape
    kinds = xlstm_layer_kinds(cfg)
    states = []
    for kind, p in zip(kinds, params["blocks"]):
        if kind == "mlstm":
            out, st = XL.mlstm_forward(p, x, cfg)
            x = x + out
        else:
            x, st = XL.slstm_forward(p, x, cfg)
        states.append(st)
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)
    return logits, {"blocks": states, "pos": jnp.full((b,), s, jnp.int32)}


def _prefill_encdec(params, cfg, batch_inputs, max_len):
    from repro.models.transformer import _forward_train_encdec  # reuse encoder body

    frames = batch_inputs["enc_frames"].astype(L.adtype(cfg))
    enc = frames + params["enc_pos"]["pos"][None, : frames.shape[1]]
    b = enc.shape[0]
    enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1], dtype=jnp.int32), (b, enc.shape[1]))

    def enc_block(p, x):
        xn = L.apply_norm(p["ln_attn"], x, cfg.norm)
        a, _ = L.attn_forward(p["attn"], xn, enc_pos, cfg, causal=False)
        x = x + a
        xn = L.apply_norm(p["ln_mlp"], x, cfg.norm)
        return x + L.apply_mlp(p["mlp"], xn, cfg.activation), ()

    if isinstance(params["enc_blocks"], list):
        for lp in params["enc_blocks"]:
            enc, _ = enc_block(lp, enc)
    else:
        enc, _ = jax.lax.scan(lambda c, lp: enc_block(lp, c), enc,
                              params["enc_blocks"])
    enc = L.apply_norm(params["enc_ln_f"], enc, cfg.norm)

    tokens = batch_inputs["tokens"]
    x = L.embed_tokens(params["embed"], tokens, cfg)
    s = x.shape[1]
    x = x + params["dec_pos"]["pos"][None, :s]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def dec_block(p, x):
        xn = L.apply_norm(p["ln_attn"], x, cfg.norm)
        a, (k, v) = L.attn_forward(p["attn"], xn, positions, cfg)
        x = x + a
        xn = L.apply_norm(p["ln_cross"], x, cfg.norm)
        ck = jnp.einsum("bsd,dhk->bshk", enc, p["cross"]["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", enc, p["cross"]["wv"])
        c, _ = L.attn_forward(p["cross"], xn, positions, cfg, kv_override=(ck, cv))
        x = x + c
        xn = L.apply_norm(p["ln_mlp"], x, cfg.norm)
        return x + L.apply_mlp(p["mlp"], xn, cfg.activation), (k, v, ck, cv)

    if isinstance(params["blocks"], list):
        per_layer = []
        for lp in params["blocks"]:
            x, o = dec_block(lp, x)
            per_layer.append(o)
        ks, vs, cks, cvs = (jnp.stack([o[i] for o in per_layer])
                            for i in range(4))
    else:
        x, outs = jax.lax.scan(lambda c, lp: dec_block(lp, c), x,
                               params["blocks"])
        ks, vs, cks, cvs = outs
    cache = init_cache(cfg, b, max_len)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], ks, 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vs, 0, axis=2)
    cache["cross_k"], cache["cross_v"] = cks, cvs
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)
    return logits, cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_step(params: Params, cfg: ModelConfig, cache: Dict[str, Any],
                tokens: jax.Array, window: int = 0) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token for every sequence.  tokens: (B,1) int32."""
    if cfg.family == "ssm":
        return _decode_xlstm(params, cfg, cache, tokens)
    pos = cache["pos"]  # (B,) absolute position of the new token
    x = L.embed_tokens(params["embed"], tokens, cfg)
    m = cache["k"].shape[2]
    write_pos = pos % m if window else pos

    def body(x, layer):
        p = layer["p"]
        aux = ()
        xn = L.apply_norm(p["ln_attn"], x, cfg.norm)
        attn_out, (ck, cv) = L.attn_decode(
            p["attn"], xn, layer["k"], layer["v"], pos, cfg,
            write_pos=write_pos, cross=False,
        )
        new_layer = {"k": ck, "v": cv}
        if cfg.family == "hybrid":
            ssm_out, st = SSM.ssm_decode(p["ssm"], xn, {"conv": layer["conv"],
                                                        "ssm": layer["ssm"]}, cfg)
            w = jax.nn.relu(p["mix_w"])
            x = x + (w[0] * attn_out.astype(jnp.float32)
                     + w[1] * ssm_out.astype(jnp.float32)).astype(x.dtype)
            new_layer["conv"], new_layer["ssm"] = st["conv"], st["ssm"]
        else:
            x = x + attn_out
        if cfg.family == "encdec":
            xn = L.apply_norm(p["ln_cross"], x, cfg.norm)
            c, _ = L.attn_decode(p["cross"], xn, layer["cross_k"], layer["cross_v"],
                                 pos, cfg, cross=True)
            x = x + c
        xn2 = L.apply_norm(p["ln_mlp"], x, cfg.norm)
        if cfg.family == "moe":
            ffn_out, _ = MOE.apply_moe(p["moe"], xn2, cfg)
        else:
            ffn_out = L.apply_mlp(p["mlp"], xn2, cfg.activation)
        x = x + ffn_out
        return x, new_layer

    if isinstance(params["blocks"], list):  # unrolled stacks
        new_cols: Dict[str, list] = {}
        for li, lp in enumerate(params["blocks"]):
            layer = {"p": lp, "k": cache["k"][li], "v": cache["v"][li]}
            if cfg.family == "hybrid":
                layer["conv"], layer["ssm"] = cache["conv"][li], cache["ssm"][li]
            if cfg.family == "encdec":
                layer["cross_k"] = cache["cross_k"][li]
                layer["cross_v"] = cache["cross_v"][li]
            x, nl = body(x, layer)
            for k_, v_ in nl.items():
                new_cols.setdefault(k_, []).append(v_)
        new_layers = {k_: jnp.stack(v_) for k_, v_ in new_cols.items()}
    else:
        layers_in = {"p": params["blocks"], "k": cache["k"], "v": cache["v"]}
        if cfg.family == "hybrid":
            layers_in["conv"], layers_in["ssm"] = cache["conv"], cache["ssm"]
        if cfg.family == "encdec":
            layers_in["cross_k"], layers_in["cross_v"] = (cache["cross_k"],
                                                          cache["cross_v"])
        x, new_layers = jax.lax.scan(lambda c, lp: body(c, lp), x, layers_in)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = new_layers["k"], new_layers["v"]
    if cfg.family == "hybrid":
        new_cache["conv"], new_cache["ssm"] = new_layers["conv"], new_layers["ssm"]
    new_cache["pos"] = pos + 1
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, new_cache


def _decode_xlstm(params, cfg, cache, tokens):
    x = L.embed_tokens(params["embed"], tokens, cfg)
    kinds = xlstm_layer_kinds(cfg)
    new_states = []
    for kind, p, st in zip(kinds, params["blocks"], cache["blocks"]):
        if kind == "mlstm":
            out, st2 = XL.mlstm_decode(p, x, st, cfg)
            x = x + out
        else:
            x, st2 = XL.slstm_decode(p, x, st, cfg)
        new_states.append(st2)
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"blocks": new_states, "pos": cache["pos"] + 1}
