"""ControllerPod — the paper's "workhorse" (Figs. 2-3).

One pod per remote job.  The pod:
  1. reads execution data from the associated config map,
  2. mounts secrets, connects to the remote resource manager over the
     HTTP/HTTPS API (the ONLY channel to the external system),
  3. fetches the job script (inline / s3 / remote) and stages extra data,
  4. submits IF AND ONLY IF the config map holds no job id — a restarted pod
     finds the id and resumes monitoring instead of resubmitting (paper §5.1),
  5. runs the monitor loop: poll status, mirror it into the config map,
     honour the kill flag, tolerate transient network failures (UNKNOWN
     after ``unknown_after`` consecutive failures — never invent a terminal
     state),
  6. on completion downloads outputs and uploads them to S3, then exits
     0 (COMPLETED) / 1 (FAILED or CANCELLED), exactly like Fig. 3.

Pod death is simulated by ``kill_pod()``: the thread aborts at the next
action boundary WITHOUT flushing anything — only config-map state survives,
which is precisely the failure mode the paper's design addresses.

The protocol itself lives in ``JobProtocol`` so it has two drivers: this
thread-per-CR pod (the paper-faithful shape) and the multiplexed
``MonitorRuntime`` (core/monitor.py), where a small fixed worker pool steps
many jobs' state machines off a poll-deadline heap.  ``JobProtocol.tick()``
is ONE iteration of the Fig.-3 monitor loop; the driver owns the inter-tick
wait.  Two per-tick I/O optimisations live here as well:

  * batched status — adapters declaring ``Capability.BATCH_STATUS`` are
    polled with one ``status_batch()`` request per ``BATCH_STATUS_CHUNK``
    ids instead of one request per index (with per-id fallback otherwise);
  * write-coalescing — the monitor diffs its computed updates against the
    last-written snapshot, so a steady-state RUNNING tick performs zero
    config-map writes (the state store additionally skips flushes for
    value-identical updates).
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Type

from repro.core.backends import base as B
from repro.core.objectstore import NoSuchKey, ObjectStore
from repro.core.resource import (DONE, FAILED, KILLED, RUNNING, SUBMITTED,
                                 UNKNOWN)
from repro.core.rest import ResourceManagerDirectory, TransportError
from repro.core.secrets import SecretStore
from repro.core.statestore import ConfigMap, StateStore

# backend canonical -> bridge state
_CANON_TO_BRIDGE = {
    B.QUEUED: SUBMITTED,
    B.RUNNING: RUNNING,
    B.COMPLETED: DONE,
    B.FAILED: FAILED,
    B.CANCELLED: KILLED,
}


class PodKilled(BaseException):
    """Out-of-band pod termination (node failure / eviction)."""


def killable_sleep(killed: threading.Event, name: str, seconds: float,
                   min_sleep: float = 0.005) -> None:
    """Checkpointed, interruptible wait shared by both protocol drivers
    (ControllerPod thread, MonitorTask worker): raises PodKilled mid-wait so
    kills take effect at ``min_sleep`` granularity."""
    deadline = time.time() + seconds
    while time.time() < deadline:
        if killed.is_set():
            raise PodKilled(name)
        time.sleep(min(min_sleep, max(deadline - time.time(), 0)))


class JobProtocol:
    """The Figs. 2-3 bridge protocol for ONE BridgeJob, structured as
    ``start()`` (connect + submit-if-no-id) plus repeated ``tick()`` calls
    (one monitor iteration each) so any driver can own the pacing.

    ``checkpoint`` is called at every action boundary and must raise
    ``PodKilled`` when the driver wants the protocol to die unflushed;
    ``sleep`` is the (checkpointed, interruptible) wait used for retry
    backoff inside a step.
    """

    # benchmark-baseline switch, PROCESS-WIDE: False restores the
    # pre-optimisation write-every-tick monitor (pair with
    # StateStore(coalesce=False)).  Not production config — flip it only in
    # single-environment measurement code, saving/restoring the prior value.
    COALESCE_WRITES = True

    def __init__(self, name: str, configmap: ConfigMap, secrets: SecretStore,
                 objectstore: ObjectStore, directory: ResourceManagerDirectory,
                 adapters: Mapping[str, Type[B.ResourceAdapter]],
                 checkpoint: Callable[[], None],
                 sleep: Callable[[float], None],
                 min_sleep: float = 0.005):
        self.name = name
        self.cm = configmap
        self.secrets = secrets
        self.s3 = objectstore
        self.directory = directory
        self.adapters = dict(adapters)
        self.min_sleep = min_sleep
        self._checkpoint = checkpoint
        self._sleep = sleep
        self.exit_code: Optional[int] = None
        self.poll: float = 0.0
        # monitor state (populated by start(), survives across ticks)
        self._adapter: Optional[B.ResourceAdapter] = None
        self._ids: List[str] = []
        self._count = 1
        self._unknown_after = 5
        self._retry_limit = 0
        self._backoff = 0.0
        self._attempts: Dict[str, int] = {}
        self._consecutive_failures = 0
        # jids a cancel has been delivered for (kill signal OR scale-down)
        self._cancel_sent: set = set()
        # jids condemned by an elastic scale-down: always a SUFFIX of _ids;
        # they stay tracked (and polled) until terminal, then drop off the
        # tail together with their per-index config-map keys
        self._condemned: set = set()
        # last monitor-written snapshot, for write-coalescing
        self._last_pushed: Dict[str, str] = {}

    # -- paper Fig. 2: main ----------------------------------------------

    def start(self) -> bool:
        """Connect and ensure the remote job(s) exist.  Returns False when
        the protocol already exited (submission failed or was killed —
        ``exit_code`` is set); True when monitoring should begin."""
        cm_data = self.cm.data
        url = cm_data["resourceURL"]
        image = cm_data["image"]
        self.poll = float(cm_data.get("updateinterval", "20"))

        # credentials from the mounted secret (never from the spec/config map)
        secret = self.secrets.mount(cm_data["resourcesecret"])
        token = secret.get("token", "")
        client = self.directory.connect(url, token)
        adapter = B.resolve_adapter(self.adapters, image)(client)

        # v1beta1 job arrays: the config map carries the fan-out count; a
        # single v1alpha1 job is the count=1 degenerate case of the same path
        count = max(int(cm_data.get("array_count", "1") or "1"), 1)
        ids = [s for s in cm_data.get("id", "").split(",") if s]
        if len(ids) < count:
            ids = self._submit(adapter, cm_data, count, ids)
            if not ids:
                return False  # FAILED already recorded; Fig. 2 klog.Exit path
        else:
            # paper: "Job has ID in ConfigMap. Handling state."
            pass
        self._adapter = adapter
        self._ids = ids
        self._count = len(ids)
        self._unknown_after = int(cm_data.get("unknown_after", "5"))
        self._retry_limit = int(cm_data.get("retry_limit", "0") or 0)
        self._backoff = float(cm_data.get("retry_backoff", "0") or 0)
        # per-index resubmission counts survive pod restarts via the cm
        self._attempts = {
            k: int(v) for k, v in
            json.loads(cm_data.get("retry_attempts", "{}") or "{}").items()}
        return True

    def _index_params(self, cm_data: Dict[str, str], index: int,
                      count: int) -> Dict[str, str]:
        """Per-index job params: base jobparams overlaid with the array's
        indexed_params[i], plus the injected BRIDGE_ARRAY_INDEX."""
        params = json.loads(cm_data.get("jobparams", "{}"))
        indexed = json.loads(cm_data.get("indexed_params", "[]") or "[]")
        if index < len(indexed):
            params.update(indexed[index])
        if count > 1:
            params.setdefault("BRIDGE_ARRAY_INDEX", str(index))
        return params

    def _submit(self, adapter: B.ResourceAdapter, cm_data: Dict[str, str],
                count: int = 1, ids: Optional[list] = None) -> list:
        self._checkpoint()
        ids = list(ids or [])
        retry_limit = int(cm_data.get("retry_limit", "0") or 0)
        backoff = float(cm_data.get("retry_backoff", "0") or 0)
        # persisted so a restarted pod never re-spends the submit budget
        attempt = int(cm_data.get("submit_attempts", "0") or 0)
        while True:
            if self.cm.get("kill", "false") == "true":
                self._abort_partial(adapter, ids)
                self.cm.update({"jobStatus": KILLED,
                                "message": "killed before submission"})
                self._exit(1)
                return []
            try:
                script = self._fetch_script(cm_data)
                self._stage_additional_data(adapter, cm_data)
                properties = json.loads(cm_data.get("jobproperties", "{}"))
                if (count > 1 and not ids
                        and adapter.supports(B.Capability.NATIVE_ARRAYS)):
                    # native fan-out: one submission call, N remote indices
                    ids = adapter.submit_array(
                        script, properties,
                        [self._index_params(cm_data, i, count)
                         for i in range(count)])
                    self.cm.update({"id": ",".join(ids)})
                else:
                    self._fanout_submit(adapter, cm_data, ids, count,
                                        script, properties)
                break
            except (B.SubmitError, TransportError, NoSuchKey, KeyError,
                    ValueError) as e:
                attempt += 1
                if attempt > retry_limit:
                    # don't orphan indices already fanned out this CR
                    self._abort_partial(adapter, ids)
                    self.cm.update(
                        {"jobStatus": FAILED,
                         "message": f"Failed to submit a job to HPC resource: {e}"})
                    self._exit(1)
                    return []
                self.cm.update({"submit_attempts": str(attempt)})
                self._sleep(backoff or self.min_sleep)
        self.cm.update({"id": ",".join(ids), "jobStatus": SUBMITTED,
                        "submit_time": str(time.time()), "message": ""})
        return ids

    def _fanout_submit(self, adapter: B.ResourceAdapter,
                       cm_data: Dict[str, str], ids: List[str], count: int,
                       script: str, properties: Dict[str, str]) -> None:
        """Facade-side fan-out: one submit per missing index, with the ``id``
        list flushed incrementally after EACH submission so a pod killed
        mid-fan-out (initial, resumed, or mid-scale-up) resumes at the next
        unsubmitted index instead of duplicating a live one.  Arrays go
        through resubmit_index so native dialects stamp their index marker
        even on a resumed fan-out."""
        while len(ids) < count:
            self._checkpoint()
            idx = len(ids)
            params = self._index_params(cm_data, idx, count)
            jid = (adapter.resubmit_index(script, properties, params, idx)
                   if count > 1
                   else adapter.submit(script, properties, params))
            ids.append(jid)
            self._push({"id": ",".join(ids)})

    def _abort_partial(self, adapter: B.ResourceAdapter, ids: list) -> None:
        """Best-effort cancel of indices submitted before an aborted fan-out."""
        if not ids or not adapter.supports(B.Capability.CANCEL):
            return
        for jid in ids:
            try:
                adapter.cancel(jid)
            except (TransportError, B.SubmitError):
                pass

    def _fetch_script(self, cm_data: Dict[str, str]) -> str:
        loc = cm_data.get("scriptlocation", "inline")
        script = cm_data.get("jobscript", "")
        if loc == "inline":
            return script
        if loc == "s3":
            bucket, key = ObjectStore.parse_ref(script)
            return self.s3.get_text(bucket, key)
        if loc == "remote":
            return script  # path already on the resource; submit by reference
        raise ValueError(f"scriptlocation {loc!r}")

    def _stage_additional_data(self, adapter: B.ResourceAdapter,
                               cm_data: Dict[str, str]) -> None:
        """Upload extra input files (s3 -> resource) where the API allows.

        The adapter's declared capabilities decide the path — no probing:
        without ``Capability.UPLOAD`` (e.g. slurmrestd) the job script must
        fetch from S3 itself, recorded for observability.
        """
        refs = [r for r in cm_data.get("additionaldata", "").split(",") if r]
        can_upload = adapter.supports(B.Capability.UPLOAD)
        for ref in refs:
            bucket, key = ObjectStore.parse_ref(ref)
            name = key.split("/")[-1]
            if not can_upload:
                self.cm.update({"staging": f"unsupported:{name}"})
                continue
            if not adapter.upload(name, self.s3.get(bucket, key)):
                self.cm.update({"staging": f"failed:{name}"})

    # -- paper Fig. 3: monitor ---------------------------------------------

    def _push(self, updates: Dict[str, Any]) -> None:
        """Monitor-side write coalescing: only keys whose value actually
        changed since the last monitor write reach the config map, so a
        steady-state tick costs zero store operations."""
        if not self.COALESCE_WRITES:
            self.cm.update({k: str(v) for k, v in updates.items()})
            return
        changed = {k: str(v) for k, v in updates.items()
                   if self._last_pushed.get(k) != str(v)}
        if changed:
            self.cm.update(changed)
            self._last_pushed.update(changed)

    def _poll_statuses(self, adapter: B.ResourceAdapter,
                       ids: List[str]) -> List[Dict[str, Any]]:
        """One tick's worth of remote status: batched (chunked) when the
        dialect declares BATCH_STATUS, per-id otherwise."""
        if len(ids) > 1 and adapter.supports(B.Capability.BATCH_STATUS):
            infos: List[Dict[str, Any]] = []
            for i in range(0, len(ids), B.BATCH_STATUS_CHUNK):
                infos.extend(
                    adapter.status_batch(ids[i:i + B.BATCH_STATUS_CHUNK]))
            return infos
        return [adapter.status(jid) for jid in ids]

    # -- elastic arrays: spec-patch reconcile (delta submit / cancel) -------

    def _scale_up(self, adapter: B.ResourceAdapter, cm_now: Dict[str, str],
                  desired: int) -> Optional[str]:
        """Submit exactly the missing indices [len(ids), desired) via the
        shared incremental fan-out.  A transient error leaves the remainder
        for the next tick; the returned stall diagnostic (if any) becomes
        this tick's status message."""
        try:
            self._fanout_submit(
                adapter, cm_now, self._ids, desired,
                self._fetch_script(cm_now),
                json.loads(cm_now.get("jobproperties", "{}")))
            return None
        except (B.SubmitError, TransportError, NoSuchKey, KeyError,
                ValueError) as e:
            return (f"scale-up to {desired} stalled at "
                    f"index {len(self._ids)}: {e}")

    def _reconcile_scale(self, adapter: B.ResourceAdapter,
                         cm_now: Dict[str, str],
                         desired: int) -> Optional[str]:
        """Diff desired vs. submitted indices and act on exactly the delta.
        Scale-down condemns the HIGHEST indices first; scale-up past a still-
        draining condemned tail waits until the tail is gone (index positions
        must free up before they are reused).  Returns a stall diagnostic
        when a scale-up could not complete this tick."""
        ids = self._ids
        n_live = len(ids) - len(self._condemned)
        if desired < n_live:
            for jid in ids[desired:n_live]:
                self._condemned.add(jid)
        elif desired > len(ids) and not self._condemned:
            return self._scale_up(adapter, cm_now, desired)
        return None

    def _try_cancel(self, adapter: B.ResourceAdapter, jid: str, state: str,
                    can_cancel_queued: bool) -> None:
        """Deliver ONE cancel, capability-gated and at-most-once: skipped for
        terminal/already-cancelled jobs, deferred for queued jobs the dialect
        cannot kill in-queue (wait for RUNNING), retried next poll on a
        transport failure.  Shared by the kill signal and scale-down drain so
        their delivery semantics cannot diverge."""
        if jid in self._cancel_sent or state in (DONE, FAILED, KILLED):
            return
        if state == SUBMITTED and not can_cancel_queued:
            return  # dialect can't kill queued jobs; wait for RUNNING
        try:
            adapter.cancel(jid)
            self._cancel_sent.add(jid)
        except TransportError:
            pass  # retry next poll

    def _drain_condemned(self, adapter: B.ResourceAdapter, cm_now: Dict[str, str],
                         states: List[str], infos: List[Dict[str, Any]]) -> None:
        """Cancel condemned indices (highest first) respecting the adapter's
        CANCEL / CANCEL_QUEUED capabilities, then pop the terminal condemned
        tail — GC'ing the per-index config-map keys (retry budget,
        results_location_{i}) those indices owned."""
        ids = self._ids
        can_cancel = adapter.supports(B.Capability.CANCEL)
        can_cancel_queued = adapter.supports(B.Capability.CANCEL_QUEUED)
        for i in range(len(ids) - 1, -1, -1):
            if ids[i] not in self._condemned:
                break  # condemned jids are a suffix
            if can_cancel:
                self._try_cancel(adapter, ids[i], states[i], can_cancel_queued)
        orphaned: List[str] = []
        while (ids and ids[-1] in self._condemned
               and states[-1] in (DONE, FAILED, KILLED)):
            jid = ids.pop()
            states.pop()
            infos.pop()
            self._condemned.discard(jid)
            self._cancel_sent.discard(jid)
            idx = len(ids)
            orphaned.append(f"results_location_{idx}")
            self._attempts.pop(str(idx), None)
        if orphaned:
            self.cm.prune(orphaned)
            for k in orphaned:
                self._last_pushed.pop(k, None)
            updates = {"id": ",".join(ids)}
            if self._retry_limit or "retry_attempts" in cm_now:
                updates["retry_attempts"] = json.dumps(self._attempts)
            self._push(updates)

    def tick(self) -> bool:
        """ONE Fig.-3 monitor iteration.  Returns True when the protocol
        finished (``exit_code`` is set); the driver waits ``poll`` seconds
        between calls."""
        adapter = self._adapter
        cm_now = self.cm.data  # Fig. 3: "Get current config map"
        kill_requested = cm_now.get("kill", "false") == "true"
        desired = max(int(cm_now.get("array_count", "1") or "1"), 1)
        is_array = "array_count" in cm_now or len(self._ids) > 1

        # elastic reconcile: act on a spec patch before polling (a kill
        # supersedes any pending resize — never grow a job being killed)
        stall_msg = None
        if not kill_requested:
            stall_msg = self._reconcile_scale(adapter, cm_now, desired)

        ids = self._ids
        self._count = len(ids)
        try:
            infos = self._poll_statuses(adapter, ids)
            self._consecutive_failures = 0
        except (TransportError, B.SubmitError) as e:
            self._consecutive_failures += 1
            if self._consecutive_failures >= self._unknown_after:
                # black-box honesty: unreachable != dead
                self._push({"jobStatus": UNKNOWN,
                            "message": f"resource unreachable: {e}"})
            return False

        states = [_CANON_TO_BRIDGE[info["state"]] for info in infos]
        if self._condemned:
            self._drain_condemned(adapter, cm_now, states, infos)
        live = [i for i in range(len(ids)) if ids[i] not in self._condemned]
        retry_limit, attempts = self._retry_limit, self._attempts

        # spec.retry: resubmit FAILED indices while budget remains
        # (a kill supersedes retries — never resubmit a killed CR; a
        # condemned index is being drained, never resubmitted)
        if retry_limit and not kill_requested:
            for i in live:
                st = states[i]
                used = attempts.get(str(i), 0)
                if st != FAILED or used >= retry_limit:
                    continue
                attempts[str(i)] = used + 1
                if self._backoff:
                    self._sleep(self._backoff)
                try:
                    # arrays go through resubmit_index so native dialects
                    # can restamp their index marker; single jobs resubmit
                    # plainly
                    resubmit = (adapter.resubmit_index if is_array
                                else lambda s, p, q, _i: adapter.submit(s, p, q))
                    new_id = resubmit(
                        self._fetch_script(cm_now),
                        json.loads(cm_now.get("jobproperties", "{}")),
                        self._index_params(cm_now, i, max(desired, len(ids))), i)
                except (B.SubmitError, TransportError, NoSuchKey,
                        KeyError, ValueError):
                    # budget consumed; surface FAILED when exhausted
                    self._push({"retry_attempts": json.dumps(attempts)})
                    continue
                ids[i] = new_id
                states[i] = SUBMITTED
                self._push({"id": ",".join(ids),
                            "retry_attempts": json.dumps(attempts)})

        def exhausted(i: int) -> bool:
            # a kill cancels the remaining budget — FAILED is final then
            return kill_requested or attempts.get(str(i), 0) >= retry_limit

        # terminal only when every LIVE index settled AND the desired count
        # is fully applied: exiting mid-drain would orphan condemned remote
        # jobs, and exiting below a stalled scale-up target would silently
        # drop an accepted patch (a kill supersedes the pending resize)
        finished = (not self._condemned
                    and (kill_requested or len(ids) == desired)
                    and all(
                        states[i] in (DONE, KILLED)
                        or (states[i] == FAILED and exhausted(i))
                        for i in live))
        # aggregate over the LIVE (desired) indices only — a condemned index
        # being drained must not colour the CR's state, times, or results
        if finished:
            if all(states[i] == DONE for i in live):
                agg = DONE
            elif any(states[i] == KILLED for i in live):
                agg = KILLED
            else:
                agg = FAILED
        elif any(states[i] == RUNNING for i in live):
            agg = RUNNING
        else:
            agg = SUBMITTED

        updates = {"jobStatus": agg,
                   "message": stall_msg or self._aggregate_message(
                       [states[i] for i in live],
                       [infos[i] for i in live])}
        if is_array:
            updates["index_states"] = json.dumps(
                {str(i): states[i] for i in live})
        starts = [infos[i].get("start_time") for i in live
                  if infos[i].get("start_time")]
        ends = [infos[i].get("end_time") for i in live
                if infos[i].get("end_time")]
        if starts:
            updates["start_time"] = str(min(starts))
        if ends and (len(ids) == 1 or finished):
            updates["end_time"] = str(max(ends))
        for i in live:
            if infos[i].get("results_location"):
                key = (f"results_location_{i}" if is_array
                       else "results_location")
                updates[key] = infos[i]["results_location"]
        # the Kubernetes convergence handshake: report the generation whose
        # desired state is now fully applied (all indices submitted, nothing
        # draining) so clients can await `observedGeneration == generation`
        if (cm_now.get("generation") and not self._condemned
                and len(ids) == desired):
            updates["observed_generation"] = cm_now["generation"]
        self._push(updates)

        if kill_requested and adapter.supports(B.Capability.CANCEL):
            can_cancel_queued = adapter.supports(B.Capability.CANCEL_QUEUED)
            for jid, st in zip(ids, states):
                self._try_cancel(adapter, jid, st, can_cancel_queued)

        if finished:
            if agg == DONE:
                self._finalize_outputs(adapter, ids, cm_now)
                self._exit(0)
            else:
                self._exit(1)
            return True
        return False

    @staticmethod
    def _aggregate_message(states: list, infos: list) -> str:
        if len(states) == 1:
            return infos[0].get("reason", "") or ""
        parts = [f"[{i}] {info.get('reason', '')}"
                 for i, info in enumerate(infos) if info.get("reason")]
        return "; ".join(parts)

    def _finalize_outputs(self, adapter: B.ResourceAdapter, ids: list,
                          cm_data: Dict[str, str]) -> None:
        """Download outputs from the resource; upload to S3 if configured.
        Array indices land under ``<pod>/<index>/`` prefixes."""
        self._checkpoint()
        props = json.loads(cm_data.get("jobproperties", "{}"))
        bucket = cm_data.get("s3uploadbucket", "")
        names = [n for n in cm_data.get("s3uploadfiles", "").split(",") if n]
        for key in ("OutputFileName", "ErrorFileName"):
            if props.get(key) and props[key] not in names:
                names.append(props[key])
        can_download = adapter.supports(B.Capability.DOWNLOAD)
        can_logs = adapter.supports(B.Capability.LOGS)
        if not names or not (can_download or can_logs):
            return
        uploaded = []
        for idx, jid in enumerate(ids):
            prefix = self.name if len(ids) == 1 else f"{self.name}/{idx}"
            for name in names:
                data = adapter.download(name) if can_download else None
                if data is None and can_logs:
                    data = adapter.download_logs(jid)  # ray idiom
                if data is None:
                    continue
                if bucket:
                    self.s3.put(bucket, f"{prefix}/{name}", data)
                    uploaded.append(f"{bucket}:{prefix}/{name}")
        if uploaded:
            self.cm.update({"outputs": ",".join(uploaded)})

    def _exit(self, code: int) -> None:
        self.exit_code = code


class ControllerPod:
    # pod phases (Kubernetes-like)
    PENDING = "Pending"
    RUNNING_PHASE = "Running"
    SUCCEEDED = "Succeeded"
    FAILED_PHASE = "Failed"
    KILLED_PHASE = "Killed"   # external kill (node loss) — operator restarts

    def __init__(self, name: str, configmap: ConfigMap, secrets: SecretStore,
                 objectstore: ObjectStore, directory: ResourceManagerDirectory,
                 adapters: Mapping[str, Type[B.ResourceAdapter]],
                 min_sleep: float = 0.005):
        self.name = name
        self.cm = configmap
        self.min_sleep = min_sleep
        self.phase = self.PENDING
        self.exit_code: Optional[int] = None
        self.error: str = ""
        self._killed = threading.Event()
        self._proto = JobProtocol(
            name, configmap, secrets, objectstore, directory, adapters,
            checkpoint=self._checkpoint, sleep=self._sleep,
            min_sleep=min_sleep)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"pod-{name}")

    # -- lifecycle --------------------------------------------------------

    def start(self) -> None:
        self._thread.start()

    def kill_pod(self) -> None:
        """Simulate pod/node failure: abort without flushing state."""
        self._killed.set()

    def poke(self) -> None:
        """Spec-patch notification.  The paper-faithful pod has no wake-up
        channel — it polls the config map every ``updateinterval`` — so a
        resize is picked up at the next tick; the multiplexed MonitorTask
        reschedules immediately instead."""

    def alive(self) -> bool:
        return self._thread.is_alive()

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)

    # -- internals ----------------------------------------------------------

    def _checkpoint(self) -> None:
        """Action boundary: a killed pod dies here, state unflushed."""
        if self._killed.is_set():
            raise PodKilled(self.name)

    def _sleep(self, seconds: float) -> None:
        killable_sleep(self._killed, self.name, seconds, self.min_sleep)

    def _run(self) -> None:
        self.phase = self.RUNNING_PHASE
        try:
            self._main()
        except PodKilled:
            self.phase = self.KILLED_PHASE
        except Exception as e:  # pod crash (bug/unhandled) — operator restarts
            self.error = f"{type(e).__name__}: {e}"
            self.phase = self.KILLED_PHASE

    def _main(self) -> None:
        proto = self._proto
        if not proto.start():
            self._exit(proto.exit_code)
            return
        while True:
            self._sleep(proto.poll)
            if proto.tick():
                self._exit(proto.exit_code)
                return

    def _exit(self, code: int) -> None:
        self.exit_code = code
        self.phase = self.SUCCEEDED if code == 0 else self.FAILED_PHASE
