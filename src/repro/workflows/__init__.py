from repro.workflows.pipeline import Pipeline, PipelineOp, bridge_pipeline
