"""Request routing for BridgeService — the data-plane half of serving.

``ServiceHandle`` is the kubectl-style control surface over one BridgeService
CR (scale / kill / wait-ready, mirroring ``JobHandle``).  ``ServiceEndpoint``
is the request router: it load-balances invocations across the replicas the
service reports READY, re-resolving ``status.endpoints`` from the registry on
every request so that a condemned replica is drained the same tick the
control plane flips its ``ready`` flag.

Routing policy is least-outstanding-requests: among ready replicas, pick the
one with the fewest in-flight invocations (ties broken by total request
count, then replica index).  Adapter connections are cached per
``(resourceURL, image, resourcesecret)`` target, so every endpoint on the
same resource manager shares one ``Channel`` — connection reuse is the
channel memo's job, not the router's.

Delivery contract: a request is retried on another replica when the attempt
fails in a way that indicts the REPLICA (transport error, 404 gone,
503 unready, 5xx crash) — so killing a replica mid-traffic loses no accepted
request.  The failed replica is locally suspended for a short TTL to stop
the router hammering it before the control plane condemns it.  The flip side
is at-least-once execution across replicas on failure: a replica that dies
AFTER executing but before replying will have its request re-executed
elsewhere.  Status codes that indict the REQUEST (4xx other than 404) are
raised to the caller unretried.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
import uuid
from collections import deque
from typing import Any, Dict, List, Optional

from repro.core.backends import base as B
from repro.core.resource import (BridgeService, BridgeServiceSpec,
                                 BridgeServiceStatus, ValidationError)
from repro.core.rest import TransportError


class NoReadyReplicas(RuntimeError):
    """No replica answered within the request budget."""


@dataclasses.dataclass(frozen=True)
class ServiceHandle:
    """A client-side reference to one BridgeService CR."""
    bridge: Any
    name: str
    namespace: str = "default"

    def service(self) -> Optional[BridgeService]:
        return self.bridge.registry.get(self.name, self.namespace)

    def status(self) -> BridgeServiceStatus:
        svc = self.service()
        if svc is None:
            raise KeyError(
                f"BridgeService {self.namespace}/{self.name} not found")
        return svc.status

    def endpoints(self) -> List[dict]:
        """``status.endpoints`` — one dict per replica:
        {replica, slice, resourceURL, image, resourcesecret, job_id, ready}."""
        return [dict(e) for e in self.status().endpoints]

    def ready_replicas(self) -> int:
        return self.status().ready_replicas

    def wait_ready(self, replicas: Optional[int] = None,
                   timeout: float = 30.0) -> BridgeService:
        """Block until at least ``replicas`` (default: spec.replicas) report
        ready, or raise TimeoutError.  A terminal service can never become
        ready and fails fast."""
        deadline = time.time() + timeout
        svc = None
        while time.time() < deadline:
            svc = self.service()
            if svc is not None:
                want = replicas if replicas is not None else svc.spec.replicas
                if svc.status.ready_replicas >= want:
                    return svc
                if svc.status.terminal():
                    raise NoReadyReplicas(
                        f"BridgeService {self.namespace}/{self.name} is "
                        f"terminal ({svc.status.state})")
            time.sleep(0.01)
        raise TimeoutError(
            f"BridgeService {self.namespace}/{self.name} not ready after "
            f"{timeout}s (ready={svc.status.ready_replicas if svc else '?'})")

    def scale(self, replicas: int) -> "ServiceHandle":
        """Resize the service to ``replicas``; the reconciler submits or
        condemns exactly the delta (scale-down drains the highest replica
        indices first)."""
        if replicas < 1:
            raise ValidationError("service replicas must be >= 1")

        def guarded(spec: BridgeServiceSpec) -> BridgeServiceSpec:
            cur = self.service()
            if cur is not None and cur.status.terminal():
                raise ValidationError(
                    f"cannot scale terminal BridgeService "
                    f"{self.namespace}/{self.name} ({cur.status.state})")
            return dataclasses.replace(spec, replicas=replicas)

        self.bridge.registry.update_spec(self.name, guarded, self.namespace)
        return self

    def wait_reconciled(self, timeout: float = 30.0) -> BridgeService:
        return self.bridge.wait_reconciled(self.name, self.namespace,
                                           timeout=timeout)

    def cancel(self) -> None:
        """Kill the service: cancel every replica, settle the CR KILLED."""
        self.bridge.registry.update_spec(
            self.name, lambda s: dataclasses.replace(s, kill=True),
            self.namespace)

    def wait(self, timeout: float = 30.0) -> BridgeService:
        """Block until terminal (only a kill makes a service terminal)."""
        return self.bridge.wait(self.name, self.namespace, timeout=timeout)

    def delete(self) -> None:
        self.bridge.delete(self.name, self.namespace)

    def autoscale_status(self) -> Dict[str, Any]:
        """Mirrored autoscaler state ({} unless ``spec.autoscale`` is set):
        ``{desired, min, max, signals: {outstanding, p99_s, reports},
        last_scale_up, last_scale_down}``."""
        return dict(self.status().autoscale or {})

    def router(self, **kwargs) -> "ServiceEndpoint":
        return ServiceEndpoint(self.bridge, self.name, self.namespace,
                               **kwargs)


class ServiceEndpoint:
    """Load-balancing request router over one BridgeService's replicas."""

    def __init__(self, bridge: Any, name: str, namespace: str = "default",
                 request_timeout: float = 30.0,
                 suspend_ttl: float = 0.5,
                 latency_window: int = 256,
                 report_interval: float = 0.25,
                 report_load: Optional[bool] = None,
                 retired_window: int = 16):
        self.bridge = bridge
        self.name = name
        self.namespace = namespace
        self.request_timeout = request_timeout
        self.suspend_ttl = suspend_ttl
        self._latency_window = latency_window
        self._mu = threading.Lock()
        # adapter per target: all endpoints behind one manager share a Channel
        self._adapters: Dict[tuple, B.ResourceAdapter] = {}
        # job_id -> suspended-until (local short fuse after a failed attempt)
        self._down: Dict[str, float] = {}
        # job_id -> live counters for THIS replica incarnation
        self._stats: Dict[str, Dict[str, Any]] = {}
        # last N replaced incarnations' counters (stats() still reports a
        # recently-dead jid; the ring bound is what stops unbounded growth)
        self._retired: deque = deque(maxlen=retired_window)
        # load reporting (the autoscaler's input): None = only when the
        # service declares spec.autoscale; True/False force it either way
        self._report_load = report_load
        self._report_interval = report_interval
        self._router_id = uuid.uuid4().hex[:8]
        self._next_report = 0.0
        self._last_report_ts = 0.0
        self._last_report_requests = 0

    # -- endpoint resolution ----------------------------------------------

    def _ready_endpoints(self) -> List[dict]:
        svc = self.bridge.registry.get(self.name, self.namespace)
        if svc is None:
            raise KeyError(
                f"BridgeService {self.namespace}/{self.name} not found")
        now = time.time()
        current = {e["job_id"] for e in svc.status.endpoints
                   if e.get("job_id")}
        with self._mu:
            # prune replaced incarnations and stale suspensions so a
            # long-lived router under replica churn stays O(replicas):
            # retired counters move to the ring (in-flight requests still
            # hold the SAME dict, so their decrements keep landing)
            for jid in [j for j in self._stats if j not in current]:
                st = self._stats.pop(jid)
                st["retired_at"] = now
                self._retired.append(st)
            for jid in [j for j, until in self._down.items()
                        if until <= now or j not in current]:
                del self._down[jid]
        eps = []
        for e in svc.status.endpoints:
            if not e.get("ready") or not e.get("job_id"):
                continue
            if self._down.get(e["job_id"], 0.0) > now:
                continue
            eps.append(e)
        self._maybe_report(svc, now)
        return eps

    def _adapter_for(self, ep: dict) -> B.ResourceAdapter:
        key = (ep["resourceURL"], ep["image"], ep["resourcesecret"])
        with self._mu:
            ad = self._adapters.get(key)
        if ad is None:
            ad = self.bridge.connect_adapter(*key)
            with self._mu:
                ad = self._adapters.setdefault(key, ad)
        return ad

    def _entry(self, ep: dict) -> Dict[str, Any]:
        jid = ep["job_id"]
        with self._mu:
            st = self._stats.get(jid)
            if st is None:
                st = self._stats[jid] = {
                    "replica": ep["replica"], "job_id": jid,
                    "requests": 0, "errors": 0, "outstanding": 0,
                    "latencies": deque(maxlen=self._latency_window),
                }
        return st

    # -- load reporting (router -> control plane) --------------------------

    def _maybe_report(self, svc: BridgeService, now: float) -> None:
        """Publish this router's per-replica load snapshot into the service
        config map (key ``loadreport_<router-id>``) at most once per
        ``report_interval``.  The ServiceProtocol merges every router's
        report — staleness-bounded by the TTL carried in the report itself —
        into the autoscale signals; see ``spec.autoscale``.  Off unless the
        service opted into autoscaling (keeps the cm byte-identical for
        plain services) or ``report_load=True`` forced it."""
        if self._report_load is False:
            return
        if self._report_load is None and getattr(
                svc.spec, "autoscale", None) is None:
            return
        if now < self._next_report:
            return
        store = getattr(self.bridge, "statestore", None)
        if store is None:
            return
        with self._mu:
            self._next_report = now + self._report_interval
            replicas: Dict[str, Dict[str, Any]] = {}
            lat_all: List[float] = []
            total_requests = 0
            outstanding = 0
            for jid, st in self._stats.items():
                lat = sorted(st["latencies"])
                replicas[jid] = {
                    "replica": st["replica"],
                    "outstanding": st["outstanding"],
                    "requests": st["requests"],
                    "p50_s": lat[len(lat) // 2] if lat else None,
                    "p99_s": lat[min(len(lat) - 1,
                                     int(len(lat) * 0.99))] if lat else None,
                }
                lat_all.extend(lat)
                total_requests += st["requests"]
                outstanding += st["outstanding"]
            window = now - self._last_report_ts
            rate = ((total_requests - self._last_report_requests) / window
                    if self._last_report_ts and window > 0 else 0.0)
            self._last_report_ts = now
            self._last_report_requests = total_requests
        lat_all.sort()
        report = {
            "router": self._router_id, "ts": now,
            # consumed-by TTL: the control plane drops (and prunes) reports
            # from routers that stopped publishing — a dead client must not
            # freeze the load signal at its last value
            "ttl": max(3 * self._report_interval, 1.0),
            "outstanding": outstanding,
            "rate_rps": round(rate, 3),
            "p50_s": lat_all[len(lat_all) // 2] if lat_all else None,
            "p99_s": lat_all[min(len(lat_all) - 1,
                                 int(len(lat_all) * 0.99))]
                     if lat_all else None,
            "replicas": replicas,
        }
        try:
            cm = store.get(f"{self.namespace}/{self.name}-bridge-cm")
            cm.update({f"loadreport_{self._router_id}": json.dumps(report)})
        except KeyError:
            pass  # no cm yet (service still admitting): report next time

    def _pick(self, eps: List[dict]) -> dict:
        """Least outstanding requests; ties fall to fewest total requests,
        then lowest replica index (deterministic)."""
        def load(ep):
            st = self._entry(ep)
            return (st["outstanding"], st["requests"], ep["replica"])
        return min(eps, key=load)

    # -- the request path --------------------------------------------------

    @staticmethod
    def _replica_fault(exc: Exception) -> bool:
        """True when the failure indicts the replica (retry elsewhere)."""
        if isinstance(exc, TransportError):
            return True
        if isinstance(exc, B.InvokeError):
            return exc.status == 404 or exc.status >= 500
        return False

    def request(self, payload: Any,
                timeout: Optional[float] = None) -> Any:
        """Route one invocation to the least-loaded ready replica.

        Replica-fault failures are retried on another replica until the
        request budget runs out; request-fault failures (4xx) raise
        immediately.  With no ready replica, the call parks and re-resolves
        until one appears or the budget is spent."""
        deadline = time.time() + (timeout if timeout is not None
                                  else self.request_timeout)
        last_exc: Optional[Exception] = None
        while True:
            eps = self._ready_endpoints()
            if not eps:
                if time.time() >= deadline:
                    raise NoReadyReplicas(
                        f"no ready replica for {self.namespace}/{self.name} "
                        f"within the request budget"
                    ) from last_exc
                time.sleep(0.01)
                continue
            ep = self._pick(eps)
            st = self._entry(ep)
            adapter = self._adapter_for(ep)
            with self._mu:
                st["requests"] += 1
                st["outstanding"] += 1
            t0 = time.time()
            try:
                result = adapter.invoke(ep["job_id"], payload)
            except Exception as exc:
                with self._mu:
                    st["outstanding"] -= 1
                    st["errors"] += 1
                if not self._replica_fault(exc):
                    raise
                last_exc = exc
                # short local suspension: stop re-picking a replica the
                # control plane has not yet condemned
                with self._mu:
                    self._down[ep["job_id"]] = time.time() + self.suspend_ttl
                if time.time() >= deadline:
                    raise NoReadyReplicas(
                        f"request to {self.namespace}/{self.name} exhausted "
                        f"its budget retrying failed replicas") from exc
                continue
            with self._mu:
                st["outstanding"] -= 1
                st["latencies"].append(time.time() - t0)
            return result

    __call__ = request

    # -- observability -----------------------------------------------------

    def stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-replica-incarnation counters, keyed by remote job id:
        {replica, job_id, requests, errors, outstanding, p50_s, p99_s,
        retired}.  Live incarnations come from the live table; recently
        replaced ones (``retired: True``) from the bounded retired ring, so
        a jid stays reportable for a while after its replica is replaced.
        Each incarnation owns its own latency window — a replacement starts
        from an empty deque, never averaging across incarnations."""
        out: Dict[str, Dict[str, Any]] = {}
        with self._mu:
            entries = ([(st, True) for st in self._retired]
                       + [(st, False) for st in self._stats.values()])
            for st, retired in entries:
                lat = sorted(st["latencies"])
                out[st["job_id"]] = {
                    "replica": st["replica"], "job_id": st["job_id"],
                    "requests": st["requests"], "errors": st["errors"],
                    "outstanding": st["outstanding"],
                    "p50_s": lat[len(lat) // 2] if lat else None,
                    "p99_s": lat[min(len(lat) - 1,
                                     int(len(lat) * 0.99))] if lat else None,
                    "retired": retired,
                }
        return out
