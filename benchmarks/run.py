"""Control-plane benchmark harness — one benchmark per paper figure/claim.

The paper is qualitative (architecture + pseudocode + workflow), so each
figure maps to a measurable property of this implementation:

  fig2_submission_latency   — Fig. 2 main(): CR create -> remote job id, per
                              backend (the bridge's dispatch overhead).
  fig3_monitor_throughput   — Fig. 3 monitor(): concurrent jobs one operator
                              sustains; REST polls/sec at two poll intervals.
  sec51_restart_recovery    — §5.1 restart semantics: pod-kill -> re-attach
                              latency, and zero double submissions.
  fig4_workflow_overhead    — Fig. 4: 3-step pipeline wall time vs the bare
                              job duration (workflow tax).
  sec4_staging_throughput   — §4 objectives: S3 -> resource file staging
                              bandwidth through the REST facade (LSF).
  e2e_bridged_training      — the jaxlocal backend: bridged REAL training
                              wall time vs running the same loop unbridged
                              (bridge overhead on a real workload).

Output: CSV `name,metric,value` on stdout (tee'd to bench_output.txt).

`--smoke` shrinks every benchmark's iteration counts and payload sizes so
the whole harness finishes in well under a minute for CI — the numbers are
not comparable to a full run, only the plumbing is exercised.
"""
import json
import statistics
import time

from common import make_parser, pick

ROWS = []
SMOKE = False


def reps(full: int, smoke: int) -> int:
    return pick(SMOKE, full, smoke)


def emit(name: str, metric: str, value) -> None:
    ROWS.append((name, metric, value))
    print(f"{name},{metric},{value}", flush=True)


def fig2_submission_latency() -> None:
    from repro.core import BridgeEnvironment

    with BridgeEnvironment(default_duration=0.05) as env:
        for kind in ("slurm", "lsf", "quantum", "ray", "jaxlocal"):
            script = (json.dumps({"arch": "gemma-2b", "steps": 1, "batch": 1,
                                  "seq": 8})
                      if kind == "jaxlocal" else "payload")
            lats = []
            for i in range(reps(5, 2)):
                name = f"lat-{kind}-{i}"
                t0 = time.time()
                env.submit(name, env.make_spec(kind, script=script,
                                               updateinterval=0.005))
                while not env.registry.get(name).status.job_id:
                    time.sleep(0.001)
                lats.append(time.time() - t0)
                env.operator.wait_for(name, timeout=120)
            emit("fig2_submission_latency", f"{kind}_p50_ms",
                 round(statistics.median(lats) * 1e3, 2))


def fig3_monitor_throughput() -> None:
    from repro.core import BridgeEnvironment

    for poll in ((0.02,) if SMOKE else (0.02, 0.1)):
        with BridgeEnvironment(default_duration=1.0, slots=64) as env:
            n = reps(32, 8)
            t0 = time.time()
            for i in range(n):
                env.submit(f"mon-{i}", env.make_spec(
                    "slurm", script="x", updateinterval=poll,
                    jobproperties={"WallSeconds": "1.0"}))
            for i in range(n):
                env.operator.wait_for(f"mon-{i}", timeout=60)
            wall = time.time() - t0
            reqs = env.servers["slurm"].request_count
            emit("fig3_monitor_throughput", f"poll{poll}_jobs", n)
            emit("fig3_monitor_throughput", f"poll{poll}_wall_s", round(wall, 2))
            emit("fig3_monitor_throughput", f"poll{poll}_rest_requests", reqs)
            emit("fig3_monitor_throughput", f"poll{poll}_req_per_job",
                 round(reqs / n, 1))


def sec51_restart_recovery() -> None:
    from repro.core import BridgeEnvironment, RUNNING, SUBMITTED

    with BridgeEnvironment(default_duration=0.8) as env:
        recov = []
        n = reps(5, 2)
        for i in range(n):
            name = f"rst-{i}"
            env.submit(name, env.make_spec("slurm", script="x",
                                           updateinterval=0.02,
                                           jobproperties={"WallSeconds": "0.8"}))
            while env.registry.get(name).status.state not in (SUBMITTED,
                                                              RUNNING):
                time.sleep(0.002)
            pod = env.operator.pods[f"default/{name}"]
            t0 = time.time()
            pod.kill_pod()
            # recovery = a NEW pod is alive again
            while True:
                p2 = env.operator.pods.get(f"default/{name}")
                if p2 is not None and p2 is not pod and p2.alive():
                    break
                time.sleep(0.002)
            recov.append(time.time() - t0)
            env.operator.wait_for(name, timeout=60)
        emit("sec51_restart_recovery", "pod_restart_p50_ms",
             round(statistics.median(recov) * 1e3, 1))
        emit("sec51_restart_recovery", "double_submissions",
             len(env.clusters["slurm"].jobs) - n)


def fig4_workflow_overhead() -> None:
    from repro.core import BridgeEnvironment, IMAGES, URLS
    from repro.workflows import bridge_pipeline

    with BridgeEnvironment(default_duration=0.5) as env:
        t0 = time.time()
        pipe = bridge_pipeline(env, "bench", resourceURL=URLS["slurm"],
                               resourcesecret="slurm-secret", script="x",
                               scriptlocation="inline",
                               docker=IMAGES["slurm"], updateinterval=0.02)
        pipe.run()
        wall = time.time() - t0
        emit("fig4_workflow_overhead", "pipeline_wall_s", round(wall, 3))
        emit("fig4_workflow_overhead", "job_duration_s", 0.5)
        emit("fig4_workflow_overhead", "overhead_ms",
             round((wall - 0.5) * 1e3, 1))


def sec4_staging_throughput() -> None:
    from repro.core import BridgeEnvironment, TOKENS, URLS
    from repro.core.backends.lsf import LSFAdapter

    with BridgeEnvironment() as env:
        client = env.directory.connect(URLS["lsf"], TOKENS["lsf"])
        ad = LSFAdapter(client)
        n = reps(8, 2)
        blob = b"\x5a" * ((1 if SMOKE else 4) << 20)
        t0 = time.time()
        for i in range(n):
            ad.upload(f"stage-{i}.bin", blob)
        up = n * len(blob) / (time.time() - t0) / 2**20
        t0 = time.time()
        for i in range(n):
            ad.download(f"stage-{i}.bin")
        down = n * len(blob) / (time.time() - t0) / 2**20
        emit("sec4_staging_throughput", "upload_MiB_s", round(up, 1))
        emit("sec4_staging_throughput", "download_MiB_s", round(down, 1))


def e2e_bridged_training() -> None:
    from repro.core import BridgeEnvironment
    from repro.core.backends.jaxlocal import train_job
    from repro.core.objectstore import ObjectStore

    spec = {"arch": "gemma-2b", "steps": reps(20, 3), "batch": 2, "seq": 16,
            "checkpoint_every": 0, "lr": 1e-3}
    # unbridged baseline
    t0 = time.time()
    train_job(spec, ObjectStore())
    base = time.time() - t0
    # bridged
    with BridgeEnvironment() as env:
        t0 = time.time()
        env.submit("bench-train", env.make_spec(
            "jaxlocal", script=json.dumps(spec), updateinterval=0.05,
            jobproperties={"OutputFileName": "t.out"}))
        env.operator.wait_for("bench-train", timeout=300)
        bridged = time.time() - t0
    emit("e2e_bridged_training", "unbridged_s", round(base, 2))
    emit("e2e_bridged_training", "bridged_s", round(bridged, 2))
    emit("e2e_bridged_training", "bridge_overhead_pct",
         round((bridged - base) / base * 100, 1))


BENCHES = [fig2_submission_latency, fig3_monitor_throughput,
           sec51_restart_recovery, fig4_workflow_overhead,
           sec4_staging_throughput, e2e_bridged_training]


def main() -> None:
    global SMOKE
    p = make_parser("control-plane benchmark harness")
    p.add_argument("names", nargs="*",
                   help="substring filter on benchmark names")
    args = p.parse_args()
    SMOKE = args.smoke
    print("name,metric,value")
    for b in BENCHES:
        if args.names and not any(n in b.__name__ for n in args.names):
            continue
        b()
    print(f"# {len(ROWS)} rows ok")


if __name__ == "__main__":
    main()
