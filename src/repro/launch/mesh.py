"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Mesh over the actually-available devices (tests / local training)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware model used for the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,            # B/s
    "ici_bw": 50e9,             # B/s per link
    "hbm_bytes": 16 * 1024**3,  # capacity
}
