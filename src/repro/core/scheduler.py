"""Load-aware backend selection — the paper's named FUTURE WORK (§7):

    "Future work will focus on creating companion operator using the same
    approach to monitor current load on these remote resources and make
    intelligent decisions on which remote resource ... to use for execution."

Beyond-paper feature: a companion that polls each registered resource
manager's queue via the SAME HTTP surface the bridge uses, scores load, and
picks a target.  Also provides speculative (straggler-mitigation) execution:
launch the same payload on the two least-loaded resources, keep the first
finisher, kill the other.

The scheduler is a pure ``Bridge`` client: it asks the facade for adapter
capabilities (only ``QUEUE_LOAD``-capable targets are schedulable) and
submits/cancels through it — no hand-wired directory/secrets/adapters.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

from repro.core.api import Bridge, JobHandle
from repro.core.backends.base import Capability
from repro.core.resource import BridgeJob, BridgeJobSpec, DONE
from repro.core.rest import TransportError


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One schedulable target: where + how to talk to it."""
    resourceURL: str
    image: str           # selects the controller-pod adapter
    resourcesecret: str


class LoadAwareScheduler:
    def __init__(self, bridge: Bridge, candidates: List[Candidate]):
        self.bridge = bridge
        self.candidates = list(candidates)

    def load_of(self, cand: Candidate) -> Optional[float]:
        """Normalized load: (queued + running) / slots.  None if the backend
        does not advertise QUEUE_LOAD or is unreachable."""
        try:
            if Capability.QUEUE_LOAD not in self.bridge.capabilities(cand.image):
                return None
            adapter = self.bridge.connect_adapter(
                cand.resourceURL, cand.image, cand.resourcesecret)
            q = adapter.queue_load()
        except (TransportError, KeyError):
            return None
        if not q or not q.get("slots"):
            return None
        return (q["queued"] + q["running"]) / q["slots"]

    def rank(self) -> List[Tuple[float, Candidate]]:
        scored = []
        for c in self.candidates:
            load = self.load_of(c)
            if load is not None:
                scored.append((load, c))
        scored.sort(key=lambda t: t[0])
        return scored

    def pick(self) -> Candidate:
        ranked = self.rank()
        if not ranked:
            raise RuntimeError("no reachable candidate resource")
        return ranked[0][1]

    def place(self, spec: BridgeJobSpec) -> BridgeJobSpec:
        """Rewrite a spec to target the least-loaded candidate."""
        best = self.pick()
        return dataclasses.replace(spec, resourceURL=best.resourceURL,
                                   image=best.image,
                                   resourcesecret=best.resourcesecret)

    def submit_placed(self, name: str, spec: BridgeJobSpec,
                      namespace: str = "default") -> JobHandle:
        """Place + submit in one step through the facade."""
        return self.bridge.submit(name, self.place(spec), namespace=namespace)

    def scale_placed(self, name: str, count: int,
                     namespace: str = "default") -> JobHandle:
        """Elastic scale with placement re-consulted (a CR targets exactly
        ONE resourceURL, so the new indices land on the job's existing
        target): growth is refused when that target no longer advertises
        queue load — unreachable, or not a QUEUE_LOAD candidate — instead of
        piling more indices onto a black hole.  Scale-down always proceeds.
        """
        job = self.bridge.registry.get(name, namespace)
        if job is None:
            raise KeyError(f"BridgeJob {namespace}/{name} not found")
        current = job.spec.array.count if job.spec.array else 1
        if count > current:
            cand = next((c for c in self.candidates
                         if c.resourceURL == job.spec.resourceURL), None)
            if cand is None or self.load_of(cand) is None:
                raise RuntimeError(
                    f"cannot scale up {namespace}/{name}: target "
                    f"{job.spec.resourceURL!r} is not schedulable")
        return self.bridge.scale(name, count, namespace=namespace)

    # -- speculative execution (straggler mitigation) ------------------------

    def submit_speculative(self, base_name: str, spec: BridgeJobSpec,
                           n: int = 2, namespace: str = "default",
                           timeout: float = 60.0) -> BridgeJob:
        """Run the payload on the ``n`` least-loaded resources; return the
        first DONE job and kill the rest.  Raises if all replicas fail."""
        ranked = self.rank()
        if not ranked:
            raise RuntimeError("no reachable candidate resource")
        handles: List[JobHandle] = []
        for i, (_, cand) in enumerate(ranked[:n]):
            s = dataclasses.replace(spec, resourceURL=cand.resourceURL,
                                    image=cand.image,
                                    resourcesecret=cand.resourcesecret)
            handles.append(self.bridge.submit(f"{base_name}-spec{i}", s,
                                              namespace=namespace))
        deadline = time.time() + timeout
        winner: Optional[BridgeJob] = None
        while time.time() < deadline and winner is None:
            jobs = [h.job() for h in handles]
            for job in jobs:
                if job and job.status.state == DONE:
                    winner = job
                    break
            if all(j and j.status.terminal() and j.status.state != DONE
                   for j in jobs):
                raise RuntimeError(
                    f"all speculative replicas failed: "
                    f"{[(j.name, j.status.state) for j in jobs]}")
            time.sleep(0.01)
        if winner is None:
            raise TimeoutError("speculative execution timed out")
        for h in handles:  # kill the stragglers
            if h.name != winner.name:
                job = h.job()
                if job and not job.status.terminal():
                    h.cancel()
        return winner
