"""JAX version probing for the compat layer.

Resolution policy everywhere in ``repro.compat``: probe for the API
(``hasattr`` / signature inspection), never compare version strings to
decide behaviour — version numbers lie across backports and dev builds.
The parsed version here is for *reporting* (``describe()``, error
messages), not for dispatch.
"""
from __future__ import annotations

import re
from typing import Tuple

import jax


def jax_version() -> str:
    return jax.__version__


def jax_version_tuple() -> Tuple[int, int, int]:
    """Best-effort (major, minor, patch); unparsable segments become 0."""
    parts = re.split(r"[.+rc-]", jax.__version__)
    nums = []
    for p in parts[:3]:
        nums.append(int(p) if p.isdigit() else 0)
    while len(nums) < 3:
        nums.append(0)
    return tuple(nums)  # type: ignore[return-value]


def at_least(major: int, minor: int, patch: int = 0) -> bool:
    """Reporting/diagnostics helper only — dispatch must probe APIs."""
    return jax_version_tuple() >= (major, minor, patch)
