import os, sys
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp
from repro.configs.base import get_smoke_config, ShapeConfig, ARCH_IDS
from repro.steps import make_synthetic_batch, init_model
from repro.models import transformer as TF
from repro.models import decoding as DEC

shape = ShapeConfig("tiny_train", 32, 2, "train")
dshape = ShapeConfig("tiny_dec", 32, 2, "decode")

for arch in sys.argv[1:] or ARCH_IDS:
    cfg = get_smoke_config(arch)
    try:
        defs, params = init_model(cfg, max_seq=64)
        batch = make_synthetic_batch(cfg, shape)
        loss, metrics = TF.forward_train(params, cfg, batch, remat=False)
        assert jnp.isfinite(loss), f"{arch}: loss not finite"
        # prefill + decode
        pre_batch = {k: v for k, v in batch.items() if k not in ("targets", "mask")}
        logits, cache = DEC.prefill(params, cfg, pre_batch, max_len=48)
        logits2, cache2 = DEC.decode_step(params, cfg, cache, batch["tokens"][:, :1])
        assert jnp.all(jnp.isfinite(logits2)), f"{arch}: decode logits not finite"
        print(f"OK   {arch:25s} loss={float(loss):.4f} logits={logits2.shape}")
    except Exception as e:
        import traceback; traceback.print_exc()
        print(f"FAIL {arch:25s} {type(e).__name__}: {e}")
        sys.exit(1)
print("all smoke OK")
