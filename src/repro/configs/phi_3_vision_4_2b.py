"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP frontend (stub).

[hf:microsoft/Phi-3-vision-128k-instruct; hf]
Backbone only per the brief; the vision frontend is a STUB — input_specs()
provides precomputed patch embeddings (n_img_tokens x d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    n_img_tokens=576,  # CLIP ViT-L/14-336 -> 24x24 patches
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

SMOKE = ModelConfig(
    name="phi-3-vision-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    activation="swiglu",
    norm="rmsnorm",
    n_img_tokens=8,
    dtype="float32",
)
