"""MonitorRuntime — the multiplexed control plane (ROADMAP "batch reconcile").

The paper's design (§5.1, Figs. 2-3) is one controller pod per remote job;
the thread-per-CR ``ControllerPod`` mirrors it literally, which costs N
threads for N CRs.  Related systems multiplex instead — the Flux Operator
drives whole job ensembles through a single reconciler, and HPK funnels many
cloud-native workloads through one HPC-side agent — and this runtime makes
the same move: a SMALL FIXED pool of worker threads steps many jobs'
``JobProtocol`` state machines (controller.py) off a poll-deadline heap.

Semantics are identical to pod-per-CR by construction: the same protocol
object runs the same Fig.-2 submit-if-no-id and Fig.-3 monitor tick, the
config map stays the only durable state, and ``MonitorTask`` exposes the
same surface the operator already manages (``kill_pod``/``alive``/``phase``/
``error``/``exit_code``), so restart, kill, and resume flow through
unchanged.  ``kill_pod()`` still means "node failure": the task dies at its
next action boundary without flushing, and a replacement task resumes from
the config map without resubmitting.

Sharded placement adds PER-SLICE scheduling: a sliced array CR
(``spec.placement``) gets one scheduling CHAIN per placement slice on the
same deadline heap, each chain ticking only its own slice
(``JobProtocol.tick(slice_k)``).  The slice's remote round-trip runs outside
the protocol's state lock and each chain holds only its own chain lock, so
a slow resource delays exactly its own slice's cadence — a healthy slice's
ticks keep firing on schedule.  Death (kill or crash) is finalized by the
first chain to observe it, after barriering on every other chain's lock, so
no in-flight step of a dying task can write state behind a restarted
replacement's back.

What changes is the cost model: monitor threads = pool size (not CR count),
and one poll tick costs one heap pop + one (batched) status request instead
of a per-CR wakeup — see benchmarks/bridge_scale.py and docs/perf.md.

Known tradeoff: IN-STEP waits (submit retries, spec.retry backoff) block a
pool worker for their duration — only the inter-tick wait is heap-scheduled.
Workloads configuring long ``retry.backoff_seconds`` should size
``monitor_workers`` for the expected number of simultaneously-backing-off
jobs, or use ``mode="pod-per-cr"`` where one job can only ever stall its
own thread.
"""
from __future__ import annotations

import heapq
import itertools
import threading
import time
from collections import deque
from typing import (Any, Dict, FrozenSet, List, Mapping, Optional, Set,
                    Tuple, Type)

from repro.core.backends import base as B
from repro.core.controller import (ControllerPod, JobProtocol, PodKilled,
                                   TickObs, killable_sleep, make_protocol)
from repro.core.objectstore import ObjectStore
from repro.core.rest import ResourceManagerDirectory, TransportError
from repro.core.secrets import SecretStore
from repro.core.statestore import ConfigMap


class Cadence:
    """Poll-cadence policy for ONE scheduling chain: given what the last
    tick observed (a ``TickObs``, or None before the first tick), decide the
    delay until the chain's next tick.  Both protocol drivers consult it —
    the ControllerPod thread between sleeps, the MonitorTask after each
    step — so pod-per-cr and multiplexed mode pace identically."""

    def next_delay(self, obs: Optional[TickObs]) -> float:
        raise NotImplementedError

    def reset(self) -> None:
        """An out-of-band event (spec patch poke) invalidated the backoff:
        snap back to the tight interval."""


class FixedCadence(Cadence):
    """The historical baseline: every ``interval`` seconds, regardless of
    what the tick observed.  Default, and the benchmark reference point."""

    def __init__(self, interval: float):
        self.interval = interval

    def next_delay(self, obs: Optional[TickObs]) -> float:
        return self.interval


class AdaptiveCadence(Cadence):
    """Deadline arithmetic extracted from the drivers into policy: back off
    a long-quiet RUNNING chain exponentially (up to ``MAX_FACTOR`` × base),
    hold the TIGHT interval whenever a transition is expected soon (just
    submitted, mixed done/running tail, reconcile/drain in flight, slice
    unreachable — an UNKNOWN chain must notice recovery fast, so it PINS
    tight rather than backing off), and drop back to base on any observed
    state change."""

    TIGHT_FACTOR = 0.25   # "expecting a transition" interval, × base
    GROWTH = 2.0          # per-quiet-tick backoff multiplier
    MAX_FACTOR = 8.0      # backoff ceiling, × base

    def __init__(self, base: float):
        self.base = base
        self._cur = base * self.TIGHT_FACTOR

    def next_delay(self, obs: Optional[TickObs]) -> float:
        if obs is None or obs.unknown or obs.busy:
            self._cur = self.base * self.TIGHT_FACTOR
        elif obs.changed:
            self._cur = self.base
        else:
            self._cur = min(max(self._cur, self.base) * self.GROWTH,
                            self.base * self.MAX_FACTOR)
        return self._cur

    def reset(self) -> None:
        self._cur = self.base * self.TIGHT_FACTOR


class WakeupCadence(Cadence):
    """Safety-net pacing for the wakeup cadence.  Urgency rides the PUSH
    path (watcher pokes jump the deadline heap), so the timer never ticks
    tighter than the base interval — and a chain whose safety ticks keep
    coming back clean (the push path is healthy and proved nothing moved)
    stretches its net up to ``MAX_FACTOR`` × base.  At 10k CRs this is what
    keeps the heap from drowning the worker pool in no-op deadline ticks:
    the steady-state tick rate is N/(MAX_FACTOR·base), not N/base.  Any
    real observation (a change, a busy tail, an unreachable slice) or an
    out-of-band poke snaps the chain back to base."""

    GROWTH = 2.0          # per-clean-tick stretch multiplier
    MAX_FACTOR = 16.0     # safety-net ceiling, × base

    def __init__(self, base: float):
        self.base = base
        self._cur = base

    def next_delay(self, obs: Optional[TickObs]) -> float:
        if obs is None or obs.changed or obs.busy or obs.unknown:
            self._cur = self.base
        else:
            self._cur = min(max(self._cur, self.base) * self.GROWTH,
                            self.base * self.MAX_FACTOR)
        return self._cur

    def reset(self) -> None:
        self._cur = self.base


class MonitorTask:
    """One job's seat in the runtime: a virtual controller pod.

    Drop-in for ``ControllerPod`` from the operator's point of view — same
    phases, same kill/alive/join surface — but stepped by the runtime's
    worker pool instead of owning a thread.  A sliced job runs one scheduling
    chain per placement slice; chain 0 additionally owns start-up and global
    reconcile wake-ups.
    """

    def __init__(self, runtime: "MonitorRuntime", name: str,
                 configmap: ConfigMap, secrets: SecretStore,
                 objectstore: ObjectStore,
                 directory: ResourceManagerDirectory,
                 adapters: Mapping[str, Type[B.ResourceAdapter]],
                 min_sleep: float = 0.005):
        self.name = name
        self.cm = configmap
        self.min_sleep = min_sleep
        self.phase = ControllerPod.PENDING
        self.exit_code: Optional[int] = None
        self.error: str = ""
        self._runtime = runtime
        self._killed = threading.Event()
        self._done = threading.Event()
        self._started = False
        # newest heap-entry token PER CHAIN (written under the runtime's cv
        # lock): a popped entry carrying an older token is stale and is
        # dropped, so each chain has exactly ONE live scheduling sequence
        # however many times kill_pod()/poke() push extra wake-up entries
        self._sched_tokens: Dict[int, int] = {}
        # chains with a pending out-of-band wake-up (spec-patch poke on
        # chain 0, watcher event delivery on any chain); a step consumes its
        # chain's entry so a poke arriving mid-step is applied by an
        # immediate follow-up tick, never a full poll later.  N pokes inside
        # one tick window collapse onto ONE pending entry (plus the heap's
        # token supersede) — that is the poke-storm coalescing guarantee
        self._poke_pending: Set[int] = set()
        # earliest unconsumed poke time per chain, popped when the chain
        # next steps: the runtime's wakeup-latency (event -> evaluation)
        # histogram is built from these stamps
        self._poke_stamp: Dict[int, float] = {}
        self._poke_mu = threading.Lock()
        # one lock per chain: serializes steps of the SAME slice (a
        # kill_pod() wake-up racing that slice's running tick) while letting
        # different slices of one job step concurrently — the whole point of
        # per-slice scheduling
        self._chain_locks: Dict[int, threading.Lock] = {0: threading.Lock()}
        # guards the lock TABLE itself: slice failover can append replacement
        # slices (and thus chains) mid-flight, racing the death barrier's
        # table snapshot
        self._chains_mu = threading.Lock()
        # single-finalizer guard for the death barrier (see _die)
        self._dying = threading.Lock()
        # one cadence policy per chain (created lazily after start() has
        # parsed the cm's cadence mode): each slice backs off or tightens on
        # ITS OWN observations, independent of its siblings
        self._cadences: Dict[int, Cadence] = {}
        self._proto = make_protocol(
            name, configmap, secrets, objectstore, directory, adapters,
            checkpoint=self._checkpoint, sleep=self._sleep,
            min_sleep=min_sleep)

    # -- the ControllerPod surface the operator manages -------------------

    def kill_pod(self) -> None:
        """Simulate pod/node failure: die at the next action boundary,
        nothing flushed.  Rescheduled at the FRONT of the heap so the death
        is observed (and the operator can restart) even when a backlog of
        overdue poll deadlines is queued ahead."""
        self._killed.set()
        self._runtime.schedule(self, 0.0, 0, front=True)

    def poke(self) -> None:
        """A spec patch landed in the config map: pull the next tick forward
        so the reconcile delta is applied now, not a poll period from now.
        Reconcile is global, so chain 0 carries the wake-up."""
        self.poke_chain(0)

    def poke_chain(self, chain: int) -> None:
        """Out-of-band wake-up for ONE chain (spec-patch poke, watcher event
        delivery).  The pending entry survives a poke that races a RUNNING
        step (whose own reschedule would otherwise supersede the immediate
        wake-up): the in-flight step consumes it by returning a zero delay.
        Repeated pokes on a chain that already has one pending coalesce —
        the heap token supersede plus the pending-set membership guarantee
        at most one extra evaluation per storm."""
        if self._done.is_set():
            return
        with self._poke_mu:
            coalesced = chain in self._poke_pending
            self._poke_pending.add(chain)
            self._poke_stamp.setdefault(chain, time.time())
        self._runtime._count_poke(coalesced)
        if coalesced:
            return  # an undelivered wake-up already covers this chain
        # the wake-up overrides any backed-off deadline RIGHT NOW: the
        # zero-delay entry supersedes the old one on the heap, and the
        # chain's cadence snaps back to tight for the follow-up work
        cad = self._cadences.get(chain)
        if cad is not None:
            cad.reset()
        # FRONT of the heap, not "now": under load the heap carries a
        # backlog of overdue speculative deadline ticks, and a poke is
        # KNOWN work — it must not wait its turn behind them
        self._runtime.schedule(self, 0.0, chain, front=True)

    def deliver_events(self, chain: int, version: int,
                       events: Optional[List[Tuple[str, str]]]) -> None:
        """Watcher push: hand an event payload (or an unknown-scope marker,
        ``events=None``) to the protocol and pull the chain's next tick
        forward.  Runs on the endpoint's watcher thread."""
        if self._done.is_set():
            return
        self._proto.deliver_events(chain, version, events)
        self.poke_chain(chain)

    def watch_registration(self, chain: int
                           ) -> Optional[Tuple[str, List[str], Any]]:
        """The subscription this chain wants from its endpoint's watcher —
        ``(url, remote ids, adapter)`` — or None when the chain does not
        participate (not wakeup cadence, task finished/not started,
        unwatchable dialect, LOST slice).  Re-consulted by the runtime after
        every step so the index tracks submits/retries/failover."""
        if self._done.is_set() or not self._started:
            return None
        return self._proto.watch_ids(chain)

    def alive(self) -> bool:
        return not self._done.is_set()

    def join(self, timeout: Optional[float] = None) -> None:
        self._done.wait(timeout)

    # -- protocol hooks ----------------------------------------------------

    def _checkpoint(self) -> None:
        if self._killed.is_set():
            raise PodKilled(self.name)

    def _sleep(self, seconds: float) -> None:
        """In-step backoff (submit/retry): blocks one pool worker, bounded
        by the spec's backoff — the inter-tick wait is the heap's job."""
        killable_sleep(self._killed, self.name, seconds, self.min_sleep)

    # -- stepping (runtime workers only) -----------------------------------

    def _step(self, chain: int) -> Optional[float]:
        """Advance the protocol by one action on ``chain`` (= slice index).
        Returns the delay until the chain's next step, or None when the
        chain is finished for good."""
        lock = self._chain_locks.get(chain)
        if lock is None:
            return None  # chain of a task generation that no longer exists
        if not lock.acquire(blocking=False):
            # this chain is mid-step on another worker (a kill_pod() wake-up
            # racing a running tick): retry shortly rather than stepping the
            # same slice concurrently
            return self.min_sleep
        try:
            if self._done.is_set():
                return None  # e.g. the kill_pod() wake-up entry of a dead task
            # a poke that landed before this point is satisfied by this very
            # step (the operator flushes the config map BEFORE poking, and
            # the step reads it fresh); one that lands mid-step re-raises the
            # flag and is consumed below.  The poke's stamp feeds the
            # runtime's wakeup-latency histogram: event -> evaluation start
            with self._poke_mu:
                self._poke_pending.discard(chain)
                stamp = self._poke_stamp.pop(chain, None)
            if stamp is not None:
                self._runtime._record_wakeup(time.time() - stamp)
            try:
                self._checkpoint()
                if not self._started:
                    self._started = True
                    self.phase = ControllerPod.RUNNING_PHASE
                    if not self._proto.start():
                        self._finish()
                        return None
                    # sliced job: spawn one scheduling chain per additional
                    # slice.  EVERY lock is registered before ANY chain is
                    # scheduled — a freshly-scheduled chain can die (kill
                    # racing start-up) and its death barrier must see the
                    # complete, no-longer-mutated lock table
                    n = self._proto.slice_count()
                    with self._chains_mu:
                        for k in range(1, n):
                            self._chain_locks[k] = threading.Lock()
                    for k in range(1, n):
                        self._runtime.schedule(self, 0.0, k)
                    return self._next_delay(chain)
                if self._proto.tick(chain):
                    self._finish()
                    return None
                # slice failover may have appended replacement slices during
                # this tick: give each a chain of its own...
                self._ensure_chains()
                # ...and retire this chain for good when its slice is LOST
                # (chain 0 never retires — it owns the global duties)
                if self._proto.chain_retired(chain):
                    return None
                return self._next_delay(chain)
            except PodKilled:
                return self._die(chain)
            except Exception as e:  # task crash — the operator restarts it
                self.error = f"{type(e).__name__}: {e}"
                return self._die(chain)
        finally:
            lock.release()

    def _ensure_chains(self) -> None:
        """Register (and schedule) a chain for any slice the protocol grew
        since start() — slice failover appends replacement slices mid-flight.
        Every new lock is in the table before its chain is scheduled, so the
        death barrier can never miss a running chain."""
        n = self._proto.slice_count()
        fresh = []
        with self._chains_mu:
            for k in range(n):
                if k not in self._chain_locks:
                    self._chain_locks[k] = threading.Lock()
                    fresh.append(k)
        for k in fresh:
            self._runtime.schedule(self, 0.0, k)

    def _die(self, chain: int) -> Optional[float]:
        """Finalize a kill/crash EXACTLY ONCE, barriering on every other
        chain's lock (held while flipping the phase) so no in-flight step of
        this task can still write config-map state once the operator sees
        the task dead and restarts a replacement."""
        self._killed.set()  # crash path: make other chains die at checkpoints
        if not self._dying.acquire(blocking=False):
            return None  # another chain is finalizing the death
        with self._chains_mu:
            table = sorted(self._chain_locks.items())
        others = [l for k, l in table if k != chain]
        for l in others:
            l.acquire()
        try:
            self.phase = ControllerPod.KILLED_PHASE
            self._done.set()
        finally:
            for l in others:
                l.release()
        return None

    def _next_delay(self, chain: int = 0) -> float:
        """Poll delay for the chain's next step, from its cadence policy —
        zero when a poke or a kill arrived mid-step (their immediate wake-up
        entries are superseded by this step's own reschedule, so the zero
        delay stands in for them): the patch is applied, or PodKilled
        observed, immediately."""
        cad = self._cadences.get(chain)
        if cad is None:
            cad = self._cadences[chain] = self._proto.make_cadence()
        with self._poke_mu:
            pending = chain in self._poke_pending
            if pending:
                # keep the stamp: latency runs until the step that actually
                # evaluates this poke starts
                self._poke_pending.discard(chain)
        if self._killed.is_set() or pending:
            cad.reset()
            return 0.0
        return cad.next_delay(self._proto.observation(chain))

    def _finish(self) -> None:
        self.exit_code = self._proto.exit_code
        self.phase = (ControllerPod.SUCCEEDED if self.exit_code == 0
                      else ControllerPod.FAILED_PHASE)
        self._done.set()


class MonitorRuntime:
    """Fixed worker pool + poll-deadline heap driving many MonitorTasks
    (one heap entry chain per placement slice of each task).

    Wakeup cadence adds a PUSH path on top of the heap: per endpoint, ONE
    dedicated watcher thread long-polls the events route and pokes exactly
    the chains subscribed to the ids that changed (the endpoint->chain
    subscription index below), instead of every chain waiting out its
    deadline.  The heap keeps running underneath as the safety net — a
    subscription-registration race or a watcher blackout degrades to
    deadline-paced polling, never to a missed transition."""

    # watcher long-poll window: short enough that stop() is responsive,
    # long enough that an idle endpoint costs ~2 requests/s, not a busy loop
    WATCH_WAIT = 0.5
    # back-off before retrying a watcher whose transport failed (blackout):
    # deadline polling covers the gap, so this only bounds reconnect lag
    WATCH_RETRY = 0.2

    def __init__(self, workers: int = 4, name: str = "bridge-monitor"):
        self.workers = workers
        self.name = name
        self._heap: List[Tuple[float, int, MonitorTask, int, int]] = []
        self._seq = itertools.count()
        self._cv = threading.Condition()
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        # endpoint->chain subscription index: url -> job id -> {(task, chain)}
        # plus each chain's last-registered (url, ids) so re-registration
        # after every step is a cheap no-op when nothing moved
        self._subs_mu = threading.Lock()
        self._subs: Dict[str, Dict[str, Set[Tuple[MonitorTask, int]]]] = {}
        self._registered: Dict[MonitorTask,
                               Dict[int, Tuple[str, FrozenSet[str]]]] = {}
        # channels we started a watcher on (one per endpoint, ever)
        self._watch_channels: Dict[str, Any] = {}
        # observability counters (stats()) — benchmarks and tests read these
        # instead of reaching into private state
        self._stats_mu = threading.Lock()
        self._stale_drops = 0
        self._pokes_delivered = 0
        self._pokes_coalesced = 0
        self._wakeup_samples: "deque[float]" = deque(maxlen=4096)

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "MonitorRuntime":
        if self._threads:
            return self
        self._stop.clear()
        for i in range(self.workers):
            t = threading.Thread(target=self._worker, daemon=True,
                                 name=f"{self.name}-w{i}")
            t.start()
            self._threads.append(t)
        return self

    def stop(self, timeout: float = 2.0) -> None:
        self._stop.set()
        with self._cv:
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=timeout)
        self._threads = []
        with self._subs_mu:
            channels = list(self._watch_channels.values())
            self._watch_channels.clear()
            self._subs.clear()
            self._registered.clear()
        for ch in channels:
            ch.stop_watcher(timeout=timeout)

    def thread_count(self) -> int:
        """Live monitor threads — pool size, independent of task count."""
        return sum(1 for t in self._threads if t.is_alive())

    # -- task management ---------------------------------------------------

    def spawn(self, name: str, configmap: ConfigMap, secrets: SecretStore,
              objectstore: ObjectStore, directory: ResourceManagerDirectory,
              adapters: Mapping[str, Type[B.ResourceAdapter]],
              min_sleep: float = 0.005) -> MonitorTask:
        """Register one job with the runtime; its first step (Fig. 2
        connect+submit) is due immediately."""
        task = MonitorTask(self, name, configmap, secrets, objectstore,
                           directory, adapters, min_sleep=min_sleep)
        self.schedule(task, 0.0, 0)
        return task

    def schedule(self, task: MonitorTask, delay: float, chain: int = 0,
                 only_if_token: Optional[int] = None,
                 front: bool = False) -> None:
        """(Re)schedule one of a task's chains, SUPERSEDING any entry that
        chain still has in the heap: the token stamped here invalidates
        older entries, which the workers drop on pop — one chain, one live
        sequence.  ``only_if_token`` makes the supersede conditional: the
        worker's own post-step reschedule passes the token it popped, so a
        poke that raced in DURING the step keeps its immediate entry instead
        of being pushed out a full poll interval.  ``front`` puts the entry
        at deadline ZERO — ahead of every overdue deadline tick already in
        the heap — for out-of-band wake-ups (pokes, kills) that carry known
        work and must preempt speculative polling under backlog."""
        with self._cv:
            cur = task._sched_tokens.get(chain, 0)
            if only_if_token is not None and cur != only_if_token:
                return  # a newer (immediate) entry raced in: let it stand
            token = cur + 1
            task._sched_tokens[chain] = token
            deadline = 0.0 if front else time.time() + delay
            heapq.heappush(self._heap,
                           (deadline, next(self._seq), task, chain, token))
            self._cv.notify()

    # -- observability counters (stats()) -----------------------------------

    def _count_poke(self, coalesced: bool) -> None:
        with self._stats_mu:
            self._pokes_delivered += 1
            if coalesced:
                self._pokes_coalesced += 1

    def _record_wakeup(self, latency: float) -> None:
        with self._stats_mu:
            self._wakeup_samples.append(latency)

    def stats(self) -> Dict[str, Any]:
        """Control-plane observability snapshot: heap depth, stale-token
        drops, poke delivery/coalescing counters, the wakeup-latency
        (poke -> evaluation start) histogram, and the watcher/subscription
        footprint.  The supported surface for benchmarks and tests."""
        with self._cv:
            heap_depth = len(self._heap)
        with self._subs_mu:
            subscribed_ids = sum(len(m) for m in self._subs.values())
            channels = list(self._watch_channels.values())
        with self._stats_mu:
            lat = sorted(self._wakeup_samples)
            stats = {
                "heap_depth": heap_depth,
                "stale_drops": self._stale_drops,
                "pokes_delivered": self._pokes_delivered,
                "pokes_coalesced": self._pokes_coalesced,
                "wakeup_samples": len(lat),
                "wakeup_latency_p50_s": lat[len(lat) // 2] if lat else None,
                "wakeup_latency_p99_s": (
                    lat[min(int(len(lat) * 0.99), len(lat) - 1)]
                    if lat else None),
            }
        stats["watcher_threads"] = sum(1 for ch in channels
                                       if ch.watcher_alive)
        stats["subscribed_ids"] = subscribed_ids
        return stats

    # -- endpoint watchers (wakeup cadence) ----------------------------------

    def _sync_subscriptions(self, task: MonitorTask, chain: int) -> None:
        """Bring the subscription index in line with what ``(task, chain)``
        wants AFTER its latest step: register fresh ids, drop superseded
        ones, purge everything once the task dies.  Called by the worker
        that stepped the chain, so registration always chases the newest
        submit/retry/failover state."""
        reg = task.watch_registration(chain)
        with self._subs_mu:
            chains = self._registered.get(task)
            if not task.alive():
                if chains:
                    for k, old in chains.items():
                        self._drop_subscription((task, k), old)
                self._registered.pop(task, None)
                return
            new = None if reg is None else (reg[0], frozenset(reg[1]))
            old = chains.get(chain) if chains else None
            if old == new:
                return
            if old is not None:
                self._drop_subscription((task, chain), old)
                del chains[chain]
                if not chains:
                    del self._registered[task]
            if new is not None:
                self._registered.setdefault(task, {})[chain] = new
                jmap = self._subs.setdefault(new[0], {})
                for jid in new[1]:
                    jmap.setdefault(jid, set()).add((task, chain))
        if reg is not None:
            self._ensure_watcher(reg[0], reg[2])

    def _drop_subscription(self, key: Tuple[MonitorTask, int],
                           old: Tuple[str, FrozenSet[str]]) -> None:
        """Remove one chain's registration (caller holds _subs_mu)."""
        jmap = self._subs.get(old[0])
        if jmap is None:
            return
        for jid in old[1]:
            keys = jmap.get(jid)
            if keys is not None:
                keys.discard(key)
                if not keys:
                    del jmap[jid]
        if not jmap:
            del self._subs[old[0]]

    def _ensure_watcher(self, url: str, adapter: Any) -> None:
        """Guarantee the endpoint has its ONE watcher thread running (idle
        watchers that died of a stopped runtime restart lazily here)."""
        channel = getattr(adapter.client, "channel", None)
        if channel is None:
            return
        with self._subs_mu:
            self._watch_channels[url] = channel
        channel.ensure_watcher(
            lambda stop: self._watch_loop(url, adapter, stop),
            name=f"{self.name}-watch:{url}")

    def _watch_loop(self, url: str, adapter: Any, stop: threading.Event) -> None:
        """The endpoint's dedicated watcher: one long-poll in flight,
        forever.  On a version bump it pokes exactly the subscribed chains
        whose ids changed; on transport failure it backs off and retries
        while the deadline heap keeps polling underneath.  Every successful
        cycle stamps the channel's heartbeat — the controllers' safety-net
        ticks consult it (``watch_push_healthy``) to decide whether push
        delivery can be trusted or deadline fetching must take over."""
        since = -1
        channel = getattr(adapter.client, "channel", None)
        while not (stop.is_set() or self._stop.is_set()):
            try:
                if since < 0:
                    # seed the watermark: everything before the watcher
                    # existed is the subscribers' own (deadline-poll) duty
                    since = adapter.watch_events(since=-1)
                    if channel is not None:
                        channel.watch_heartbeat = time.time()
                    continue
                r = adapter.watch_events_ids(since=since, wait=self.WATCH_WAIT)
            except (TransportError, B.SubmitError):
                stop.wait(self.WATCH_RETRY)
                continue
            if channel is not None:
                channel.watch_heartbeat = time.time()
            if r is None:
                continue  # 204: nothing changed inside the window
            version, events = r
            self._dispatch_events(url, version, events)
            since = version

    def _dispatch_events(self, url: str, version: int,
                         events: Optional[List[Tuple[str, str]]]) -> None:
        """Fan an event payload out to the subscribed chains.  ``events=
        None`` (enumeration unknown: ring overflow) pokes EVERY chain on the
        endpoint — each re-polls from its own watermark."""
        targets: Dict[Tuple[MonitorTask, int],
                      Optional[List[Tuple[str, str]]]] = {}
        with self._subs_mu:
            jmap = self._subs.get(url)
            if not jmap:
                return
            if events is None:
                for keys in jmap.values():
                    for key in keys:
                        targets[key] = None
            else:
                for jid, state in events:
                    for key in jmap.get(jid, ()):
                        lst = targets.setdefault(key, [])
                        if lst is not None:
                            lst.append((jid, state))
        for (task, chain), evs in targets.items():
            task.deliver_events(chain, version, evs)

    # -- workers -----------------------------------------------------------

    def _worker(self) -> None:
        while True:
            with self._cv:
                task = chain = None
                while not self._stop.is_set():
                    now = time.time()
                    if self._heap and self._heap[0][0] <= now:
                        _, _, task, chain, token = heapq.heappop(self._heap)
                        if token != task._sched_tokens.get(chain):
                            task = None
                            with self._stats_mu:
                                self._stale_drops += 1
                            continue  # superseded by a newer entry
                        break
                    wait = (min(self._heap[0][0] - now, 0.2)
                            if self._heap else 0.2)
                    self._cv.wait(wait)
                if task is None:
                    return  # stopped
            delay = task._step(chain)
            self._sync_subscriptions(task, chain)
            if delay is not None:
                # a zero delay stands in for an out-of-band wake-up consumed
                # mid-step (poke, kill): it keeps front-of-heap priority
                self.schedule(task, delay, chain, only_if_token=token,
                              front=(delay == 0.0))
