"""Roofline table generator: reads artifacts/dryrun/*/*.json (written by
repro.launch.dryrun) and emits the §Roofline markdown + CSV.

  PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
      [--mesh single|multi] [--csv artifacts/roofline.csv]
"""
import argparse
import glob
import json
import os
from typing import Dict, List


def load(dir_: str, mesh: str) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, mesh, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def matmul_flops_ratio(r: Dict) -> float:
    """useful ratio with gather-only embedding params excluded from 6ND
    (6ND overcounts archs whose params are dominated by the input-embedding
    table — gemma's 256k vocab at d=2048 is a GATHER, not a matmul)."""
    from repro.configs.base import get_config

    cfg = get_config(r["arch"])
    n = r["n_active_params"]
    if not cfg.tie_embeddings:
        n -= cfg.vocab * cfg.d_model  # input table: gather, no flops
    mult = 6.0 if r["kind"] == "train" else 2.0
    if r["kind"] == "train" or r["kind"] == "prefill":
        tokens = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768}.get(
            r["shape"], 0)
    else:
        tokens = {"decode_32k": 128, "long_500k": 1}.get(r["shape"], 0)
    if not tokens or not r["hlo_flops_per_dev"]:
        return 0.0
    return mult * n * tokens / r["n_chips"] / r["hlo_flops_per_dev"]


def fmt_row(r: Dict) -> Dict[str, str]:
    rf = r["roofline"]
    mem = r.get("memory", {})
    return {
        "arch": r["arch"], "shape": r["shape"], "strategy": r["strategy"],
        "compute_s": f"{rf['compute_s']:.3e}",
        "memory_s": f"{rf['memory_s']:.3e}",
        "collective_s": f"{rf['collective_s']:.3e}",
        "dominant": rf["dominant"].replace("_s", ""),
        "roofline_frac": f"{rf['roofline_fraction']:.3f}",
        "useful_ratio": (f"{r['useful_flops_ratio']:.3f}"
                         if r.get("useful_flops_ratio") else "-"),
        "useful_mm": f"{matmul_flops_ratio(r):.3f}",
        "peak_GiB": f"{mem.get('peak_bytes_per_device', 0)/2**30:.1f}",
        "params_B": f"{r['n_params']/1e9:.2f}",
    }


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="artifacts/dryrun")
    p.add_argument("--mesh", default="single", choices=["single", "multi"])
    p.add_argument("--csv", default="")
    args = p.parse_args()
    recs = load(args.dir, args.mesh)
    if not recs:
        raise SystemExit(f"no dry-run artifacts in {args.dir}/{args.mesh}")
    rows = [fmt_row(r) for r in recs]
    cols = list(rows[0])
    print("| " + " | ".join(cols) + " |")
    print("|" + "---|" * len(cols))
    for row in rows:
        print("| " + " | ".join(row[c] for c in cols) + " |")
    if args.csv:
        os.makedirs(os.path.dirname(args.csv), exist_ok=True)
        with open(args.csv, "w") as f:
            f.write(",".join(cols) + "\n")
            for row in rows:
                f.write(",".join(row[c] for c in cols) + "\n")
        print(f"# wrote {args.csv}")


if __name__ == "__main__":
    main()
