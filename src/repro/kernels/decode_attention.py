"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

The decode bottleneck is pure HBM bandwidth (read M keys+values per head per
token).  The kernel streams (block_m x D) cache tiles through VMEM with the
same online-softmax scratch trick as flash attention; all G query heads of a
kv group share each streamed tile (GQA's arithmetic-intensity win, expressed
as a (G x block_m) score tile that keeps the MXU busy instead of a
vector-only dot).

Grid: (B, Hkv, nm), nm innermost/sequential.  Valid-length masking reads a
scalar per batch row from SMEM (scalar prefetch idiom).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import mosaic_params, resolve_interpret

NEG_INF = -1e30
LANES = 128


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_m: int, n_m: int):
    mi = pl.program_id(2)

    @pl.when(mi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)           # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)           # (bm, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G,bm)

    valid = len_ref[0]
    cols = mi * block_m + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(cols < valid, s, NEG_INF)

    m_prev = m_scr[:, :1]
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc

    @pl.when(mi == n_m - 1)
    def _finish():
        denom = jnp.where(l_scr[:, :1] == 0.0, 1.0, l_scr[:, :1])
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention_bhd(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array, *, block_m: int = 512,
                         interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,Hq,D); k,v: (B,Hkv,M,D); lengths: (B,) int32 -> (B,Hq,D).

    M must be a multiple of block_m (ops.py pads; padding is masked by
    ``lengths``).  ``interpret=None`` auto-selects per backend."""
    interpret = resolve_interpret(interpret)
    b, hq, d = q.shape
    hkv, m = k.shape[1], k.shape[2]
    group = hq // hkv
    block_m = min(block_m, m)
    if m % block_m:
        raise ValueError(f"cache len {m} % block_m {block_m}")
    n_m = m // block_m
    qg = q.reshape(b, hkv, group, d)

    kernel = functools.partial(_decode_kernel, scale=1.0 / (d ** 0.5),
                               block_m=block_m, n_m=n_m)

    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, n_m),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h, mi: (b_,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, d), lambda b_, h, mi: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_m, d), lambda b_, h, mi: (b_, h, mi, 0)),
            pl.BlockSpec((1, 1, block_m, d), lambda b_, h, mi: (b_, h, mi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda b_, h, mi: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        interpret=interpret,
        **mosaic_params(dimension_semantics=("parallel", "parallel",
                                             "arbitrary")),
    )(lengths, qg, k, v)
    return out.reshape(b, hq, d)
