"""Pallas TPU kernels for the data plane's compute hot-spots.

Each kernel ships three pieces: <name>.py (pl.pallas_call + explicit
BlockSpec VMEM tiling), ops.py (jit'd layout/padding wrapper used by the
model code), ref.py (pure-jnp oracle for the allclose sweeps in
tests/test_kernels.py).  CPU validation runs interpret=True; on TPU the
same calls lower through Mosaic.

  flash_attention.py  — blockwise online-softmax causal attention (GQA via
                        k/v index_map; lane-replicated m/l scratch)
  decode_attention.py — flash-decode over a long KV cache (SMEM lengths,
                        G x block_m MXU tiles)
  ssm_scan.py         — chunked selective scan + the discretization-FUSED
                        variant (dA/dBx built in VMEM, ~30x less HBM read)
"""
from repro.kernels import ops, ref
