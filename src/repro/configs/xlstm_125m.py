"""xlstm-125m [ssm]: sLSTM + mLSTM blocks.  [arXiv:2405.04517; unverified]

12L d_model=768 4H vocab=50304, d_ff=0 (mixers carry their own projections).
Every 4th block is sLSTM (xLSTM[m:s] interleave); others mLSTM.
Sub-quadratic: eligible for long_500k (O(1) recurrent state per token).
Uses unrolled layers (12 heterogeneous blocks; compile cost is fine).
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50_304,
    norm="layernorm",
    xlstm=XLSTMConfig(slstm_every=4, proj_factor=2.0),
    tie_embeddings=True,
    layer_impl="unroll",
    source="arXiv:2405.04517",
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=2,
    d_model=32,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab=256,
    norm="layernorm",
    xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0),
    tie_embeddings=True,
    layer_impl="unroll",
    dtype="float32",
)
