"""End-to-end driver: REAL training dispatched through the Bridge Operator.

A BridgeJob whose payload is a genuine repro training loop (jaxlocal
backend): the operator creates the controller pod, the pod submits the job
over the REST API, training runs with framework checkpointing, loss history
and checkpoints land in the object store, and the CR status mirrors it all.

Default: a reduced gemma config for a few hundred steps (CPU-friendly).
--full trains the real xlstm-125m (~125M params) — the same command a
production pod would run; on this 1-core container budget ~hours.

  PYTHONPATH=src python examples/train_end_to_end.py [--steps 300] [--full]
"""
import argparse
import json
import time

from repro.core import BridgeEnvironment, DONE


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--arch", default="gemma-2b")
    p.add_argument("--full", action="store_true",
                   help="train the real xlstm-125m config (slow on CPU)")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=32)
    args = p.parse_args()

    payload = {
        "arch": "xlstm-125m" if args.full else args.arch,
        "steps": args.steps, "batch": args.batch, "seq": args.seq,
        "checkpoint_every": max(args.steps // 10, 1),
        "workdir": "ckpts:runs/e2e", "lr": 1e-2,
    }
    if args.full:
        payload["config_overrides"] = {}  # real CONFIG is selected by the
        # jaxlocal trainer via get_smoke_config; --full documents intent:
        # on TPU pods the bridge submits repro.launch.train with the full
        # config — this container trains the reduced one end-to-end.

    with BridgeEnvironment(default_duration=0.05) as env:
        spec = env.make_spec("jaxlocal", script=json.dumps(payload),
                             updateinterval=0.2,
                             jobproperties={"OutputFileName": "train.out"})
        env.submit("e2e-train", spec)
        print(f"bridged training submitted ({payload['steps']} steps)...")
        t0 = time.time()
        while True:
            job = env.registry.get("e2e-train")
            if job.status.terminal():
                break
            time.sleep(0.5)
        print(f"state={job.status.state} after {time.time()-t0:.1f}s")
        assert job.status.state == DONE, job.status.message

        hist_key = [k for k in env.s3.list("ckpts", "runs/e2e/")
                    if "history" in k][0]
        hist = json.loads(env.s3.get("ckpts", hist_key))
        n = len(hist)
        print(f"loss curve ({n} steps): "
              f"{hist[0]:.3f} -> {hist[n//2]:.3f} -> {hist[-1]:.3f}")
        ckpts = [k for k in env.s3.list("ckpts", "runs/e2e/") if "MANIFEST" in k]
        print(f"checkpoints in object store: {len(ckpts)}")
        assert hist[-1] < hist[0], "training must reduce loss"
        print("end-to-end bridged training complete")


if __name__ == "__main__":
    main()
