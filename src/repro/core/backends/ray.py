"""Simulated Ray Jobs API (job submission SDK surface, paper §2.1).

Dialect notes: submission ids look like ``raysubmit_XXXX``; the client may
supply its own submission_id (Ray semantics — used here to demonstrate
idempotent resubmission); states PENDING/RUNNING/SUCCEEDED/STOPPED/FAILED.
"""
from __future__ import annotations

import base64
from typing import Any, Dict, Optional

from repro.core.backends import base as B
from repro.core.rest import FaultProfile, HttpResponse, RestServer

_STATE_TO_RAY = {
    B.QUEUED: "PENDING",
    B.RUNNING: "RUNNING",
    B.COMPLETED: "SUCCEEDED",
    B.FAILED: "FAILED",
    B.CANCELLED: "STOPPED",
}
_RAY_TO_STATE = {v: k for k, v in _STATE_TO_RAY.items()}


def make_server(cluster: B.SimulatedCluster, token: str = "",
                fault: FaultProfile = None) -> RestServer:
    srv = RestServer(token=token, fault=fault)
    by_submission: Dict[str, str] = {}  # submission_id -> cluster job id

    def submit(_groups, body) -> HttpResponse:
        body = body or {}
        if not body.get("entrypoint"):
            return HttpResponse(400, {"error": "entrypoint required"})
        sid = body.get("submission_id", "")
        if sid and sid in by_submission:  # idempotent resubmission
            return HttpResponse(200, {"submission_id": sid})
        job = cluster.submit(body["entrypoint"],
                             body.get("runtime_env", {}) | body.get("metadata", {}),
                             body.get("params", {}))
        sid = sid or f"raysubmit_{job.id}"
        by_submission[sid] = job.id
        return HttpResponse(200, {"submission_id": sid})

    def _job_for(sid: str):
        jid = by_submission.get(sid)
        return cluster.get(jid) if jid else None

    def jobinfo(groups, _body) -> HttpResponse:
        job = _job_for(groups["sid"])
        if job is None:
            return HttpResponse(404, {"error": "submission not found"})
        return HttpResponse(200, {
            "submission_id": groups["sid"], "status": _STATE_TO_RAY[job.state],
            "start_time": job.start_time, "end_time": job.end_time,
            "message": job.reason,
        })

    def stop(groups, _body) -> HttpResponse:
        job = _job_for(groups["sid"])
        if job is None:
            return HttpResponse(404, {})
        cluster.cancel(job.id)
        return HttpResponse(200, {"stopped": True})

    def logs(groups, _body) -> HttpResponse:
        job = _job_for(groups["sid"])
        if job is None:
            return HttpResponse(404, {})
        blob = b"".join(job.outputs.values())
        return HttpResponse(200, {"logs": base64.b64encode(blob).decode()})

    def load(_groups, _body) -> HttpResponse:
        return HttpResponse(200, cluster.queue_load())

    srv.route("POST", "/api/jobs/", submit)
    srv.route("GET", "/api/jobs/{sid}", jobinfo)
    srv.route("POST", "/api/jobs/{sid}/stop", stop)
    srv.route("GET", "/api/jobs/{sid}/logs", logs)
    srv.route("GET", "/api/cluster_status", load)
    return srv


class RayAdapter(B.ResourceAdapter):
    image = "raypod"
    # Ray Jobs expose logs, not arbitrary files; no native arrays, and the
    # Jobs API has no multi-id status endpoint (no BATCH_STATUS — the
    # monitor falls back to per-id polling)
    capabilities = frozenset({
        B.Capability.CANCEL, B.Capability.CANCEL_QUEUED,
        B.Capability.LOGS, B.Capability.QUEUE_LOAD,
    })

    def __init__(self, client, submission_id: str = "") -> None:
        super().__init__(client)
        self.submission_id = submission_id  # deterministic id => idempotent submit

    def submit(self, script, properties, params) -> str:
        body = {"entrypoint": script, "runtime_env": dict(properties or {}),
                "params": dict(params or {})}
        if self.submission_id:
            body["submission_id"] = self.submission_id
        r = self.client.post("/api/jobs/", body)
        if not r.ok:
            raise B.SubmitError(f"ray submit: HTTP {r.status} {r.json}")
        return r.json["submission_id"]

    def status(self, job_id: str) -> Dict[str, Any]:
        r = self.client.get(f"/api/jobs/{job_id}")
        if r.status == 404:
            return {"state": B.FAILED, "reason": "submission not found"}
        if not r.ok:
            raise B.SubmitError(f"ray status: HTTP {r.status}")
        j = r.json
        return {"state": _RAY_TO_STATE.get(j["status"], B.FAILED),
                "start_time": j.get("start_time"), "end_time": j.get("end_time"),
                "reason": j.get("message", "")}

    def cancel(self, job_id: str) -> None:
        self.client.post(f"/api/jobs/{job_id}/stop")

    def download_logs(self, job_id: str) -> Optional[bytes]:
        r = self.client.get(f"/api/jobs/{job_id}/logs")
        if not r.ok:
            return None
        return base64.b64decode(r.json["logs"])

    def queue_load(self) -> Optional[Dict[str, int]]:
        r = self.client.get("/api/cluster_status")
        if not r.ok:
            return None
        return {k: r.json[k] for k in ("queued", "running", "slots")}
