"""Simulated IBM Quantum Runtime service.

Dialect notes (paper §2.1): "technically this is not a resource manager" but
the API provides the same verbs.  Idiom: program + params submission returns
an opaque job id; results are pushed to OBJECT STORAGE on completion (the
bridge downloads from there, not from the service).
"""
from __future__ import annotations

import json
from typing import Any, Dict, Optional

from repro.core.backends import base as B
from repro.core.objectstore import ObjectStore
from repro.core.rest import FaultProfile, HttpResponse, RestServer

_STATE_TO_Q = {
    B.QUEUED: "Queued",
    B.RUNNING: "Running",
    B.COMPLETED: "Completed",
    B.FAILED: "Failed",
    B.CANCELLED: "Cancelled",
}
_Q_TO_STATE = {v: k for k, v in _STATE_TO_Q.items()}


def quantum_payload(store: ObjectStore, bucket: str) -> B.Payload:
    """Payload that uploads a result object to S3 on completion (the
    quantum-service idiom: results land in object storage)."""

    def run(job: B.ClusterJob, cluster: B.SimulatedCluster) -> int:
        code = B.sleep_payload(job, cluster)
        if code == 0:
            result = {"job_id": job.id, "quasi_dists": [{"0": 0.5, "1": 0.5}],
                      "shots": int(job.properties.get("shots", "1024"))}
            store.put(bucket, f"results/{job.id}.json", json.dumps(result).encode())
            job.outputs["result_ref"] = f"{bucket}:results/{job.id}.json".encode()
        return code

    return run


def make_server(cluster: B.SimulatedCluster, token: str = "",
                fault: FaultProfile = None) -> RestServer:
    srv = RestServer(token=token, fault=fault)

    def submit(_groups, body) -> HttpResponse:
        body = body or {}
        if not body.get("program"):
            return HttpResponse(400, {"errors": [{"message": "program required"}]})
        job = cluster.submit(body["program"], body.get("backend_options", {}),
                             body.get("params", {}))
        return HttpResponse(200, {"id": f"q-{job.id}"})

    def jobinfo(groups, _body) -> HttpResponse:
        job = cluster.get(groups["id"].replace("q-", "", 1))
        if job is None:
            return HttpResponse(404, {"errors": [{"message": "job not found"}]})
        out = {"id": f"q-{job.id}", "status": _STATE_TO_Q[job.state],
               "created": job.submit_time, "ended": job.end_time,
               "reason": job.reason}
        if "result_ref" in job.outputs:
            out["results_location"] = job.outputs["result_ref"].decode()
        return HttpResponse(200, out)

    def cancel(groups, _body) -> HttpResponse:
        ok = cluster.cancel(groups["id"].replace("q-", "", 1))
        return HttpResponse(204 if ok else 404, {})

    def load(_groups, _body) -> HttpResponse:
        q = cluster.queue_load()
        return HttpResponse(200, {"backends": [dict(name="simulated_qpu", **q)]})

    srv.route("POST", "/runtime/jobs", submit)
    srv.route("GET", "/runtime/jobs/{id}", jobinfo)
    srv.route("DELETE", "/runtime/jobs/{id}", cancel)
    srv.route("GET", "/runtime/backends", load)
    return srv


class QuantumAdapter(B.ResourceAdapter):
    image = "quantumpod"
    # results are PUSHED to object storage by the service — no file verbs;
    # the Runtime API is strictly one-job-per-request, so no BATCH_STATUS
    # either (the monitor falls back to per-id polling)
    capabilities = frozenset({
        B.Capability.CANCEL, B.Capability.CANCEL_QUEUED,
        B.Capability.QUEUE_LOAD,
    })

    def submit(self, script, properties, params) -> str:
        r = self.client.post("/runtime/jobs", {"program": script,
                                               "backend_options": properties,
                                               "params": params})
        if not r.ok:
            raise B.SubmitError(f"quantum submit: HTTP {r.status} {r.json}")
        return r.json["id"]

    def status(self, job_id: str) -> Dict[str, Any]:
        r = self.client.get(f"/runtime/jobs/{job_id}")
        if r.status == 404:
            return {"state": B.FAILED, "reason": "job not found"}
        if not r.ok:
            raise B.SubmitError(f"quantum status: HTTP {r.status}")
        j = r.json
        out = {"state": _Q_TO_STATE.get(j["status"], B.FAILED),
               "start_time": j.get("created"), "end_time": j.get("ended"),
               "reason": j.get("reason", "")}
        if "results_location" in j:
            out["results_location"] = j["results_location"]
        return out

    def cancel(self, job_id: str) -> None:
        self.client.delete(f"/runtime/jobs/{job_id}")

    def queue_load(self) -> Optional[Dict[str, int]]:
        r = self.client.get("/runtime/backends")
        if not r.ok:
            return None
        b = r.json["backends"][0]
        return {"queued": b["queued"], "running": b["running"], "slots": b["slots"]}
