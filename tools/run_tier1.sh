#!/usr/bin/env bash
# Tier-1 verification — the EXACT command from ROADMAP.md, with the
# PYTHONPATH the tree expects, so local runs and CI cannot drift.
# Usage: tools/run_tier1.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
