"""ConfigMap analogue: atomic, file-backed KV store per job.

The paper's key fault-tolerance mechanism: "Because the remote job ID is kept
in the config map, [on restart] the pod will know that the remote job is
already running and will not try to restart it" (§5.1).  The store therefore
must (a) survive controller-pod death, (b) be atomic per update, and (c) allow
both the operator and the pod to read/write concurrently.

Writes go through tempfile + os.replace (atomic on POSIX).  An optional
in-memory mode backs unit tests that don't need durability.

``update()`` is write-coalesced: an update whose every key already holds the
requested value is a no-op (no flush), so a monitor loop that pushes the same
RUNNING snapshot every poll tick costs zero disk writes.  ``flush_count``
counts actual flushes, which is what the scale benchmark and the I/O
regression tests measure.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import threading
from typing import Any, Dict, Iterator, Optional

# -- sharded placement: per-slice key namespacing ---------------------------
#
# A sliced array CR owns state on SEVERAL external resources at once, so its
# per-index config-map keys are namespaced by the owning slice
# ("slice_2_results_location_7"): two slices can never collide on a key, and
# the scale-down GC (``ConfigMap.prune``) can drop exactly the keys a drained
# index owned on exactly the slice that ran it.  Single-slice jobs keep the
# bare legacy names ("results_location_7") byte-for-byte.

_RESULTS_KEY_RE = re.compile(
    r"^(slice_\d+_)?results_location(_\d+)?$")


def slice_key(k: int, base: str) -> str:
    """Namespace a per-job config-map key by its owning placement slice."""
    return f"slice_{k}_{base}"


def is_results_key(key: str) -> bool:
    """True for any results-location key, slice-namespaced or legacy."""
    return bool(_RESULTS_KEY_RE.match(key))


class ConfigMap:
    """One named KV map (string -> string), Kubernetes-ConfigMap shaped."""

    def __init__(self, name: str, store: "StateStore"):
        self.name = name
        self._store = store

    @property
    def data(self) -> Dict[str, str]:
        return self._store._read(self.name)

    def get(self, key: str, default: str = "") -> str:
        return self.data.get(key, default)

    def update(self, updates: Dict[str, str]) -> Dict[str, str]:
        return self._store._update(self.name, updates)

    def prune(self, keys) -> Dict[str, str]:
        """Drop keys (missing ones ignored).  Elastic scale-down uses this to
        GC orphaned per-index entries so the map never grows monotonically
        across resizes."""
        return self._store._prune(self.name, keys)

    def replace(self, data: Dict[str, str]) -> None:
        self._store._replace(self.name, data)


class StateStore:
    """Cluster-level config-map registry (durable by default)."""

    def __init__(self, root: Optional[str] = None, coalesce: bool = True):
        self._root = root
        self._mem: Dict[str, Dict[str, str]] = {}
        self._lock = threading.RLock()
        # coalesce=False restores always-write semantics (benchmark baseline)
        self.coalesce = coalesce
        self.flush_count = 0  # number of full-map writes actually performed
        if root:
            os.makedirs(root, exist_ok=True)

    # -- public API -----------------------------------------------------

    def create(self, name: str, data: Optional[Dict[str, str]] = None) -> ConfigMap:
        with self._lock:
            if self.exists(name):
                raise KeyError(f"configmap {name!r} already exists")
            self._replace(name, dict(data or {}))
        return ConfigMap(name, self)

    def get(self, name: str) -> ConfigMap:
        if not self.exists(name):
            raise KeyError(f"configmap {name!r} not found")
        return ConfigMap(name, self)

    def get_or_create(self, name: str, data: Optional[Dict[str, str]] = None) -> ConfigMap:
        with self._lock:
            if self.exists(name):
                return ConfigMap(name, self)
            return self.create(name, data)

    def exists(self, name: str) -> bool:
        with self._lock:
            if self._root:
                return os.path.exists(self._path(name))
            return name in self._mem

    def delete(self, name: str) -> None:
        with self._lock:
            if self._root:
                try:
                    os.remove(self._path(name))
                except FileNotFoundError:
                    pass
            self._mem.pop(name, None)

    def list(self) -> Iterator[str]:
        with self._lock:
            if self._root:
                for f in sorted(os.listdir(self._root)):
                    if f.endswith(".json"):
                        yield f[:-5]
            else:
                yield from sorted(self._mem)

    # -- internals --------------------------------------------------------

    def _path(self, name: str) -> str:
        safe = name.replace("/", "__")
        return os.path.join(self._root, safe + ".json")

    def _read(self, name: str) -> Dict[str, str]:
        with self._lock:
            if self._root:
                try:
                    with open(self._path(name)) as f:
                        return json.load(f)
                except FileNotFoundError:
                    raise KeyError(f"configmap {name!r} not found")
            if name not in self._mem:
                raise KeyError(f"configmap {name!r} not found")
            return dict(self._mem[name])

    def _replace(self, name: str, data: Dict[str, str]) -> None:
        with self._lock:
            self.flush_count += 1
            if self._root:
                fd, tmp = tempfile.mkstemp(dir=self._root, suffix=".tmp")
                try:
                    with os.fdopen(fd, "w") as f:
                        json.dump(data, f)
                    os.replace(tmp, self._path(name))  # atomic
                finally:
                    if os.path.exists(tmp):
                        os.remove(tmp)
            self._mem[name] = dict(data)

    def _update(self, name: str, updates: Dict[str, str]) -> Dict[str, str]:
        with self._lock:
            cur = self._read(name)
            new = {k: str(v) for k, v in updates.items()}
            if self.coalesce and all(cur.get(k) == v for k, v in new.items()):
                return cur  # nothing changed value: skip the flush entirely
            cur.update(new)
            self._replace(name, cur)
            return cur

    def _prune(self, name: str, keys) -> Dict[str, str]:
        with self._lock:
            cur = self._read(name)
            present = [k for k in keys if k in cur]
            if not present:
                return cur  # nothing to drop: no flush
            for k in present:
                del cur[k]
            self._replace(name, cur)
            return cur
