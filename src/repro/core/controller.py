"""ControllerPod — the paper's "workhorse" (Figs. 2-3).

One pod per remote job.  The pod:
  1. reads execution data from the associated config map,
  2. mounts secrets, connects to the remote resource manager over the
     HTTP/HTTPS API (the ONLY channel to the external system),
  3. fetches the job script (inline / s3 / remote) and stages extra data,
  4. submits IF AND ONLY IF the config map holds no job id — a restarted pod
     finds the id and resumes monitoring instead of resubmitting (paper §5.1),
  5. runs the monitor loop: poll status, mirror it into the config map,
     honour the kill flag, tolerate transient network failures (UNKNOWN
     after ``unknown_after`` consecutive failures — never invent a terminal
     state),
  6. on completion downloads outputs and uploads them to S3, then exits
     0 (COMPLETED) / 1 (FAILED or CANCELLED), exactly like Fig. 3.

Pod death is simulated by ``kill_pod()``: the thread aborts at the next
action boundary WITHOUT flushing anything — only config-map state survives,
which is precisely the failure mode the paper's design addresses.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, Mapping, Optional, Type

from repro.core.backends import base as B
from repro.core.objectstore import NoSuchKey, ObjectStore
from repro.core.resource import (DONE, FAILED, KILLED, RUNNING, SUBMITTED,
                                 UNKNOWN)
from repro.core.rest import ResourceManagerDirectory, TransportError
from repro.core.secrets import SecretStore
from repro.core.statestore import ConfigMap, StateStore

# backend canonical -> bridge state
_CANON_TO_BRIDGE = {
    B.QUEUED: SUBMITTED,
    B.RUNNING: RUNNING,
    B.COMPLETED: DONE,
    B.FAILED: FAILED,
    B.CANCELLED: KILLED,
}


class PodKilled(BaseException):
    """Out-of-band pod termination (node failure / eviction)."""


class ControllerPod:
    # pod phases (Kubernetes-like)
    PENDING = "Pending"
    RUNNING_PHASE = "Running"
    SUCCEEDED = "Succeeded"
    FAILED_PHASE = "Failed"
    KILLED_PHASE = "Killed"   # external kill (node loss) — operator restarts

    def __init__(self, name: str, configmap: ConfigMap, secrets: SecretStore,
                 objectstore: ObjectStore, directory: ResourceManagerDirectory,
                 adapters: Mapping[str, Type[B.ResourceAdapter]],
                 min_sleep: float = 0.005):
        self.name = name
        self.cm = configmap
        self.secrets = secrets
        self.s3 = objectstore
        self.directory = directory
        self.adapters = dict(adapters)
        self.min_sleep = min_sleep
        self.phase = self.PENDING
        self.exit_code: Optional[int] = None
        self.error: str = ""
        self._killed = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"pod-{name}")

    # -- lifecycle --------------------------------------------------------

    def start(self) -> None:
        self._thread.start()

    def kill_pod(self) -> None:
        """Simulate pod/node failure: abort without flushing state."""
        self._killed.set()

    def alive(self) -> bool:
        return self._thread.is_alive()

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)

    # -- internals ----------------------------------------------------------

    def _checkpoint(self) -> None:
        """Action boundary: a killed pod dies here, state unflushed."""
        if self._killed.is_set():
            raise PodKilled(self.name)

    def _sleep(self, seconds: float) -> None:
        deadline = time.time() + seconds
        while time.time() < deadline:
            self._checkpoint()
            time.sleep(min(self.min_sleep, max(deadline - time.time(), 0)))

    def _adapter_for(self, image: str, client) -> B.ResourceAdapter:
        return B.resolve_adapter(self.adapters, image)(client)

    # -- paper Fig. 2: main --------------------------------------------------

    def _run(self) -> None:
        self.phase = self.RUNNING_PHASE
        try:
            self._main()
        except PodKilled:
            self.phase = self.KILLED_PHASE
        except Exception as e:  # pod crash (bug/unhandled) — operator restarts
            self.error = f"{type(e).__name__}: {e}"
            self.phase = self.KILLED_PHASE

    def _main(self) -> None:
        cm_data = self.cm.data
        url = cm_data["resourceURL"]
        image = cm_data["image"]
        poll = float(cm_data.get("updateinterval", "20"))

        # credentials from the mounted secret (never from the spec/config map)
        secret = self.secrets.mount(cm_data["resourcesecret"])
        token = secret.get("token", "")
        client = self.directory.connect(url, token)
        adapter = self._adapter_for(image, client)

        # v1beta1 job arrays: the config map carries the fan-out count; a
        # single v1alpha1 job is the count=1 degenerate case of the same path
        count = max(int(cm_data.get("array_count", "1") or "1"), 1)
        ids = [s for s in cm_data.get("id", "").split(",") if s]
        if len(ids) < count:
            ids = self._submit(adapter, cm_data, count, ids)
            if not ids:
                return  # FAILED already recorded; Fig. 2 klog.Exit path
        else:
            # paper: "Job has ID in ConfigMap. Handling state."
            pass
        self._monitor(adapter, ids, poll, cm_data)

    def _index_params(self, cm_data: Dict[str, str], index: int,
                      count: int) -> Dict[str, str]:
        """Per-index job params: base jobparams overlaid with the array's
        indexed_params[i], plus the injected BRIDGE_ARRAY_INDEX."""
        params = json.loads(cm_data.get("jobparams", "{}"))
        indexed = json.loads(cm_data.get("indexed_params", "[]") or "[]")
        if index < len(indexed):
            params.update(indexed[index])
        if count > 1:
            params.setdefault("BRIDGE_ARRAY_INDEX", str(index))
        return params

    def _submit(self, adapter: B.ResourceAdapter, cm_data: Dict[str, str],
                count: int = 1, ids: Optional[list] = None) -> list:
        self._checkpoint()
        ids = list(ids or [])
        retry_limit = int(cm_data.get("retry_limit", "0") or 0)
        backoff = float(cm_data.get("retry_backoff", "0") or 0)
        # persisted so a restarted pod never re-spends the submit budget
        attempt = int(cm_data.get("submit_attempts", "0") or 0)
        while True:
            if self.cm.get("kill", "false") == "true":
                self._abort_partial(adapter, ids)
                self.cm.update({"jobStatus": KILLED,
                                "message": "killed before submission"})
                self._exit(1)
                return []
            try:
                script = self._fetch_script(cm_data)
                self._stage_additional_data(adapter, cm_data)
                properties = json.loads(cm_data.get("jobproperties", "{}"))
                if (count > 1 and not ids
                        and adapter.supports(B.Capability.NATIVE_ARRAYS)):
                    # native fan-out: one submission call, N remote indices
                    ids = adapter.submit_array(
                        script, properties,
                        [self._index_params(cm_data, i, count)
                         for i in range(count)])
                    self.cm.update({"id": ",".join(ids)})
                else:
                    # facade-side fan-out: one submit per index, flushed
                    # incrementally so a pod killed mid-fan-out resumes at
                    # the next unsubmitted index instead of duplicating
                    while len(ids) < count:
                        self._checkpoint()
                        jid = adapter.submit(
                            script, properties,
                            self._index_params(cm_data, len(ids), count))
                        ids.append(jid)
                        self.cm.update({"id": ",".join(ids)})
                break
            except (B.SubmitError, TransportError, NoSuchKey, KeyError,
                    ValueError) as e:
                attempt += 1
                if attempt > retry_limit:
                    # don't orphan indices already fanned out this CR
                    self._abort_partial(adapter, ids)
                    self.cm.update(
                        {"jobStatus": FAILED,
                         "message": f"Failed to submit a job to HPC resource: {e}"})
                    self._exit(1)
                    return []
                self.cm.update({"submit_attempts": str(attempt)})
                self._sleep(backoff or self.min_sleep)
        self.cm.update({"id": ",".join(ids), "jobStatus": SUBMITTED,
                        "submit_time": str(time.time()), "message": ""})
        return ids

    def _abort_partial(self, adapter: B.ResourceAdapter, ids: list) -> None:
        """Best-effort cancel of indices submitted before an aborted fan-out."""
        if not ids or not adapter.supports(B.Capability.CANCEL):
            return
        for jid in ids:
            try:
                adapter.cancel(jid)
            except (TransportError, B.SubmitError):
                pass

    def _fetch_script(self, cm_data: Dict[str, str]) -> str:
        loc = cm_data.get("scriptlocation", "inline")
        script = cm_data.get("jobscript", "")
        if loc == "inline":
            return script
        if loc == "s3":
            bucket, key = ObjectStore.parse_ref(script)
            return self.s3.get_text(bucket, key)
        if loc == "remote":
            return script  # path already on the resource; submit by reference
        raise ValueError(f"scriptlocation {loc!r}")

    def _stage_additional_data(self, adapter: B.ResourceAdapter,
                               cm_data: Dict[str, str]) -> None:
        """Upload extra input files (s3 -> resource) where the API allows.

        The adapter's declared capabilities decide the path — no probing:
        without ``Capability.UPLOAD`` (e.g. slurmrestd) the job script must
        fetch from S3 itself, recorded for observability.
        """
        refs = [r for r in cm_data.get("additionaldata", "").split(",") if r]
        can_upload = adapter.supports(B.Capability.UPLOAD)
        for ref in refs:
            bucket, key = ObjectStore.parse_ref(ref)
            name = key.split("/")[-1]
            if not can_upload:
                self.cm.update({"staging": f"unsupported:{name}"})
                continue
            if not adapter.upload(name, self.s3.get(bucket, key)):
                self.cm.update({"staging": f"failed:{name}"})

    # -- paper Fig. 3: monitor ------------------------------------------------

    def _monitor(self, adapter: B.ResourceAdapter, ids: list, poll: float,
                 cm_data: Dict[str, str]) -> None:
        """Poll every remote index, mirror aggregate + per-index state into
        the config map, honour kill and the spec retry policy.

        Aggregate semantics: DONE only when every index completed; any KILLED
        propagates KILLED; a FAILED index is resubmitted while the retry
        budget lasts and propagates FAILED once it is exhausted.
        """
        count = len(ids)
        unknown_after = int(cm_data.get("unknown_after", "5"))
        retry_limit = int(cm_data.get("retry_limit", "0") or 0)
        backoff = float(cm_data.get("retry_backoff", "0") or 0)
        # per-index resubmission counts survive pod restarts via the cm
        attempts: Dict[str, int] = {
            k: int(v) for k, v in
            json.loads(cm_data.get("retry_attempts", "{}") or "{}").items()}
        consecutive_failures = 0
        kill_sent: set = set()
        while True:
            self._sleep(poll)
            cm_now = self.cm.data  # Fig. 3: "Get current config map"
            try:
                infos = [adapter.status(jid) for jid in ids]
                consecutive_failures = 0
            except (TransportError, B.SubmitError) as e:
                consecutive_failures += 1
                if consecutive_failures >= unknown_after:
                    # black-box honesty: unreachable != dead
                    self.cm.update({"jobStatus": UNKNOWN,
                                    "message": f"resource unreachable: {e}"})
                continue

            states = [_CANON_TO_BRIDGE[info["state"]] for info in infos]
            kill_requested = cm_now.get("kill", "false") == "true"

            # spec.retry: resubmit FAILED indices while budget remains
            # (a kill supersedes retries — never resubmit a killed CR)
            if retry_limit and not kill_requested:
                for i, st in enumerate(states):
                    used = attempts.get(str(i), 0)
                    if st != FAILED or used >= retry_limit:
                        continue
                    attempts[str(i)] = used + 1
                    if backoff:
                        self._sleep(backoff)
                    try:
                        # arrays go through resubmit_index so native dialects
                        # can restamp their index marker; single jobs resubmit
                        # plainly
                        resubmit = (adapter.resubmit_index if count > 1
                                    else lambda s, p, q, _i: adapter.submit(s, p, q))
                        new_id = resubmit(
                            self._fetch_script(cm_now),
                            json.loads(cm_now.get("jobproperties", "{}")),
                            self._index_params(cm_now, i, count), i)
                    except (B.SubmitError, TransportError, NoSuchKey,
                            KeyError, ValueError):
                        # budget consumed; surface FAILED when exhausted
                        self.cm.update(
                            {"retry_attempts": json.dumps(attempts)})
                        continue
                    ids[i] = new_id
                    states[i] = SUBMITTED
                    self.cm.update({"id": ",".join(ids),
                                    "retry_attempts": json.dumps(attempts)})

            def exhausted(i: int) -> bool:
                # a kill cancels the remaining budget — FAILED is final then
                return kill_requested or attempts.get(str(i), 0) >= retry_limit

            finished = all(
                st in (DONE, KILLED) or (st == FAILED and exhausted(i))
                for i, st in enumerate(states))
            if finished:
                if all(st == DONE for st in states):
                    agg = DONE
                elif any(st == KILLED for st in states):
                    agg = KILLED
                else:
                    agg = FAILED
            elif any(st == RUNNING for st in states):
                agg = RUNNING
            else:
                agg = SUBMITTED

            updates = {"jobStatus": agg,
                       "message": self._aggregate_message(states, infos)}
            if count > 1:
                updates["index_states"] = json.dumps(
                    {str(i): st for i, st in enumerate(states)})
            starts = [i.get("start_time") for i in infos if i.get("start_time")]
            ends = [i.get("end_time") for i in infos if i.get("end_time")]
            if starts:
                updates["start_time"] = str(min(starts))
            if ends and (count == 1 or finished):
                updates["end_time"] = str(max(ends))
            for i, info in enumerate(infos):
                if info.get("results_location"):
                    key = ("results_location" if count == 1
                           else f"results_location_{i}")
                    updates[key] = info["results_location"]
            self.cm.update(updates)

            if kill_requested and adapter.supports(B.Capability.CANCEL):
                can_cancel_queued = adapter.supports(B.Capability.CANCEL_QUEUED)
                for jid, st in zip(ids, states):
                    if jid in kill_sent or st in (DONE, FAILED, KILLED):
                        continue
                    if st == SUBMITTED and not can_cancel_queued:
                        continue  # dialect can't kill queued jobs; wait for RUNNING
                    try:
                        adapter.cancel(jid)
                        kill_sent.add(jid)
                    except TransportError:
                        pass  # retry next poll

            if finished:
                if agg == DONE:
                    self._finalize_outputs(adapter, ids, cm_now)
                    self._exit(0)
                else:
                    self._exit(1)
                return

    @staticmethod
    def _aggregate_message(states: list, infos: list) -> str:
        if len(states) == 1:
            return infos[0].get("reason", "") or ""
        parts = [f"[{i}] {info.get('reason', '')}"
                 for i, info in enumerate(infos) if info.get("reason")]
        return "; ".join(parts)

    def _finalize_outputs(self, adapter: B.ResourceAdapter, ids: list,
                          cm_data: Dict[str, str]) -> None:
        """Download outputs from the resource; upload to S3 if configured.
        Array indices land under ``<pod>/<index>/`` prefixes."""
        self._checkpoint()
        props = json.loads(cm_data.get("jobproperties", "{}"))
        bucket = cm_data.get("s3uploadbucket", "")
        names = [n for n in cm_data.get("s3uploadfiles", "").split(",") if n]
        for key in ("OutputFileName", "ErrorFileName"):
            if props.get(key) and props[key] not in names:
                names.append(props[key])
        can_download = adapter.supports(B.Capability.DOWNLOAD)
        can_logs = adapter.supports(B.Capability.LOGS)
        if not names or not (can_download or can_logs):
            return
        uploaded = []
        for idx, jid in enumerate(ids):
            prefix = self.name if len(ids) == 1 else f"{self.name}/{idx}"
            for name in names:
                data = adapter.download(name) if can_download else None
                if data is None and can_logs:
                    data = adapter.download_logs(jid)  # ray idiom
                if data is None:
                    continue
                if bucket:
                    self.s3.put(bucket, f"{prefix}/{name}", data)
                    uploaded.append(f"{bucket}:{prefix}/{name}")
        if uploaded:
            self.cm.update({"outputs": ",".join(uploaded)})

    def _exit(self, code: int) -> None:
        self.exit_code = code
        self.phase = self.SUCCEEDED if code == 0 else self.FAILED_PHASE
