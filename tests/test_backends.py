"""Backend genericity: ONE BridgeJob programming model, four+1 managers.

Paper claim: "a generic pattern which works for different external resources
(Slurm, LSF, Quantum, Ray, etc) without any change to the operator".
"""
import json
import time

import pytest

from repro.core import BridgeEnvironment, DONE, FAILED, KILLED

KINDS = ["slurm", "lsf", "quantum", "ray"]


@pytest.fixture(scope="module")
def env():
    with BridgeEnvironmentModule() as e:
        yield e


class BridgeEnvironmentModule(BridgeEnvironment):
    def __init__(self):
        super().__init__(default_duration=0.05)


@pytest.mark.parametrize("kind", KINDS)
def test_same_spec_shape_all_backends(env, kind):
    """Identical spec fields; only resourceURL/image/secret differ."""
    spec = env.make_spec(kind, script=f"run-on-{kind}",
                         jobproperties={"OutputFileName": "out.txt"})
    env.submit(f"generic-{kind}", spec)
    job = env.operator.wait_for(f"generic-{kind}", timeout=30)
    assert job.status.state == DONE, (kind, job.status.message)
    assert job.status.job_id


@pytest.mark.parametrize("kind", KINDS)
def test_kill_all_backends(env, kind):
    spec = env.make_spec(kind, script="sleepy", updateinterval=0.02,
                         jobproperties={"WallSeconds": "5"})
    env.submit(f"kill-{kind}", spec)
    deadline = time.time() + 10
    while time.time() < deadline:
        job = env.registry.get(f"kill-{kind}")
        if job.status.job_id:
            break
        time.sleep(0.01)
    env.operator.kill(f"kill-{kind}")
    job = env.operator.wait_for(f"kill-{kind}", timeout=30)
    assert job.status.state == KILLED, (kind, job.status.state)


def test_s3_script_staging(env):
    """scriptlocation=s3: pod fetches the script from the object store."""
    env.s3.put("mys3bucket", "slurmbatch.sh", b"#!/bin/bash\nsrun true\n")
    spec = env.make_spec("slurm", script="mys3bucket:slurmbatch.sh",
                         scriptlocation="s3")
    env.submit("s3script", spec)
    job = env.operator.wait_for("s3script", timeout=30)
    assert job.status.state == DONE
    # the backend received the RESOLVED script text, not the s3 ref
    cluster_job = env.clusters["slurm"].jobs[job.status.job_id]
    assert cluster_job.script.startswith("#!/bin/bash")


def test_s3_missing_script_fails_cleanly(env):
    spec = env.make_spec("slurm", script="mys3bucket:does-not-exist.sh",
                         scriptlocation="s3")
    env.submit("s3missing", spec)
    job = env.operator.wait_for("s3missing", timeout=30)
    assert job.status.state == FAILED
    assert "Failed to submit" in job.status.message


def test_lsf_upload_download_and_s3_output(env):
    """LSF supports staging: additionaldata uploads; outputs land in S3."""
    env.s3.put("inputs", "data/input.csv", b"a,b\n1,2\n")
    spec = env.make_spec(
        "lsf", script="analyse input.csv",
        additionaldata="inputs:data/input.csv",
        jobproperties={"OutputFileName": "lsfjob.out"},
        uploadfiles="lsfjob.out", uploadbucket="outputs")
    env.submit("lsf-stage", spec)
    job = env.operator.wait_for("lsf-stage", timeout=30)
    assert job.status.state == DONE
    # input staged onto the cluster
    assert env.clusters["lsf"].files.get("input.csv") == b"a,b\n1,2\n"
    # output uploaded to S3 under the pod's prefix
    keys = env.s3.list("outputs")
    assert any(k.endswith("lsfjob.out") for k in keys), keys


def test_slurm_has_no_file_api(env):
    """Slurm REST 21.08 lacks upload (paper §5.2) — staging degrades
    gracefully and is recorded in the config map."""
    env.s3.put("inputs", "x.bin", b"\x00\x01")
    spec = env.make_spec("slurm", script="job", additionaldata="inputs:x.bin",
                         jobproperties={"WallSeconds": "0.1"})
    env.submit("slurm-stage", spec)
    job = env.operator.wait_for("slurm-stage", timeout=30)
    assert job.status.state == DONE
    cm = env.statestore.get(env.operator.cm_name(job))
    assert cm.get("staging").startswith("unsupported:")


def test_quantum_results_in_object_storage(env):
    """Quantum idiom: results are uploaded to object storage by the service;
    the bridge records the location."""
    spec = env.make_spec("quantum", script="OPENQASM 3; qubit q;",
                         jobproperties={"shots": "2048"})
    env.submit("qjob", spec)
    job = env.operator.wait_for("qjob", timeout=30)
    assert job.status.state == DONE
    cm = env.statestore.get(env.operator.cm_name(job))
    loc = cm.get("results_location")
    assert loc
    bucket, key = loc.split(":", 1)
    result = json.loads(env.s3.get(bucket, key))
    assert result["shots"] == 2048


def test_lsf_native_array_one_call(env):
    """ROADMAP satellite: the Application Center dialect submits a whole
    job array in ONE bsub -J "name[lo-hi]"-style request, every element
    stamped with its 1-based LSB_JOBINDEX and per-index params applied."""
    from repro.core.backends.lsf import LSFAdapter
    from repro.core import TOKENS, URLS

    client = env.directory.connect(URLS["lsf"], TOKENS["lsf"])
    ad = LSFAdapter(client)
    req0 = env.servers["lsf"].request_count
    ids = ad.submit_array("member", {"WallSeconds": "0.05"},
                          [{"IDX": str(i)} for i in range(3)], start_index=4)
    assert env.servers["lsf"].request_count - req0 == 1, (
        "native arrays must fan out server-side, in one request")
    assert len(ids) == 3
    jobs = [env.clusters["lsf"].jobs[j] for j in ids]
    # global indices 4..6 -> 1-based LSB_JOBINDEX 5..7
    assert [j.params["LSB_JOBINDEX"] for j in jobs] == ["5", "6", "7"]
    assert [j.params["IDX"] for j in jobs] == ["0", "1", "2"]

    # malformed array names are a 400, not a silent single submission
    r = client.post("/platform/ws/jobs/submit",
                    {"COMMANDTORUN": "x", "JOB_ARRAY": "oops[3-1]"})
    assert r.status == 400


def test_ray_idempotent_resubmission(env):
    """Ray submission_id semantics: resubmitting the same id is a no-op."""
    from repro.core.backends.ray import RayAdapter
    from repro.core import TOKENS, URLS

    client = env.directory.connect(URLS["ray"], TOKENS["ray"])
    ad = RayAdapter(client, submission_id="raysubmit_fixed")
    id1 = ad.submit("python train.py", {}, {})
    id2 = ad.submit("python train.py", {}, {})
    assert id1 == id2 == "raysubmit_fixed"
    n = sum(1 for j in env.clusters["ray"].jobs.values()
            if j.script == "python train.py")
    assert n == 1


@pytest.mark.parametrize("kind", ["slurm", "lsf"])
def test_cancel_of_terminal_job_is_409_not_500(env, kind):
    """Regression: a cancel that loses the race against a terminal status
    transition answers 409 Conflict — a protocol outcome, not a 500 — and a
    cancel of a live job still succeeds."""
    from repro.core import TOKENS, URLS

    client = env.directory.connect(URLS[kind], TOKENS[kind])

    def cancel_req(jid):
        if kind == "slurm":
            return client.delete(f"/slurm/v0.0.37/job/{jid}")
        return client.post(f"/platform/ws/jobs/{jid}/kill")

    done = env.clusters[kind].submit("quick", {"WallSeconds": "0.01"}, {})
    deadline = time.time() + 10
    while time.time() < deadline and done.state not in ("COMPLETED", "FAILED"):
        time.sleep(0.01)
    r = cancel_req(done.id)
    assert r.status == 409, (r.status, r.json)
    assert "error" in r.json

    live = env.clusters[kind].submit("slow", {"WallSeconds": "30"}, {})
    assert cancel_req(live.id).status == 200
    assert cancel_req("999999").status == 404


def test_auth_required(env):
    """Requests without the bearer token are rejected (401)."""
    from repro.core import URLS

    client = env.directory.connect(URLS["slurm"], token="wrong-token")
    r = client.get("/slurm/v0.0.37/ping")
    assert r.status == 401


def test_unauthenticated_spec_fails(env):
    """A spec whose secret holds a bad token -> submission fails, FAILED."""
    env.secrets.create("bad-secret", {"token": "nope"})
    spec = env.make_spec("slurm", script="x")
    import dataclasses
    spec = dataclasses.replace(spec, resourcesecret="bad-secret")
    env.submit("badauth", spec)
    job = env.operator.wait_for("badauth", timeout=30)
    assert job.status.state == FAILED
