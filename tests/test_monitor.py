"""The multiplexed control plane: MonitorRuntime semantics + the per-tick
I/O complexity guarantees (batched status, write-coalesced state store).

The I/O tests are REGRESSION tests: they pin the control plane's cost model
(requests per tick sublinear in array size; zero flushes on steady-state
RUNNING ticks), not just its observable job states.
"""
import threading
import time

import pytest

from repro.core import (ArraySpec, BATCH_STATUS_CHUNK, BridgeEnvironment,
                        Capability, DONE, KILLED, RUNNING, SUBMITTED)


def _wait(predicate, timeout=10, interval=0.005):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


# ---------------------------------------------------------------------------
# both modes: identical lifecycle semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["multiplexed", "pod-per-cr"])
def test_lifecycle_parity_across_modes(mode):
    """Submit-to-DONE (incl. a 4-index array) and kill behave identically in
    both operator modes."""
    with BridgeEnvironment(default_duration=0.05,
                           operator_kwargs={"mode": mode}) as env:
        arr = env.make_spec("slurm", script="member", updateinterval=0.02,
                            array=ArraySpec(count=4))
        single = env.make_spec("lsf", script="solo", updateinterval=0.02)
        victim = env.make_spec("ray", script="sleepy", updateinterval=0.02,
                               jobproperties={"WallSeconds": "5"})
        h_arr = env.bridge.submit("par-arr", arr)
        h_single = env.bridge.submit("par-single", single)
        h_victim = env.bridge.submit("par-victim", victim)
        assert _wait(lambda: h_victim.status().job_id, timeout=10)
        h_victim.cancel()
        assert h_arr.wait(timeout=30).status.state == DONE
        assert h_arr.job().status.index_states == {str(i): DONE
                                                   for i in range(4)}
        assert h_single.wait(timeout=30).status.state == DONE
        assert h_victim.wait(timeout=30).status.state == KILLED


def test_multiplexed_pod_kill_resume_no_double_submit():
    """Satellite-spec coverage: kill the virtual pod (MonitorTask) of a
    running job under mode="multiplexed" — the operator restarts it, the
    replacement resumes via the config map, and the remote cluster sees
    exactly ONE job."""
    with BridgeEnvironment(default_duration=0.05,
                           operator_kwargs={"mode": "multiplexed"}) as env:
        assert env.operator.runtime is not None
        handle = env.bridge.submit("mres", env.make_spec(
            "slurm", script="long", updateinterval=0.02,
            jobproperties={"WallSeconds": "1.0"}))
        assert _wait(lambda: handle.status().job_id, timeout=10)
        first_id = handle.status().job_id
        env.operator.pods["default/mres"].kill_pod()
        job = handle.wait(timeout=30)
        assert job.status.state == DONE
        assert job.status.restarts >= 1
        assert job.status.job_id == first_id, "restarted task must NOT resubmit"
        assert len(env.clusters["slurm"].jobs) == 1, "no double submission"


def test_multiplexed_thread_count_is_pool_size_not_cr_count():
    """The whole point of the runtime: 8 concurrent CRs are monitored by
    monitor_workers threads, with zero per-CR pod threads."""
    with BridgeEnvironment(default_duration=0.3, slots=8,
                           operator_kwargs={"mode": "multiplexed",
                                            "monitor_workers": 3}) as env:
        handles = [env.bridge.submit(f"tc-{i}", env.make_spec(
            "slurm", script="t", updateinterval=0.02,
            jobproperties={"WallSeconds": "0.3"})) for i in range(8)]
        assert _wait(lambda: all(h.status().job_id for h in handles),
                     timeout=15)
        pod_threads = [t for t in threading.enumerate()
                       if t.name.startswith("pod-")]
        assert pod_threads == [], "multiplexed mode must not spawn pod threads"
        assert env.operator.runtime.thread_count() == 3
        for h in handles:
            assert h.wait(timeout=30).status.state == DONE


# ---------------------------------------------------------------------------
# I/O complexity: REST requests per tick, config-map flushes per tick
# ---------------------------------------------------------------------------


def test_array_rest_request_complexity_is_batched():
    """A 64-index SLURM array run to DONE issues ~count/chunk requests per
    tick (one squeue-style batch per chunk), NOT one request per index."""
    count = 64
    with BridgeEnvironment(default_duration=0.2, slots=count,
                           operator_kwargs={"mode": "multiplexed"}) as env:
        srv = env.servers["slurm"]
        spec = env.make_spec("slurm", script="m", updateinterval=0.05,
                             array=ArraySpec(count=count))
        req0 = srv.request_count
        t0 = time.time()
        job = env.bridge.submit("batcharr", spec).wait(timeout=60)
        elapsed = time.time() - t0
        assert job.status.state == DONE
        requests = srv.request_count - req0
        # 1 native-array submit + ceil(count/chunk) requests per tick, with
        # a generous tick allowance derived from the measured wall time
        chunks_per_tick = -(-count // BATCH_STATUS_CHUNK)
        max_ticks = elapsed / 0.05 + 5
        assert requests <= 1 + chunks_per_tick * max_ticks, (
            f"{requests} requests for {count} indices over ~{max_ticks:.0f} "
            f"ticks — batched polling regressed to per-index")


def test_steady_state_running_ticks_flush_nothing():
    """While a job just keeps RUNNING, poll ticks must not rewrite the
    config map: the monitor diffs its updates and the store coalesces."""
    with BridgeEnvironment(default_duration=0.05) as env:
        handle = env.bridge.submit("steady", env.make_spec(
            "slurm", script="s", updateinterval=0.02,
            jobproperties={"WallSeconds": "1.0"}))
        assert _wait(lambda: handle.status().state == RUNNING
                     and handle.status().start_time is not None, timeout=10)
        time.sleep(0.06)  # let the RUNNING-transition write land
        flushes0 = env.statestore.flush_count
        time.sleep(0.3)   # ~15 steady-state RUNNING ticks
        assert env.statestore.flush_count == flushes0, (
            "steady-state RUNNING ticks must not flush the config map")
        assert handle.wait(timeout=30).status.state == DONE


def test_batch_status_capability_matrix():
    """slurm/lsf/jaxlocal speak a multi-id status verb; quantum/ray honestly
    do not (their real APIs are one-job-per-request) and fall back."""
    with BridgeEnvironment() as env:
        has = {k: Capability.BATCH_STATUS in env.bridge.capabilities(img)
               for k, img in (("slurm", "slurmpod:0.1"), ("lsf", "lsfpod:0.1"),
                              ("quantum", "quantumpod:0.1"),
                              ("ray", "raypod:0.1"),
                              ("jaxlocal", "jaxpod:0.1"))}
        assert has == {"slurm": True, "lsf": True, "jaxlocal": True,
                       "quantum": False, "ray": False}


def test_status_batch_aligned_and_handles_vanished_ids():
    """status_batch answers in request order and gives a vanished id the
    same semantics as a per-id 404."""
    with BridgeEnvironment(default_duration=5.0) as env:
        from repro.core import TOKENS, URLS
        from repro.core.backends.slurm import SlurmAdapter

        jobs = [env.clusters["slurm"].submit("x", {}, {}) for _ in range(3)]
        ad = SlurmAdapter(env.directory.connect(URLS["slurm"],
                                                TOKENS["slurm"]))
        infos = ad.status_batch([jobs[1].id, "99999", jobs[0].id])
        assert len(infos) == 3
        assert infos[0]["state"] == infos[2]["state"]  # both live
        assert infos[1]["state"] == "FAILED"
        assert "vanished" in infos[1]["reason"]
        assert infos[0] == ad.status(jobs[1].id)  # parity with per-id verb


def test_array_fallback_without_batch_status():
    """An adapter without BATCH_STATUS still completes arrays (per-id
    polling path stays correct)."""
    with BridgeEnvironment(default_duration=0.05) as env:
        spec = env.make_spec("ray", script="member", updateinterval=0.02,
                             array=ArraySpec(count=3))
        job = env.bridge.submit("arr-ray", spec).wait(timeout=30)
        assert job.status.state == DONE
        assert job.status.index_states == {str(i): DONE for i in range(3)}


# ---------------------------------------------------------------------------
# satellites: stop() race, TTL dependency hold, FaultProfile thread-safety
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["multiplexed", "pod-per-cr"])
def test_stop_with_live_pods_joins_cleanly(mode):
    """stop() while many pods monitor long jobs: snapshot + bounded join —
    no dict-changed-size crash, and every pod is dead afterwards."""
    env = BridgeEnvironment(default_duration=0.05,
                            operator_kwargs={"mode": mode}).start()
    try:
        handles = [env.bridge.submit(f"stop-{i}", env.make_spec(
            "slurm", script="long", updateinterval=0.02,
            jobproperties={"WallSeconds": "10"})) for i in range(6)]
        assert _wait(lambda: all(h.status().job_id for h in handles),
                     timeout=15)
    finally:
        env.stop()
    assert _wait(lambda: not any(p.alive()
                                 for p in env.operator.pods.values()),
                 timeout=5), "pods must be dead after stop()"


def test_ttl_gc_held_while_dependent_alive():
    """A terminal CR past its TTL survives as long as a live sibling depends
    on it (guards the reverse-dependency index refactor)."""
    with BridgeEnvironment(default_duration=0.05) as env:
        dep = env.make_spec("slurm", script="dep", updateinterval=0.02,
                            ttl_seconds_after_finished=0.1)
        child = env.make_spec("slurm", script="child", updateinterval=0.02,
                              jobproperties={"WallSeconds": "0.8"},
                              dependencies=["ttl-dep"])
        h_dep = env.bridge.submit("ttl-dep", dep)
        h_child = env.bridge.submit("ttl-child", child)
        assert h_dep.wait(timeout=30).status.state == DONE
        # well past the 0.1s TTL, the child still runs -> CR must survive
        assert _wait(lambda: h_child.status().state == RUNNING, timeout=15)
        assert h_dep.job() is not None, "TTL GC must wait for the dependent"
        assert h_child.wait(timeout=30).status.state == DONE
        assert _wait(lambda: h_dep.job() is None, timeout=10), (
            "TTL GC must resume once the dependent finished")


def test_fault_profile_deterministic_under_concurrency():
    """The shared seeded RNG is lock-guarded: N draws produce the same drop
    count whether they come from 1 thread or 8."""
    from repro.core.rest import FaultProfile, TransportError

    def count_drops(fault, n_threads, checks_per_thread):
        drops = [0] * n_threads

        def hammer(i):
            for _ in range(checks_per_thread):
                try:
                    fault.check()
                except TransportError:
                    drops[i] += 1

        threads = [threading.Thread(target=hammer, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return sum(drops)

    serial = count_drops(FaultProfile(drop_rate=0.3, seed=1234), 1, 4000)
    concurrent = count_drops(FaultProfile(drop_rate=0.3, seed=1234), 8, 500)
    assert serial > 0
    assert concurrent == serial, (
        "same seed + same draw count must yield the same injected drops")
