"""Training driver: the same pjit train step the dry-run lowers, executed
on the locally available devices, with framework checkpointing.

Local smoke scale (CPU container):
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
      --steps 50 --batch 4 --seq 64 --ckpt-dir /tmp/run1 --ckpt-every 10

Production scale: the identical code path with --data/--model sized to the
pod (the dry-run proves lowering for 16x16 / 2x16x16).  XLA's latency-hiding
scheduler overlaps the TP collectives with compute
(--xla_tpu_enable_latency_hiding_scheduler on real TPU; documented here
because this container has no TPU to pass it to).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.compat import jit_sharded, use_mesh
from repro.configs.base import ARCH_IDS, ShapeConfig, get_config, get_smoke_config
from repro.core.objectstore import ObjectStore
from repro.data import DataConfig, SyntheticDataset, with_frontend_stubs
from repro.launch.mesh import make_local_mesh
from repro.models.params import init_params
from repro.models.transformer import model_defs
from repro.optim import AdamWConfig, adamw_init
from repro.steps import make_train_step


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="gemma-2b", choices=ARCH_IDS)
    p.add_argument("--smoke", action="store_true", help="reduced config")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=1e-2)
    p.add_argument("--data", type=int, default=1, help="data mesh dim")
    p.add_argument("--model", type=int, default=1, help="model mesh dim")
    p.add_argument("--strategy", default="tp", choices=["tp", "fsdp_tp"])
    p.add_argument("--no-zero1", action="store_true")
    p.add_argument("--no-remat", action="store_true")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=0)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = make_local_mesh(args.data, args.model)
    bundle = make_train_step(cfg, mesh, shape,
                             opt_cfg=AdamWConfig(lr=args.lr,
                                                 total_steps=args.steps,
                                                 warmup_steps=max(args.steps // 10, 1)),
                             strategy=args.strategy,
                             zero1=not args.no_zero1,
                             remat=not args.no_remat)
    ds = SyntheticDataset(DataConfig(cfg.vocab, args.seq, args.batch,
                                     seed=args.seed))
    defs = model_defs(cfg, max_seq=args.seq)
    params = init_params(jax.random.PRNGKey(args.seed), defs)
    opt_state = adamw_init(params)

    mgr, start = None, 0
    if args.ckpt_dir and args.ckpt_every:
        mgr = CheckpointManager(ObjectStore(root=args.ckpt_dir), "ckpt", "run")
        resumed = mgr.restore_latest({"params": params, "opt": opt_state})
        if resumed:
            start, tree, _ = resumed
            params, opt_state = tree["params"], tree["opt"]
            print(f"[train] resumed from step {start}")

    with use_mesh(mesh):
        step_fn = jit_sharded(bundle.fn, mesh,
                              in_shardings=bundle.in_shardings,
                              out_shardings=bundle.out_shardings,
                              donate_argnames=bundle.donate_argnames)
        t0 = time.time()
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     with_frontend_stubs(ds.batch(step), cfg,
                                         seed=args.seed).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (step + 1) % args.log_every == 0 or step == start:
                dt = (time.time() - t0) / max(step - start + 1, 1)
                print(f"[train] step {step + 1:5d} "
                      f"loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f} ms/step")
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save_async(step + 1, {"params": params, "opt": opt_state},
                               extra={"loss": float(metrics["loss"])})
    if mgr:
        mgr.wait()
        mgr.save(args.steps, {"params": params, "opt": opt_state})
        print(f"[train] checkpointed at {args.ckpt_dir}")
    print("[train] done")


if __name__ == "__main__":
    main()
