"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 12 \
      --max-batch 4 --max-new 8

Reports throughput (tokens/sec, requests/sec) and per-request latency
percentiles (submit -> finish, so queueing inside the engine counts).
``--json`` emits the summary as one machine-readable JSON object instead of
prose — the shape benchmark tooling can diff.
"""
import argparse
import json
import time

import numpy as np

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.serving import ServingEngine
from repro.steps import init_model


def _pct(sorted_vals, p):
    if not sorted_vals:
        return None
    return sorted_vals[min(len(sorted_vals) - 1, int(len(sorted_vals) * p))]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="gemma-2b", choices=ARCH_IDS)
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--prefill-len", type=int, default=16)
    p.add_argument("--max-len", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", action="store_true",
                   help="emit the summary as one JSON object")
    args = p.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("serve targets decoder LMs; whisper decode is "
                         "exercised via tests/test_arch_smoke.py")
    _, params = init_model(cfg, seed=args.seed, max_seq=args.max_len)
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_len=args.max_len, prefill_len=args.prefill_len)
    rng = np.random.RandomState(args.seed)
    t0 = time.time()
    submit_t = {}
    ids = []
    for _ in range(args.requests):
        rid = eng.submit(list(rng.randint(1, cfg.vocab,
                                          size=args.prefill_len)),
                         max_new_tokens=args.max_new)
        submit_t[rid] = time.time()
        ids.append(rid)
    # pump the engine by hand (instead of run_until_idle) so each request's
    # finish time — and with it the latency distribution — is observable
    finish_t = {}
    pending = set(ids)
    for _ in range(100_000):
        if not pending:
            break
        eng.step()
        now = time.time()
        for rid in list(pending):
            if rid in eng.finished:
                finish_t[rid] = now
                pending.discard(rid)
    dt = time.time() - t0
    results = {rid: r.generated for rid, r in eng.finished.items()}

    lat = sorted(finish_t[rid] - submit_t[rid] for rid in ids
                 if rid in finish_t)
    toks = eng.stats["tokens"]
    summary = {
        "arch": args.arch, "requests": args.requests,
        "completed": len(finish_t), "tokens": toks,
        "wall_s": round(dt, 4),
        "tokens_per_s": round(toks / dt, 2) if dt > 0 else None,
        "requests_per_s": round(len(finish_t) / dt, 2) if dt > 0 else None,
        "latency_p50_s": _pct(lat, 0.50),
        "latency_p90_s": _pct(lat, 0.90),
        "latency_p99_s": _pct(lat, 0.99),
        "decode_ticks": eng.stats["decode_ticks"],
        "prefills": eng.stats["prefills"],
    }
    if args.json:
        print(json.dumps(summary))
        return
    for rid in ids[:4]:
        print(f"[serve] req {rid}: {results[rid]}")
    print(f"[serve] {summary['completed']}/{args.requests} requests, "
          f"{toks} tokens in {dt:.2f}s ({summary['tokens_per_s']} tok/s, "
          f"{summary['requests_per_s']} req/s)")
    print(f"[serve] latency p50={summary['latency_p50_s']:.4f}s "
          f"p90={summary['latency_p90_s']:.4f}s "
          f"p99={summary['latency_p99_s']:.4f}s "
          f"({summary['decode_ticks']} ticks, "
          f"{summary['prefills']} prefills)")


if __name__ == "__main__":
    main()
