"""CRD generation semantics + conversion round-trips of PATCHED documents.

Elastic arrays made the spec mutable, so the conversion layer now has to
carry the convergence handshake (`metadata.generation` /
`status.observedGeneration`) across versions, and the registry must bump the
generation on spec changes only.
"""
import json

import pytest

from repro.core import (API_V1ALPHA1, API_V1BETA1, ArraySpec, BridgeJob,
                        BridgeJobSpec, ConversionError, JobData,
                        PlacementCandidate, PlacementSpec, ResourceRegistry,
                        StateStore, ValidationError, convert, load_bridgejob)
from repro.core.statestore import is_results_key, slice_key


def _spec(**kw) -> BridgeJobSpec:
    return BridgeJobSpec(resourceURL="https://hpc.example.com",
                         image="slurmpod:0.1", resourcesecret="sec",
                         jobdata=JobData(jobscript="run"), **kw)


# ---------------------------------------------------------------------------
# generation fields survive conversion round-trips
# ---------------------------------------------------------------------------


def test_patched_alpha_document_roundtrips_with_generation():
    """A patched (generation > 1) non-elastic document survives
    alpha -> beta -> alpha bit-for-bit, generation fields included."""
    job = BridgeJob(name="p", spec=_spec(), generation=5)
    job.status.observed_generation = 4
    doc = job.to_dict(API_V1ALPHA1)
    assert doc["metadata"]["generation"] == 5
    assert doc["status"]["observed_generation"] == 4
    up = convert(doc, API_V1BETA1)
    assert up["metadata"]["generation"] == 5
    assert up["status"]["observed_generation"] == 4
    down = convert(up, API_V1ALPHA1)
    assert json.dumps(down, sort_keys=True) == json.dumps(doc, sort_keys=True)


def test_patched_elastic_document_roundtrips_in_beta():
    """An elastic (resized) document keeps its generation handshake through
    a beta -> beta serialization round-trip via from_dict/to_dict."""
    job = BridgeJob(name="el", spec=_spec(array=ArraySpec(count=48)),
                    generation=3)
    job.status.observed_generation = 2
    doc = job.to_dict()
    assert doc["apiVersion"] == API_V1BETA1
    parsed = load_bridgejob(json.dumps(doc))
    assert parsed.generation == 3
    assert parsed.status.observed_generation == 2
    assert parsed.spec.array.count == 48


def test_lossy_downgrade_of_elastic_spec_refused_with_clear_error():
    """Downgrading a resized array document to v1alpha1 must fail loudly —
    the alpha schema cannot express the elastic state."""
    doc = BridgeJob(name="el", spec=_spec(array=ArraySpec(count=8)),
                    generation=2).to_dict()
    with pytest.raises(ConversionError) as ei:
        convert(doc, API_V1ALPHA1)
    assert "array" in str(ei.value) and "v1alpha1" in str(ei.value)


def test_from_dict_defaults_generation_for_legacy_documents():
    """Pre-elastic documents (no metadata.generation) parse with the
    Kubernetes default of 1."""
    doc = BridgeJob(name="old", spec=_spec()).to_dict(API_V1ALPHA1)
    del doc["metadata"]["generation"]
    del doc["status"]
    job = BridgeJob.from_dict(doc)
    assert job.generation == 1
    assert job.status.observed_generation == 0


# ---------------------------------------------------------------------------
# sharded placement: spec.placement / status.placements round-trips
# ---------------------------------------------------------------------------


def _placement(**kw) -> PlacementSpec:
    return PlacementSpec(candidates=[
        PlacementCandidate("https://a.example.com", "slurmpod:0.1", "sa"),
        PlacementCandidate("https://b.example.com", "lsfpod:0.1", "sb",
                           weight=3.0),
    ], **kw)


def test_placement_spec_and_status_roundtrip():
    """spec.placement (candidates/strategy/maxSlices) and the per-slice
    status.placements survive a beta -> beta serialization round-trip."""
    job = BridgeJob(name="sh", spec=_spec(
        array=ArraySpec(count=64),
        placement=_placement(strategy="spread", max_slices=2)))
    job.status.placements = [
        {"slice": 0, "resourceURL": "https://a.example.com",
         "image": "slurmpod:0.1", "indices": [0, 1], "state": "RUNNING"},
        {"slice": 1, "resourceURL": "https://b.example.com",
         "image": "lsfpod:0.1", "indices": [2, 3], "state": "SUBMITTED"},
    ]
    doc = job.to_dict()
    assert doc["apiVersion"] == API_V1BETA1
    assert doc["spec"]["placement"]["strategy"] == "spread"
    assert doc["spec"]["placement"]["maxSlices"] == 2
    assert doc["spec"]["placement"]["candidates"][1]["weight"] == 3.0
    parsed = load_bridgejob(json.dumps(doc))
    assert parsed.spec.placement == job.spec.placement
    assert parsed.status.placements == job.status.placements
    # and the re-serialization is bit-for-bit stable
    assert json.dumps(parsed.to_dict(), sort_keys=True) == json.dumps(
        doc, sort_keys=True)


def test_placement_allows_empty_toplevel_target():
    """With spec.placement the scheduler assigns endpoints, so the top-level
    resourceURL/image/resourcesecret trio becomes optional."""
    spec = BridgeJobSpec(resourceURL="", image="", resourcesecret="",
                         jobdata=JobData(jobscript="run"),
                         placement=_placement(strategy="spread"))
    spec.validate()  # must not raise
    with pytest.raises(ValidationError, match="resourceURL"):
        BridgeJobSpec(resourceURL="", image="", resourcesecret="",
                      jobdata=JobData(jobscript="run")).validate()


def test_placement_validation():
    with pytest.raises(ValidationError, match="at least one candidate"):
        _spec(placement=PlacementSpec()).validate()
    with pytest.raises(ValidationError, match="strategy"):
        _spec(placement=_placement(strategy="everywhere")).validate()
    with pytest.raises(ValidationError, match="maxSlices"):
        _spec(placement=_placement(max_slices=-1)).validate()
    with pytest.raises(ValidationError, match="weight"):
        _spec(placement=PlacementSpec(candidates=[PlacementCandidate(
            "https://a", "slurmpod", "sa", weight=0)])).validate()


def test_sliced_spec_refuses_v1alpha1_downgrade():
    """Mirroring the elastic-array rule: a sliced (placed) document has no
    v1alpha1 representation — even under strategy "single" — and must fail
    loudly rather than silently drop its placement."""
    doc = BridgeJob(name="sh", spec=_spec(placement=_placement())).to_dict()
    with pytest.raises(ConversionError) as ei:
        convert(doc, API_V1ALPHA1)
    assert "placement" in str(ei.value) and "v1alpha1" in str(ei.value)


def test_unplaced_documents_still_roundtrip_to_alpha():
    """The placement field is emitted only when candidates exist, so plain
    documents keep converting losslessly in both directions."""
    doc = BridgeJob(name="plain", spec=_spec()).to_dict(API_V1ALPHA1)
    up = convert(doc, API_V1BETA1)
    assert "placement" not in up["spec"]
    down = convert(up, API_V1ALPHA1)
    assert json.dumps(down, sort_keys=True) == json.dumps(doc, sort_keys=True)


def test_slice_key_namespacing_helpers():
    """statestore's slice-key helpers: namespacing and results-key
    recognition for both the legacy and the slice-namespaced shapes."""
    assert slice_key(2, "results_location_7") == "slice_2_results_location_7"
    assert slice_key(0, "id") == "slice_0_id"
    assert is_results_key("results_location")
    assert is_results_key("results_location_12")
    assert is_results_key("slice_3_results_location_12")
    assert not is_results_key("slice_3_id")
    assert not is_results_key("results_location_12_extra")
    assert not is_results_key("id")


# ---------------------------------------------------------------------------
# registry generation bookkeeping
# ---------------------------------------------------------------------------


def test_registry_bumps_generation_on_spec_change_only():
    import dataclasses

    reg = ResourceRegistry()
    reg.create(BridgeJob(name="g", spec=_spec(array=ArraySpec(count=2))))
    assert reg.get("g").generation == 1

    reg.update_status("g", state="RUNNING")
    assert reg.get("g").generation == 1, "status writes must not bump"

    reg.update_spec("g", lambda s: dataclasses.replace(
        s, array=ArraySpec(count=5)))
    assert reg.get("g").generation == 2

    reg.update_spec("g", lambda s: s)  # no-op patch
    assert reg.get("g").generation == 2, "a no-op mutation must not bump"
    rv = reg.get("g").resource_version
    reg.update_spec("g", lambda s: dataclasses.replace(s, kill=True))
    assert reg.get("g").generation == 3
    assert reg.get("g").resource_version > rv


# ---------------------------------------------------------------------------
# state-store pruning (the per-index GC primitive)
# ---------------------------------------------------------------------------


def test_configmap_prune_drops_keys_and_coalesces():
    store = StateStore()
    cm = store.create("ns/j-cm", {"a": "1", "results_location_2": "b:k",
                                  "index_states": "{}"})
    flushes = store.flush_count
    cm.prune(["results_location_2", "not-there"])
    assert store.flush_count == flushes + 1
    assert "results_location_2" not in cm.data and cm.get("a") == "1"
    cm.prune(["still-not-there"])
    assert store.flush_count == flushes + 1, "pruning nothing must not flush"
