"""Perf-iteration helper: run one dry-run cell with overrides and diff the
roofline terms against the recorded baseline artifact.

  PYTHONPATH=src python tools/perf_iter.py <arch> <shape> \
      [--set attention_impl=blockwise] [--set moe.routing_impl=ep_shard_map] \
      [--strategy fsdp_tp] [--save artifacts/perf/<name>.json]

Override value parsing: int/float/bool/str auto-detected; "moe.<field>" and
"ssm.<field>" nest into the sub-config.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json


def parse_val(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    return v


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("arch")
    p.add_argument("shape")
    p.add_argument("--set", action="append", default=[], dest="sets")
    p.add_argument("--strategy", default=None)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--baseline", default="artifacts/dryrun")
    p.add_argument("--save", default="")
    p.add_argument("--mode", default=None, choices=["probe", "direct"])
    args = p.parse_args()

    from repro.configs.base import get_config
    from repro.launch.dryrun import run_cell

    overrides = {}
    sub: dict = {}
    for s in args.sets:
        k, v = s.split("=", 1)
        if "." in k:
            outer, inner = k.split(".", 1)
            sub.setdefault(outer, {})[inner] = parse_val(v)
        else:
            overrides[k] = parse_val(v)
    if sub:
        cfg0 = get_config(args.arch)
        for outer, fields in sub.items():
            subcfg = getattr(cfg0, outer)
            overrides[outer] = dataclasses.replace(subcfg, **fields)

    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   strategy=args.strategy, overrides=overrides,
                   mode=args.mode)

    mesh_tag = "multi" if args.multi_pod else "single"
    base_path = os.path.join(args.baseline, mesh_tag,
                             f"{args.arch}__{args.shape}.json")
    if os.path.exists(base_path):
        with open(base_path) as f:
            base = json.load(f)
        print("\n=== delta vs baseline ===")
        for key in ("compute_s", "memory_s", "collective_s"):
            b, n = base["roofline"][key], rec["roofline"][key]
            print(f"{key:14s} {b:.4e} -> {n:.4e}  "
                  f"({(n - b) / b * 100 if b else 0:+.1f}%)")
        bm = base["memory"].get("peak_bytes_per_device", 0)
        nm = rec["memory"].get("peak_bytes_per_device", 0)
        print(f"{'peak_mem_GiB':14s} {bm/2**30:.2f} -> {nm/2**30:.2f}")
        bu = base.get("useful_flops_ratio") or 0
        nu = rec.get("useful_flops_ratio") or 0
        print(f"{'useful_ratio':14s} {bu:.3f} -> {nu:.3f}")
    if args.save:
        os.makedirs(os.path.dirname(args.save), exist_ok=True)
        with open(args.save, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"# saved {args.save}")


if __name__ == "__main__":
    main()
