"""Version-portable ``shard_map``.

API churn absorbed here:
  * location: ``jax.shard_map`` (jax >= 0.6) vs
    ``jax.experimental.shard_map.shard_map`` (<= 0.5.x);
  * the replication-check kwarg rename: ``check_vma`` (new) vs
    ``check_rep`` (old) — callers always say ``check_vma`` and we
    translate to whatever the resolved function accepts.

Every ``shard_map`` call site in the tree MUST go through
:func:`shard_map` below; a regression test scans for direct uses.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, Optional, Tuple

import jax


@functools.lru_cache(maxsize=None)
def _resolve() -> Tuple[Callable[..., Any], frozenset]:
    """Return (the real shard_map, the kwarg names it accepts)."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # type: ignore
    try:
        accepted = frozenset(inspect.signature(fn).parameters)
    except (TypeError, ValueError):  # builtins without a signature
        accepted = frozenset({"check_rep", "check_vma", "auto"})
    return fn, accepted


def shard_map(f: Callable[..., Any], mesh, in_specs, out_specs, *,
              check_vma: Optional[bool] = None, **kwargs: Any
              ) -> Callable[..., Any]:
    """Portable ``shard_map(f, mesh, in_specs, out_specs, ...)``.

    ``check_vma`` follows the newest spelling; on older JAX it is passed
    as ``check_rep``.  Unknown extra kwargs are forwarded verbatim so new
    features keep working when the pin moves forward.
    """
    fn, accepted = _resolve()
    if check_vma is not None:
        if "check_vma" in accepted:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in accepted:
            kwargs["check_rep"] = check_vma
        # neither spelling: the check is gone upstream; drop silently
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def shard_map_source() -> str:
    """Where shard_map resolved from (for describe()/diagnostics)."""
    fn, _ = _resolve()
    return f"{fn.__module__}.{fn.__qualname__}"
