"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (GQA kv=16)
d_ff=1408 (expert width) vocab=163840, MoE 64e top-6 + 2 shared experts.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163_840,
    activation="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared_experts=2),
    source="hf:moonshotai/Moonlight-16B-A3B",
)

SMOKE = ModelConfig(
    name="moonshot-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab=256,
    activation="swiglu",
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared_experts=1),
    dtype="float32",
)
