"""Operator lifecycle conformance (paper §5.1 semantics)."""
import dataclasses
import time

import pytest

from repro.core import (BridgeEnvironment, BridgeJob, DONE, FAILED, KILLED,
                        PENDING, RUNNING, SUBMITTED, UNKNOWN,
                        ValidationError)


@pytest.fixture()
def env():
    with BridgeEnvironment(default_duration=0.05) as e:
        yield e


def test_submit_and_complete_slurm(env):
    spec = env.make_spec("slurm", script="#!/bin/bash\nsrun hostname\n",
                         jobproperties={"NodesNumber": "1", "Queue": "V100",
                                        "OutputFileName": "slurmjob.out"})
    env.submit("slurmjob-test", spec)
    job = env.operator.wait_for("slurmjob-test", timeout=20)
    assert job.status.state == DONE
    assert job.status.job_id != ""
    assert job.status.start_time is not None
    assert job.status.end_time is not None
    assert job.status.end_time >= job.status.start_time


def test_failed_job_reported(env):
    spec = env.make_spec("slurm", script="exit 1",
                         jobproperties={"FailMe": "true"})
    env.submit("failjob", spec)
    job = env.operator.wait_for("failjob", timeout=20)
    assert job.status.state == FAILED
    assert "FailMe" in job.status.message


def test_kill_signal(env):
    spec = env.make_spec("slurm", script="sleep", updateinterval=0.02,
                         jobproperties={"WallSeconds": "5"})
    env.submit("killme", spec)
    # wait until running, then send kill via CR update (paper mechanism)
    deadline = time.time() + 10
    while time.time() < deadline:
        job = env.registry.get("killme")
        if job.status.state in (SUBMITTED, RUNNING) and job.status.job_id:
            break
        time.sleep(0.01)
    env.operator.kill("killme")
    job = env.operator.wait_for("killme", timeout=20)
    assert job.status.state == KILLED
    assert time.time() < deadline + 10, "kill should beat the 5s wallclock"


def test_delete_cleans_up(env):
    spec = env.make_spec("slurm", script="x", jobproperties={"WallSeconds": "3"})
    env.submit("gcjob", spec)
    deadline = time.time() + 10
    while time.time() < deadline:
        if env.statestore.exists(env.operator.cm_name(env.registry.get("gcjob"))):
            break
        time.sleep(0.01)
    job = env.registry.get("gcjob")
    cm_name = env.operator.cm_name(job)
    env.registry.delete("gcjob")
    deadline = time.time() + 10
    while time.time() < deadline:
        if (not env.statestore.exists(cm_name)
                and env.registry.get("gcjob") is None):
            break
        time.sleep(0.01)
    assert not env.statestore.exists(cm_name), "config map must be GC'd"
    assert env.registry.get("gcjob") is None, "CR must be purged"


def test_spec_validation():
    from repro.core.resource import BridgeJobSpec, JobData

    with pytest.raises(ValidationError):
        BridgeJobSpec(resourceURL="", image="x", resourcesecret="s").validate()
    with pytest.raises(ValidationError):
        BridgeJobSpec(resourceURL="u", image="x", resourcesecret="s",
                      jobdata=JobData(scriptlocation="ftp")).validate()
    with pytest.raises(ValidationError):
        # s3 script without s3storage
        BridgeJobSpec(resourceURL="u", image="x", resourcesecret="s",
                      jobdata=JobData(jobscript="b:k", scriptlocation="s3")
                      ).validate()


def test_cr_dict_roundtrip():
    from repro.core.resource import BridgeJob, load_bridgejob
    import json

    env_spec = {
        "kind": "BridgeJob",
        "apiVersion": "bridgeoperator.repro/v1alpha1",
        "metadata": {"name": "slurmjob-test"},
        "spec": {
            "resourceURL": "http://my-slurm-cluster@hpc.com",
            "image": "slurmpod:0.1",
            "resourcesecret": "mysecret",
            "imagepullpolicy": "Always",
            "updateinterval": 20,
            "jobdata": {"jobscript": "mys3bucket:slurmbatch.sh",
                        "scriptlocation": "s3"},
            "jobproperties": {"NodesNumber": "1", "Queue": "V100"},
            "s3storage": {"s3secret": "mysecret-s3",
                          "endpoint": "s3endpoint.cloud", "secure": False},
        },
    }
    job = load_bridgejob(json.dumps(env_spec))
    assert job.name == "slurmjob-test"
    assert job.spec.jobdata.scriptlocation == "s3"
    d = job.to_dict()
    job2 = BridgeJob.from_dict(d)
    assert job2.spec == job.spec


def test_status_unknown_on_outage(env):
    """Paper/black-box honesty: unreachable resource -> UNKNOWN, not FAILED."""
    spec = env.make_spec("lsf", script="job", updateinterval=0.02,
                         jobproperties={"WallSeconds": "5"}, unknown_after=3)
    env.submit("outage", spec)
    deadline = time.time() + 10
    while time.time() < deadline:
        job = env.registry.get("outage")
        if job.status.state == RUNNING:
            break
        time.sleep(0.01)
    env.servers["lsf"].fault.begin_outage()
    deadline = time.time() + 10
    while time.time() < deadline:
        job = env.registry.get("outage")
        if job.status.state == UNKNOWN:
            break
        time.sleep(0.01)
    assert env.registry.get("outage").status.state == UNKNOWN
    # network heals -> status recovers, job completes
    env.servers["lsf"].fault.end_outage()
    job = env.operator.wait_for("outage", timeout=20)
    assert job.status.state == DONE
