#!/usr/bin/env python
"""Control-plane scale benchmark: pod-per-cr vs multiplexed.

Measures, for {1, 64, 256}-index SLURM arrays and for {1, 16, 64} concurrent
CRs, in BOTH operator modes:

  * monitor thread count (peak)   — pod-per-cr grows with CR count,
                                    multiplexed stays at the pool size
  * REST requests (total + /tick) — batched BATCH_STATUS polling vs the
                                    per-index baseline
  * config-map flushes            — write-coalesced store + monitor diff vs
                                    the always-write baseline
  * CR-create -> DONE wall time   — the single-job case guards against a
                                    latency regression

Baselines are the SAME code with the optimisation switched off (an adapter
withholding Capability.BATCH_STATUS; StateStore(coalesce=False) plus
JobProtocol.COALESCE_WRITES=False), so every delta is attributable.

The event-driven scenario (``cr_scaling_event``) additionally runs a
1000-CR fleet (32 in --smoke) on one endpoint under each poll cadence —
fixed vs adaptive vs watch — measuring p50/p99 status staleness, requests
per CR-tick, per-route server counters, and peak monitor threads, and
asserts the adaptive/watch savings right where they are measured.

Emits BENCH_bridge_scale.json (committed at the repo root; CI uploads the
--smoke variant as an artifact).  See docs/perf.md for the methodology and
the resulting before/after table.
"""
from __future__ import annotations

import json
import os
import queue
import statistics
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import make_parser, percentile, pick
from repro.core import (ArraySpec, BATCH_STATUS_CHUNK, BridgeEnvironment,
                        DONE)
from repro.core.backends import base as B
from repro.core.backends.slurm import SlurmAdapter
from repro.core.controller import JobProtocol

MODES = ("pod-per-cr", "multiplexed")


class PerIndexSlurmAdapter(SlurmAdapter):
    """Baseline adapter: same dialect, BATCH_STATUS withheld, so the monitor
    polls one request per index per tick (the pre-optimisation shape)."""
    capabilities = SlurmAdapter.capabilities - {B.Capability.BATCH_STATUS}


class CountingSlurmAdapter(SlurmAdapter):
    """Instrumented adapter for the large-array wakeup scenario: counts how
    many JOB IDS each status fetch touches (one per single status, the chunk
    size per BATCH_STATUS), so the id-filtered wakeup claim — a drain tick
    polls only the CHANGED indices — is measured, not inferred."""
    ids_polled = 0
    _count_mu = threading.Lock()

    def status(self, job_id):
        with CountingSlurmAdapter._count_mu:
            CountingSlurmAdapter.ids_polled += 1
        return super().status(job_id)

    def status_batch(self, job_ids):
        with CountingSlurmAdapter._count_mu:
            CountingSlurmAdapter.ids_polled += len(job_ids)
        return super().status_batch(job_ids)


def _monitor_threads() -> int:
    """Threads doing monitor work: controller pods + runtime pool workers."""
    return sum(1 for t in threading.enumerate()
               if t.name.startswith(("pod-", "bridge-monitor")))


def run_case(mode: str, count: int = 1, crs: int = 1, *, batched: bool = True,
             coalesced: bool = True, duration: float = 0.3,
             interval: float = 0.02, label: str = "") -> dict:
    """One measured scenario: ``crs`` CRs of ``count``-index SLURM arrays,
    run to DONE under ``mode``."""
    prev_coalesce = JobProtocol.COALESCE_WRITES  # process-wide switch
    JobProtocol.COALESCE_WRITES = coalesced
    env = BridgeEnvironment(slots=max(count, crs, 4),
                            default_duration=duration,
                            operator_kwargs={"mode": mode})
    try:
        if not batched:
            env.operator.adapters[PerIndexSlurmAdapter.image] = \
                PerIndexSlurmAdapter
        env.statestore.coalesce = coalesced
        env.start()
        srv = env.servers["slurm"]
        req0, flush0 = srv.request_count, env.statestore.flush_count
        t0 = time.time()
        handles = [env.bridge.submit(f"bench-{i}", env.make_spec(
            "slurm", script="bench", updateinterval=interval,
            jobproperties={"WallSeconds": str(duration)},
            array=ArraySpec(count=count) if count > 1 else None))
            for i in range(crs)]
        peak_threads = 0
        pending = list(handles)
        deadline = t0 + 300
        while pending and time.time() < deadline:
            peak_threads = max(peak_threads, _monitor_threads())
            pending = [h for h in pending
                       if not (h.job() and h.job().status.terminal())]
            time.sleep(0.01)
        elapsed = time.time() - t0
        states = [h.job().status.state for h in handles]
        if not all(s == DONE for s in states):
            raise RuntimeError(f"benchmark jobs did not all finish: {states}")
        requests = srv.request_count - req0
        flushes = env.statestore.flush_count - flush0
        ticks = max(elapsed / interval, 1.0)
        return {
            "label": label or f"{mode}/{count}ix{crs}cr",
            "mode": mode, "array_count": count, "crs": crs,
            "batched_status": batched, "coalesced_writes": coalesced,
            "wall_time_s": round(elapsed, 3),
            "rest_requests": requests,
            "rest_requests_per_tick": round(requests / ticks, 2),
            "cm_flushes": flushes,
            "monitor_threads_peak": peak_threads,
            "ticks_est": round(ticks, 1),
        }
    finally:
        env.stop()
        JobProtocol.COALESCE_WRITES = prev_coalesce


def run_sliced_case(mode: str, count: int, *, slurm_slots: int = 8,
                    lsf_slots: int = 4, interval: float = 0.02,
                    duration: float = 0.3) -> dict:
    """Sharded placement scenario: one ``count``-index array spread across
    TWO uneven resources (slurm vs lsf, ``slurm_slots`` vs ``lsf_slots``),
    run to DONE.  Reports the load-proportional split, wall time, and — for
    the aggregate-capacity story — the wall time of the same array pinned to
    the slurm resource alone."""
    from repro.core import IMAGES, PlacementCandidate, PlacementSpec, URLS

    def run(placed: bool) -> dict:
        env = BridgeEnvironment(slots=slurm_slots, default_duration=duration,
                                operator_kwargs={"mode": mode})
        try:
            env.clusters["lsf"].slots = lsf_slots
            env.start()
            placement = PlacementSpec(candidates=[
                PlacementCandidate(URLS[k], IMAGES[k], f"{k}-secret")
                for k in ("slurm", "lsf")], strategy="spread") if placed \
                else None
            t0 = time.time()
            h = env.bridge.submit("sliced", env.make_spec(
                "slurm", script="bench", updateinterval=interval,
                jobproperties={"WallSeconds": str(duration)},
                array=ArraySpec(count=count), placement=placement))
            job = h.wait(timeout=600)
            elapsed = time.time() - t0
            if job.status.state != DONE:
                raise RuntimeError(
                    f"sliced benchmark did not finish: {job.status.state} "
                    f"{job.status.message}")
            return {"wall_time_s": round(elapsed, 3),
                    "split": {k: len(env.clusters[k].jobs)
                              for k in ("slurm", "lsf")}}
        finally:
            env.stop()

    sliced = run(placed=True)
    pinned = run(placed=False)
    expect_slurm = round(count * slurm_slots / (slurm_slots + lsf_slots))
    if sliced["split"]["slurm"] != expect_slurm:
        raise RuntimeError(f"split not load-proportional: {sliced['split']} "
                           f"(expected {expect_slurm} on slurm)")
    return {
        "label": f"{mode}/sliced-{count}ix-{slurm_slots}v{lsf_slots}",
        "mode": mode, "array_count": count,
        "slots": {"slurm": slurm_slots, "lsf": lsf_slots},
        "split": sliced["split"],
        "wall_time_s_sliced": sliced["wall_time_s"],
        "wall_time_s_single_resource": pinned["wall_time_s"],
        "speedup_x": round(pinned["wall_time_s"]
                           / max(sliced["wall_time_s"], 1e-9), 2),
    }


def run_service_case(mode: str, *, replicas: int = 4, threads: int = 4,
                     warm_s: float = 1.0, post_s: float = 1.0,
                     interval: float = 0.02) -> dict:
    """BridgeService serving scenario: ``replicas`` echo replicas spread
    over TWO resource managers, a thread pool driving the request router,
    one replica killed mid-traffic.  Measures request throughput, p50/p99
    latency, and time-to-recover (kill -> replacement ready), and asserts
    the serving contract right here: zero lost requests, zero requests
    routed to the dead replica after its endpoint is dropped."""
    from repro.core import (HealthProbeSpec, IMAGES, PlacementCandidate,
                            PlacementSpec, URLS)

    env = BridgeEnvironment(slots=max(replicas * 2, 8),
                            operator_kwargs={"mode": mode})
    try:
        env.start()
        health = HealthProbeSpec(failure_threshold=3,
                                 startup_failure_threshold=50)
        placement = PlacementSpec(candidates=[
            PlacementCandidate(URLS[k], IMAGES[k], f"{k}-secret")
            for k in ("slurm", "lsf")], strategy="spread")
        h = env.bridge.submit_service("svc-bench", env.make_service_spec(
            "slurm", replicas=replicas, script="serve",
            updateinterval=interval, health=health, placement=placement))
        h.wait_ready(timeout=60)
        split = {}
        for e in h.endpoints():
            kind = "slurm" if e["resourceURL"] == URLS["slurm"] else "lsf"
            split[kind] = split.get(kind, 0) + 1
        if len(split) < 2:
            raise RuntimeError(f"replicas not spread over 2 managers: {split}")

        router = h.router(request_timeout=30)
        stop = threading.Event()
        lock = threading.Lock()
        lat: list = []
        failures: list = []

        def traffic(tid: int) -> None:
            i = 0
            while not stop.is_set():
                t0 = time.time()
                try:
                    out = router.request({"t": tid, "i": i})
                    if out["echo"] != {"t": tid, "i": i}:
                        with lock:
                            failures.append(("bad-echo", out))
                    else:
                        with lock:
                            lat.append(time.time() - t0)
                except Exception as exc:
                    with lock:
                        failures.append(("error", repr(exc)))
                i += 1

        t_start = time.time()
        ths = [threading.Thread(target=traffic, args=(t,))
               for t in range(threads)]
        for t in ths:
            t.start()
        time.sleep(warm_s)

        victim = h.endpoints()[0]
        vkind = "slurm" if victim["resourceURL"] == URLS["slurm"] else "lsf"
        vjob = env.clusters[vkind].jobs[victim["job_id"]]
        t_kill = time.time()
        env.clusters[vkind].cancel_if_live(victim["job_id"])
        deadline = time.time() + 60
        while time.time() < deadline:
            if (victim["job_id"] not in
                    [e["job_id"] for e in h.endpoints()]
                    and h.ready_replicas() == replicas):
                break
            time.sleep(0.005)
        recovery = time.time() - t_kill
        if h.ready_replicas() != replicas:
            raise RuntimeError(
                f"service never recovered: ready={h.ready_replicas()}")
        # drain window, then snapshot: anything the router sends the dead
        # replica from here on is a routing-to-condemned bug
        time.sleep(0.05)
        attempted_at_drop = router.stats().get(
            victim["job_id"], {}).get("requests", 0)
        delivered_at_drop = vjob.invocations

        time.sleep(post_s)
        stop.set()
        for t in ths:
            t.join(timeout=60)
        elapsed = time.time() - t_start

        # delivered is the contract: the condemned replica never SERVES
        # another request.  A stale *attempt* is legal — a client thread may
        # have resolved the endpoint list just before the kill and only get
        # scheduled again much later; its attempt faults on the dead replica
        # and is retried on a survivor (the at-least-once delivery contract),
        # which the zero-failed-requests assert above already covers.
        routed_dead = (router.stats().get(victim["job_id"], {})
                       .get("requests", 0) - attempted_at_drop)
        delivered_dead = vjob.invocations - delivered_at_drop
        if failures:
            raise RuntimeError(
                f"lost/failed requests under replica kill: {failures[:3]}")
        if delivered_dead:
            raise RuntimeError(
                f"requests delivered to the dead replica after its drop: "
                f"{delivered_dead}")
        # a DEAD replica (terminal remote job) is detected by the very next
        # status poll — budget it like the probe path plus generous slack
        budget = health.failure_threshold * interval + 5.0
        if recovery > budget:
            raise RuntimeError(
                f"recovery took {recovery:.2f}s (budget {budget:.2f}s)")

        lat.sort()
        return {
            "label": f"{mode}/service-{replicas}rep",
            "mode": mode, "replicas": replicas, "threads": threads,
            "replica_split": split,
            "requests_total": len(lat),
            "errors": len(failures),
            "throughput_rps": round(len(lat) / elapsed, 1),
            "latency_p50_ms": round(percentile(lat, 0.5) * 1e3, 3)
                if lat else None,
            "latency_p99_ms": round(percentile(lat, 0.99) * 1e3, 3)
                if lat else None,
            "recovery_s": round(recovery, 3),
            "requests_to_dead_after_drop": delivered_dead,
            "stale_attempts_after_drop": routed_dead,
        }
    finally:
        env.stop()


def run_autoscale_case(mode: str, *, min_replicas: int = 2,
                       max_replicas: int = 8, threads: int = 16,
                       serve_latency: float = 0.05,
                       up_cooldown: float = 0.15, down_cooldown: float = 0.3,
                       light_s: float = 1.5, heavy_s: float = 1.5,
                       interval: float = 0.02) -> dict:
    """Load-driven autoscaling scenario (``spec.autoscale``): replicas
    spread over TWO resource managers, request load ramped up in two stages
    (light -> ``threads`` concurrent clients, a ~4x swing against the
    outstanding-per-replica target) and then dropped to zero.  Measures the
    scale-up/scale-down tracking latency and asserts the tentpole contract
    right here: replicas reach ``maxReplicas`` within the cooldown budget,
    fall back to ``minReplicas`` once the routers go quiet, and no request
    is lost across any resize or drain."""
    from repro.core import (AutoscaleSpec, HealthProbeSpec, IMAGES,
                            PlacementCandidate, PlacementSpec, URLS)

    env = BridgeEnvironment(slots=max_replicas * 2,
                            operator_kwargs={"mode": mode})
    try:
        env.start()
        autoscale = AutoscaleSpec(
            min_replicas=min_replicas, max_replicas=max_replicas,
            target_outstanding_per_replica=1.0,
            scale_up_cooldown_seconds=up_cooldown,
            scale_down_cooldown_seconds=down_cooldown)
        placement = PlacementSpec(candidates=[
            PlacementCandidate(URLS[k], IMAGES[k], f"{k}-secret")
            for k in ("slurm", "lsf")], strategy="spread")
        h = env.bridge.submit_service("svc-autoscale", env.make_service_spec(
            "slurm", replicas=min_replicas, script="serve",
            updateinterval=interval,
            health=HealthProbeSpec(failure_threshold=3,
                                   startup_failure_threshold=50),
            jobproperties={"ServeLatency": str(serve_latency)},
            placement=placement, autoscale=autoscale))
        h.wait_ready(timeout=60)
        router = h.router(request_timeout=60, report_interval=0.1)

        stop = threading.Event()
        gate = threading.Semaphore(0)  # admits traffic threads in stages
        lock = threading.Lock()
        failures: list = []
        done: list = []

        def traffic(tid: int) -> None:
            gate.acquire()
            i = 0
            while not stop.is_set():
                try:
                    out = router.request({"t": tid, "i": i})
                    if out["echo"] != {"t": tid, "i": i}:
                        with lock:
                            failures.append(("bad-echo", out))
                    else:
                        with lock:
                            done.append(1)
                except Exception as exc:
                    with lock:
                        failures.append(("error", repr(exc)))
                i += 1

        ths = [threading.Thread(target=traffic, args=(t,))
               for t in range(threads)]
        for t in ths:
            t.start()

        # stage 1: light load (a quarter of the clients)
        gate.release(max(threads // 4, 1))
        time.sleep(light_s)
        replicas_light = h.ready_replicas()

        # stage 2: full load — the ~4x ramp the autoscaler must chase to max
        t_ramp = time.time()
        gate.release(threads - max(threads // 4, 1))
        up_deadline = time.time() + 60
        while time.time() < up_deadline:
            if h.ready_replicas() == max_replicas:
                break
            time.sleep(0.01)
        ramp_to_max = time.time() - t_ramp
        if h.ready_replicas() != max_replicas:
            raise RuntimeError(
                f"autoscale never reached max under full load: "
                f"ready={h.ready_replicas()} status={h.autoscale_status()}")
        # straight-to-target scaling: the whole ramp is a handful of cooldown-
        # gated decisions plus replica spin-up; budget it with CI slack
        up_budget = max_replicas * up_cooldown + 10.0
        if ramp_to_max > up_budget:
            raise RuntimeError(f"scale-up took {ramp_to_max:.2f}s "
                               f"(budget {up_budget:.2f}s)")
        time.sleep(heavy_s)

        # stage 3: idle — reports expire, the service must fall to the floor
        t_idle = time.time()
        stop.set()
        for t in ths:
            t.join(timeout=60)
        down_deadline = time.time() + 60
        while time.time() < down_deadline:
            if h.ready_replicas() == min_replicas:
                break
            time.sleep(0.01)
        idle_to_min = time.time() - t_idle
        if h.ready_replicas() != min_replicas:
            raise RuntimeError(
                f"autoscale never returned to min when idle: "
                f"ready={h.ready_replicas()} status={h.autoscale_status()}")
        # report TTL (staleness bound) + down cooldown + drain, with slack
        down_budget = 1.0 + down_cooldown + 10.0
        if idle_to_min > down_budget:
            raise RuntimeError(f"scale-down took {idle_to_min:.2f}s "
                               f"(budget {down_budget:.2f}s)")
        if failures:
            raise RuntimeError(
                f"lost/failed requests across the ramp: {failures[:3]}")

        status = h.autoscale_status()
        return {
            "label": f"{mode}/autoscale-{min_replicas}to{max_replicas}",
            "mode": mode,
            "min_replicas": min_replicas, "max_replicas": max_replicas,
            "threads": threads,
            "target_outstanding_per_replica": 1.0,
            "up_cooldown_s": up_cooldown, "down_cooldown_s": down_cooldown,
            "replicas_light_load": replicas_light,
            "reached_max": True, "returned_to_min": True,
            "ramp_to_max_s": round(ramp_to_max, 3),
            "idle_to_min_s": round(idle_to_min, 3),
            "requests_total": len(done),
            "errors": len(failures),
            "final_desired": status.get("desired"),
        }
    finally:
        env.stop()


def _coarse_payload(job, cluster) -> int:
    """Event-wait job body for the large-fleet scenarios: identical
    semantics to sleep_payload's run-for-WallSeconds, but waiting on the
    cancel event at 2s granularity instead of 5ms polling — ten thousand
    concurrent payload threads must not spend the benchmark context-
    switching.  End times stay exact (the final wait is ``remaining``);
    only cancel NOTICE is coarse, and these jobs run to completion.
    ``PerIndexWall`` in the job params (the indexed_params overlay)
    overrides WallSeconds so one array can drain index by index."""
    dur = float(job.params.get("PerIndexWall")
                or job.properties.get("WallSeconds", cluster.default_duration))
    deadline = time.time() + dur
    while True:
        remaining = deadline - time.time()
        if remaining <= 0:
            return 0
        if job._cancel.wait(min(remaining, 2.0)):
            return -1


def run_event_case(cadence: str, crs: int, *, interval: float,
                   dur_lo: float, dur_hi: float, workers: int = 8,
                   slots: int = 0, reconcile: float = 0.05) -> dict:
    """Event-driven control-plane scenario: ``crs`` single-job SLURM CRs in
    multiplexed mode under one cadence ("fixed" | "adaptive" | "watch" |
    "wakeup"), with staggered durations sharing a long common RUNNING
    plateau.

    Measures what the tentpole claims: p50/p99 STATUS STALENESS (cluster-side
    end_time -> the CR status first observed terminal, via a registry watch),
    REST requests per CR-tick, per-route server counters, peak monitor
    threads, runtime wakeup counters, and lost/duplicated terminal
    transitions as a watch consumer sees them — then the caller asserts the
    event-driven modes actually pay off vs their baseline.
    """
    env = BridgeEnvironment(
        slots=slots or crs, default_duration=dur_hi,
        operator_kwargs={"mode": "multiplexed", "cadence": cadence,
                         "monitor_workers": workers,
                         "reconcile_interval": reconcile})
    try:
        env.clusters["slurm"].payload = _coarse_payload
        env.start()
        srv = env.servers["slurm"]
        req0 = srv.request_count
        stats0 = srv.stats

        # registry-side terminal observer: the first moment each CR's
        # status turns terminal, as a consumer of the watch stream sees it —
        # plus every ENTRY into a terminal state, so a lost transition
        # (never observed terminal) or a duplicated one (terminal ->
        # non-terminal -> terminal flap) is caught at the consumer, where
        # it would actually mislead a client
        events = env.registry.watch(include_existing=False)
        terminal_seen: dict = {}
        terminal_entries: dict = {}
        was_terminal: set = set()
        stop_consumer = threading.Event()

        def consume() -> None:
            while True:
                try:
                    _, job = events.get(timeout=0.2)
                except queue.Empty:
                    if stop_consumer.is_set():
                        return
                    continue
                if job.status.terminal():
                    if job.uid not in was_terminal:
                        was_terminal.add(job.uid)
                        terminal_entries[job.uid] = \
                            terminal_entries.get(job.uid, 0) + 1
                        terminal_seen.setdefault(job.uid, time.time())
                else:
                    was_terminal.discard(job.uid)

        consumer = threading.Thread(target=consume, daemon=True,
                                    name="bench-staleness-observer")
        consumer.start()

        t0 = time.time()
        handles = [env.bridge.submit(f"ev-{i}", env.make_spec(
            "slurm", script="bench", updateinterval=interval,
            jobproperties={"WallSeconds":
                           str(dur_lo + (dur_hi - dur_lo) * i / max(crs - 1, 1))}))
            for i in range(crs)]
        peak_threads = 0
        pending = list(handles)
        # convergence guard, not a measured quantity: scale with the
        # scenario (the 10k rows run a 50-100s staggered plateau plus a
        # submission ramp; 300s would sit right on the watch row's edge)
        deadline = t0 + max(300.0, dur_hi * 5)
        while pending and time.time() < deadline:
            peak_threads = max(peak_threads, _monitor_threads())
            pending = [h for h in pending
                       if not (h.job() and h.job().status.terminal())]
            # the observer must not starve the system under test: at 10k
            # CRs a 50ms full re-scan of the pending handles is ~200k
            # registry reads/s on one core — more CPU than the monitor
            # pool gets.  Back off while the pending set is large.
            time.sleep(0.05 if len(pending) < 1024 else 1.0)
        elapsed = time.time() - t0
        states = [h.job().status.state for h in handles]
        if not all(s == DONE for s in states):
            bad = [s for s in states if s != DONE]
            raise RuntimeError(f"event scenario: {len(bad)} CRs not DONE "
                               f"(e.g. {bad[:3]})")
        rt = env.operator.runtime.stats()  # before stop() kills the watchers
        stop_consumer.set()
        consumer.join(timeout=2)
        env.registry.unwatch(events)

        # staleness: cluster-side terminal transition -> registry observer
        jobs = env.clusters["slurm"].jobs
        stale = []
        for h in handles:
            job = h.job()
            jid = job.status.job_id
            seen = terminal_seen.get(job.uid)
            end = jobs[jid].end_time if jid in jobs else None
            if seen is not None and end is not None:
                stale.append(seen - end)
        if len(stale) < crs * 0.95:
            raise RuntimeError(f"staleness samples missing: {len(stale)}/{crs}")
        stale.sort()
        p50 = percentile(stale, 0.5)
        p99 = percentile(stale, 0.99)

        requests = srv.request_count - req0
        # nominal tick budget: what a fixed cadence would spend
        ticks = crs * max(elapsed / interval, 1.0)
        route_delta = {
            k: v["requests"] - stats0.get(k, {}).get("requests", 0)
            for k, v in srv.stats.items()}
        return {
            "label": f"{cadence}/{crs}cr-event",
            "cadence": cadence, "crs": crs, "interval": interval,
            "duration_range_s": [dur_lo, dur_hi],
            "wall_time_s": round(elapsed, 3),
            "rest_requests": requests,
            "rest_requests_per_cr_tick": round(requests / ticks, 4),
            "status_staleness_p50_s": round(p50, 3),
            "status_staleness_p99_s": round(p99, 3),
            "monitor_threads_peak": peak_threads,
            "monitor_workers": workers,
            "watcher_threads": rt["watcher_threads"],
            "wakeup_latency_p99_s": (
                round(rt["wakeup_latency_p99_s"], 4)
                if rt["wakeup_latency_p99_s"] is not None else None),
            "pokes_delivered": rt["pokes_delivered"],
            "pokes_coalesced": rt["pokes_coalesced"],
            "stale_drops": rt["stale_drops"],
            "terminal_transitions_lost": crs - len(terminal_seen),
            "terminal_transitions_duplicated": sum(
                1 for c in terminal_entries.values() if c > 1),
            "server_stats": {k: v for k, v in sorted(route_delta.items())
                             if v},
        }
    finally:
        env.stop()


def run_array_event_case(cadence: str, crs: int, count: int, *,
                         interval: float, dur_lo: float, dur_hi: float,
                         slots: int, workers: int = 8) -> dict:
    """Large-array wakeup scenario: ``crs`` CRs of ``count``-index SLURM
    arrays whose indices drain a few at a time (per-index staggered
    durations via the indexed_params overlay).  Under the wakeup cadence
    the event payload names WHICH job ids changed, so a drain tick's
    BATCH_STATUS touches only the changed indices; under the watch cadence
    every version bump re-polls every live index of every chain.  The
    difference is measured as ``ids_polled`` through an instrumented
    adapter, not inferred from request counts."""
    CountingSlurmAdapter.ids_polled = 0
    env = BridgeEnvironment(
        slots=slots, default_duration=dur_hi,
        operator_kwargs={"mode": "multiplexed", "cadence": cadence,
                         "monitor_workers": workers,
                         "reconcile_interval": 0.05})
    try:
        env.clusters["slurm"].payload = _coarse_payload
        env.operator.adapters[CountingSlurmAdapter.image] = \
            CountingSlurmAdapter
        env.start()
        srv = env.servers["slurm"]
        req0 = srv.request_count
        stats0 = srv.stats
        step = (dur_hi - dur_lo) / max(count - 1, 1)
        indexed = [{"PerIndexWall": str(round(dur_lo + step * i, 3))}
                   for i in range(count)]
        t0 = time.time()
        handles = [env.bridge.submit(f"arr-{i}", env.make_spec(
            "slurm", script="bench", updateinterval=interval,
            array=ArraySpec(count=count, indexed_params=indexed)))
            for i in range(crs)]
        peak_threads = 0
        pending = list(handles)
        deadline = t0 + 600
        while pending and time.time() < deadline:
            peak_threads = max(peak_threads, _monitor_threads())
            pending = [h for h in pending
                       if not (h.job() and h.job().status.terminal())]
            time.sleep(0.05)
        elapsed = time.time() - t0
        states = [h.job().status.state for h in handles]
        if not all(s == DONE for s in states):
            bad = [s for s in states if s != DONE]
            raise RuntimeError(f"array event scenario: {len(bad)} CRs not "
                               f"DONE (e.g. {bad[:3]})")
        rt = env.operator.runtime.stats()  # before stop() kills the watchers
        requests = srv.request_count - req0
        route_delta = {
            k: v["requests"] - stats0.get(k, {}).get("requests", 0)
            for k, v in srv.stats.items()}
        ids = CountingSlurmAdapter.ids_polled
        return {
            "label": f"{cadence}/{crs}x{count}ix-array-event",
            "cadence": cadence, "crs": crs, "array_count": count,
            "interval": interval, "duration_range_s": [dur_lo, dur_hi],
            "wall_time_s": round(elapsed, 3),
            "rest_requests": requests,
            "ids_polled": ids,
            "ids_polled_per_index": round(ids / (crs * count), 2),
            "monitor_threads_peak": peak_threads,
            "monitor_workers": workers,
            "watcher_threads": rt["watcher_threads"],
            "wakeup_latency_p99_s": (
                round(rt["wakeup_latency_p99_s"], 4)
                if rt["wakeup_latency_p99_s"] is not None else None),
            "server_stats": {k: v for k, v in sorted(route_delta.items())
                             if v},
        }
    finally:
        env.stop()


def run_resize_case(mode: str, start: int, up: int, down: int, *,
                    interval: float = 0.02) -> dict:
    """Elastic-array resize scenario: scale a live ``start``-index array to
    ``up`` then ``down``, measuring the reconcile latency of each patch and
    checking the exact submit/cancel delta (no live index resubmitted)."""
    env = BridgeEnvironment(slots=4, default_duration=600,
                            operator_kwargs={"mode": mode})
    try:
        env.start()
        srv = env.servers["slurm"]
        h = env.bridge.submit("resize", env.make_spec(
            "slurm", script="bench", updateinterval=interval,
            jobproperties={"WallSeconds": "600"},
            array=ArraySpec(count=start)))
        deadline = time.time() + 120
        while (len([s for s in h.status().job_id.split(",") if s]) < start
               and time.time() < deadline):
            time.sleep(0.005)
        req0 = srv.request_count
        t0 = time.time()
        h.scale(up)
        h.wait_reconciled(timeout=120)
        t_up = time.time() - t0
        t0 = time.time()
        h.scale(down)
        h.wait_reconciled(timeout=120)
        t_down = time.time() - t0
        jobs = env.clusters["slurm"].jobs
        live = sum(1 for j in jobs.values()
                   if j.state in (B.QUEUED, B.RUNNING))
        cancelled = sum(1 for j in jobs.values() if j.state == B.CANCELLED)
        if len(jobs) != up or cancelled != up - down or live != down:
            raise RuntimeError(
                f"resize delta wrong: {len(jobs)} submitted (want {up}), "
                f"{cancelled} cancelled (want {up - down}), {live} live "
                f"(want {down})")
        return {
            "label": f"{mode}/resize-{start}-{up}-{down}",
            "mode": mode, "start": start, "up": up, "down": down,
            "scale_up_latency_s": round(t_up, 3),
            "scale_down_latency_s": round(t_down, 3),
            "rest_requests": srv.request_count - req0,
            "submitted_total": len(jobs), "cancelled_total": cancelled,
        }
    finally:
        env.stop()


def run_failover_case(mode: str, *, count: int = 16, threshold: int = 3,
                      interval: float = 0.02, duration: float = 1.0) -> dict:
    """Slice-failover chaos scenario: a ``count``-index array spread over
    TWO resources, one killed mid-array (endpoint blackout + power-off).
    Measures detection latency (kill -> LOST recorded in the cm) and
    evacuation latency (kill -> CR DONE again), and asserts the recovery
    contract right here: zero lost indices, zero duplicated completions,
    detection within the policy budget."""
    from repro.core import (FailoverSpec, FaultProfile, IMAGES,
                            PlacementCandidate, PlacementSpec, URLS)

    fp = FaultProfile(seed=42)
    env = BridgeEnvironment(slots=max(count, 8), default_duration=duration,
                            fault_profiles={"slurm": fp},
                            operator_kwargs={"mode": mode})
    try:
        env.start()
        placement = PlacementSpec(
            candidates=[PlacementCandidate(URLS[k], IMAGES[k], f"{k}-secret")
                        for k in ("slurm", "lsf")],
            strategy="spread",
            failover=FailoverSpec(enabled=True,
                                  unreachable_threshold=threshold))
        h = env.bridge.submit("failover", env.make_spec(
            "slurm", script="bench", updateinterval=interval,
            jobproperties={"WallSeconds": str(duration)},
            array=ArraySpec(count=count), placement=placement))
        cm_name = "default/failover-bridge-cm"
        deadline = time.time() + 120
        while (len([s for s in h.status().job_id.split(",") if s]) < count
               and time.time() < deadline):
            time.sleep(0.005)

        # kill one of the two resources mid-array
        t_kill = time.time()
        fp.schedule_blackout()
        env.clusters["slurm"].power_off()

        # detection: the LOST flag landing in the persisted slice defs
        t_detect = None
        deadline = time.time() + 120
        while time.time() < deadline:
            defs = json.loads(env.statestore.get(cm_name).get("slices")
                              or "[]")
            if any(d.get("lost") for d in defs):
                t_detect = time.time()
                break
            time.sleep(0.002)
        if t_detect is None:
            raise RuntimeError("failover never detected the dead slice")

        job = h.wait(timeout=300)
        t_done = time.time()
        if job.status.state != DONE:
            raise RuntimeError(f"failover scenario did not finish: "
                               f"{job.status.state} {job.status.message}")

        # the chaos invariant: every index completed exactly once while live
        runs: dict = {}
        for kind in ("slurm", "lsf"):
            for j in env.clusters[kind].jobs.values():
                if j.state != B.COMPLETED:
                    continue
                p = j.params
                idx = int(p.get("SLURM_ARRAY_TASK_ID",
                          p.get("BRIDGE_ARRAY_INDEX",
                                int(p.get("LSB_JOBINDEX", 0)) - 1)))
                runs[idx] = runs.get(idx, 0) + 1
        missing = [i for i in range(count) if i not in runs]
        duplicated = {i: n for i, n in runs.items() if n != 1}
        if missing or duplicated:
            raise RuntimeError(f"failover lost/duplicated indices: "
                               f"missing={missing} dup={duplicated}")
        # evacuated = the dead resource's unfinished indices (the ones that
        # had to re-run elsewhere); its completed ones kept their results
        evacuated = len({
            int(j.params.get("SLURM_ARRAY_TASK_ID",
                j.params.get("BRIDGE_ARRAY_INDEX",
                             int(j.params.get("LSB_JOBINDEX", 0)) - 1)))
            for j in env.clusters["slurm"].jobs.values()
            if j.state != B.COMPLETED})
        # detection budget: threshold failed polls, one per interval, plus
        # generous slack for tick scheduling on a loaded box
        budget = threshold * interval + 2.0
        detect_s = t_detect - t_kill
        if detect_s > budget:
            raise RuntimeError(f"detection took {detect_s:.3f}s "
                               f"(budget {budget:.3f}s)")
        return {
            "label": f"{mode}/failover-{count}ix",
            "mode": mode, "array_count": count,
            "unreachable_threshold": threshold, "interval": interval,
            "detection_s": round(detect_s, 3),
            "evacuation_s": round(t_done - t_kill, 3),
            "evacuated_indices": evacuated,
            "missing_indices": len(missing),
            "duplicated_completions": len(duplicated),
        }
    finally:
        env.stop()


def main() -> int:
    ap = make_parser(__doc__)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_bridge_scale.json"))
    args = ap.parse_args()
    smoke = args.smoke

    # the wakeup scenario runs tens of thousands of shallow payload threads;
    # the default 8 MiB stacks are pure virtual-memory noise at that scale
    threading.stack_size(512 * 1024)

    counts = pick(smoke, [1, 64, 256], [1, 16])
    cr_counts = pick(smoke, [1, 16, 64], [1, 8])
    # jobs long enough that the run is dominated by steady-state RUNNING
    # ticks (the hot path being optimised), not the start/end ramps
    array_dur = pick(smoke, 4.0, 0.5)
    interval = 0.01
    cr_dur = pick(smoke, 0.3, 0.2)
    single_repeats = pick(smoke, 9, 1)
    resize = pick(smoke, (32, 48, 8), (8, 16, 2))
    sliced = pick(smoke,
                  dict(count=64, slurm_slots=8, lsf_slots=4, duration=0.3),
                  dict(count=16, slurm_slots=4, lsf_slots=2, duration=0.2))
    # 1000 CRs on one endpoint: a long shared RUNNING plateau (the
    # steady state the event-driven control plane optimises) plus a
    # staggered drain (constant churn, the conservative re-poll path)
    event = pick(smoke,
                 dict(crs=1000, interval=0.5, dur_lo=6.0, dur_hi=8.0),
                 dict(crs=32, interval=0.2, dur_lo=1.5, dur_hi=2.5))
    # 10k single-job CRs, watch vs wakeup at IDENTICAL parameters: the
    # plateau must outlast the submission ramp (so the watch baseline gets
    # to observe RUNNING as its own transition instead of collapsing the
    # whole lifecycle into one capacity-starved poll), slots < crs queues a
    # tail of CRs so QUEUED->RUNNING is a real, separately-billed
    # transition, and the drain spreads terminals thinly enough that the
    # shared event ring (4096 entries) keeps covering a one-interval
    # watermark lag per chain
    # reconcile=1.0: the operator's sweep mirrors EVERY CR's status each
    # pass — at 10k CRs a 50ms cadence spends the whole core re-scanning
    # the registry and starves the monitor of tick throughput for BOTH
    # cadences (the comparison stays fair: one value, shared by the rows)
    wakeup = pick(smoke,
                  dict(crs=10000, interval=1.0, dur_lo=50.0, dur_hi=100.0,
                       slots=6000, reconcile=1.0),
                  dict(crs=48, interval=0.2, dur_lo=1.5, dur_hi=2.5,
                       slots=48))
    # the large-array variant: few CRs, many indices, slots << indices so
    # QUEUED->RUNNING churn runs the whole scenario — the id-filtered
    # BATCH_STATUS path is exercised continuously, not just at the drain
    array_event = pick(smoke,
                       dict(crs=64, count=256, interval=0.5, dur_lo=2.0,
                            dur_hi=6.0, slots=2048),
                       dict(crs=4, count=32, interval=0.2, dur_lo=0.5,
                            dur_hi=1.5, slots=64))
    service = pick(smoke,
                   dict(replicas=6, threads=8, warm_s=2.0, post_s=2.0),
                   dict(replicas=4, threads=4, warm_s=0.5, post_s=0.5))
    autoscale = pick(smoke,
                     dict(min_replicas=2, max_replicas=8, threads=16,
                          light_s=1.5, heavy_s=1.5),
                     dict(min_replicas=2, max_replicas=4, threads=8,
                          light_s=0.8, heavy_s=0.8))
    failover = pick(smoke,
                    dict(count=32, threshold=3, interval=0.02, duration=1.0),
                    dict(count=8, threshold=3, interval=0.02, duration=0.4))

    baseline_count = counts[-1]

    results = {"smoke": args.smoke,
               "config": {"interval": interval, "array_duration_s": array_dur,
                          "batch_status_chunk": BATCH_STATUS_CHUNK,
                          "event": event, "wakeup": wakeup,
                          "array_event": array_event},
               "array_scaling": [], "baselines": [], "cr_scaling": [],
               "cr_scaling_event": [], "cr_scaling_wakeup": [],
               "array_wakeup": [], "single_job": [], "resize": [],
               "sliced_placement": [], "service_scale": [],
               "service_autoscale": [], "slice_failover": []}

    print("== array scaling (one CR, N indices) ==")
    for mode in MODES:
        for count in counts:
            r = run_case(mode, count=count, duration=array_dur,
                         interval=interval)
            results["array_scaling"].append(r)
            print(f"  {r['label']:<24} wall={r['wall_time_s']:>6.2f}s "
                  f"req/tick={r['rest_requests_per_tick']:>8.2f} "
                  f"flushes={r['cm_flushes']:>4} "
                  f"threads={r['monitor_threads_peak']}")

    print("== baselines (optimisations off, multiplexed mode) ==")
    for kwargs, label in ((dict(batched=False), "per-index-status"),
                          (dict(coalesced=False), "always-write-store")):
        r = run_case("multiplexed", count=baseline_count, duration=array_dur,
                     interval=interval, label=f"{label}/{baseline_count}ix",
                     **kwargs)
        results["baselines"].append(r)
        print(f"  {r['label']:<24} wall={r['wall_time_s']:>6.2f}s "
              f"req/tick={r['rest_requests_per_tick']:>8.2f} "
              f"flushes={r['cm_flushes']:>4}")

    print("== CR scaling (N CRs, single jobs) — thread growth ==")
    for mode in MODES:
        for crs in cr_counts:
            r = run_case(mode, crs=crs, duration=cr_dur)
            results["cr_scaling"].append(r)
            print(f"  {r['label']:<24} threads={r['monitor_threads_peak']:>3} "
                  f"wall={r['wall_time_s']:>6.2f}s")

    print(f"== event-driven control plane ({event['crs']} CRs, "
          "fixed vs adaptive vs watch) ==")
    for cadence in ("fixed", "adaptive", "watch"):
        r = run_event_case(cadence, **event)
        results["cr_scaling_event"].append(r)
        print(f"  {r['label']:<24} req/cr-tick="
              f"{r['rest_requests_per_cr_tick']:>7.4f} "
              f"stale p99={r['status_staleness_p99_s']:>6.3f}s "
              f"threads={r['monitor_threads_peak']}")
        for route, n in r["server_stats"].items():
            print(f"      {route:<36} {n}")

    ev_fixed, ev_adaptive, ev_watch = results["cr_scaling_event"]
    # the tentpole's claims, asserted where the numbers are made: the
    # event-driven modes must cut request volume without letting staleness
    # run away, and monitor threads must stay at the pool size throughout
    for r in results["cr_scaling_event"]:
        if r["monitor_threads_peak"] > r["monitor_workers"]:
            raise RuntimeError(
                f"{r['label']}: monitor threads grew past the pool "
                f"({r['monitor_threads_peak']} > {r['monitor_workers']})")
    if not (ev_adaptive["rest_requests"] < ev_fixed["rest_requests"] * 0.75):
        raise RuntimeError(
            f"adaptive cadence did not reduce request volume: "
            f"{ev_adaptive['rest_requests']} vs {ev_fixed['rest_requests']}")
    # watch replaces expensive status reads with cheap 204 event probes:
    # the STATUS route must collapse, and the total (probes included) must
    # not regress past fixed
    status_route = "GET /slurm/v0.0.37/job/{id}"
    if not (ev_watch["server_stats"].get(status_route, 0)
            < ev_fixed["server_stats"].get(status_route, 1) * 0.5):
        raise RuntimeError(
            f"watch transport did not skip status requests: "
            f"{ev_watch['server_stats']} vs {ev_fixed['server_stats']}")
    if not (ev_watch["rest_requests"] <= ev_fixed["rest_requests"] * 1.1):
        raise RuntimeError(
            f"watch transport regressed total request volume: "
            f"{ev_watch['rest_requests']} vs {ev_fixed['rest_requests']}")
    # staleness bounds: fixed/watch see a transition within a few poll
    # intervals (+ mirror latency slack for a loaded CI box); adaptive may
    # legitimately be backed off up to MAX_FACTOR intervals when it fires
    iv = event["interval"]
    for r, factor in ((ev_fixed, 4), (ev_watch, 4), (ev_adaptive, 12)):
        if r["status_staleness_p99_s"] > iv * factor + 2.0:
            raise RuntimeError(
                f"{r['label']}: p99 staleness unbounded "
                f"({r['status_staleness_p99_s']}s > {iv * factor + 2.0}s)")

    print(f"== watch-driven wakeups ({wakeup['crs']} CRs, "
          "watch vs wakeup) ==")
    for cadence in ("watch", "wakeup"):
        r = run_event_case(cadence, **wakeup)
        results["cr_scaling_wakeup"].append(r)
        print(f"  {r['label']:<24} req={r['rest_requests']:>7} "
              f"stale p99={r['status_staleness_p99_s']:>6.3f}s "
              f"wakeup p99={r['wakeup_latency_p99_s']} "
              f"threads={r['monitor_threads_peak']} "
              f"lost={r['terminal_transitions_lost']} "
              f"dup={r['terminal_transitions_duplicated']}")
        for route, n in r["server_stats"].items():
            print(f"      {route:<36} {n}")

    wk_watch, wk_wakeup = results["cr_scaling_wakeup"]
    # the PR's claims, asserted where the numbers are made.
    # 1. the wakeup cadence at least HALVES the status-route volume the
    #    watch transport still pays at identical parameters (non-terminal
    #    transitions merge from event payloads; only terminals are polled)
    if not (wk_wakeup["server_stats"].get(status_route, 0)
            < wk_watch["server_stats"].get(status_route, 1) * 0.5):
        raise RuntimeError(
            f"wakeup cadence did not halve status-route requests: "
            f"{wk_wakeup['server_stats']} vs {wk_watch['server_stats']}")
    # 2. pushing wakeups must not cost total request volume (the filtered
    #    events fetch replaces a status poll 1:1; the per-endpoint watcher
    #    adds ~2 long-polls a second)
    if not (wk_wakeup["rest_requests"] <= wk_watch["rest_requests"] * 1.1):
        raise RuntimeError(
            f"wakeup cadence regressed total request volume: "
            f"{wk_wakeup['rest_requests']} vs {wk_watch['rest_requests']}")
    # 3. staleness: the wakeup row's p99 stays inside the design's own
    #    worst-case envelope — a straggler whose poke was consumed early is
    #    caught by a stretched safety tick (WakeupCadence ceiling:
    #    16 x base interval), plus the operator's full-registry mirror pass
    #    (~2s at 10k CRs on one core).  The typical path (poke -> tick ->
    #    mirror) lands far under it; the wakeup-latency assert below pins
    #    that separately.
    if wk_wakeup["status_staleness_p99_s"] > wakeup["interval"] * 16 + 4.0:
        raise RuntimeError(
            f"wakeup p99 staleness outside the safety-net envelope: "
            f"{wk_wakeup['status_staleness_p99_s']}s")
    #    ...and must strictly dominate the watch baseline at identical
    #    parameters: watch burns its request budget re-polling, wakeup
    #    spends it only where events point
    if (wk_wakeup["status_staleness_p99_s"]
            > wk_watch["status_staleness_p99_s"]):
        raise RuntimeError(
            f"wakeup staleness worse than watch: "
            f"{wk_wakeup['status_staleness_p99_s']}s vs "
            f"{wk_watch['status_staleness_p99_s']}s")
    #    the watch baseline only gets a runaway guard — at this CR count its
    #    poll-everything drain may saturate the worker pool (that is
    #    precisely the failure mode the wakeup cadence removes)
    if wk_watch["status_staleness_p99_s"] > 240.0:
        raise RuntimeError(
            f"watch p99 staleness runaway: "
            f"{wk_watch['status_staleness_p99_s']}s")
    # 4. event -> evaluation latency: a poke beats the deadline heap
    if (wk_wakeup["wakeup_latency_p99_s"] is None
            or wk_wakeup["wakeup_latency_p99_s"] >= wakeup["interval"]):
        raise RuntimeError(
            f"wakeup latency p99 not below the poll interval: "
            f"{wk_wakeup['wakeup_latency_p99_s']} vs {wakeup['interval']}")
    # 5. watcher threads are per-ENDPOINT, not per-CR: one endpoint, one
    #    watcher, and the monitor pool itself stays flat
    if wk_wakeup["watcher_threads"] != 1:
        raise RuntimeError(
            f"expected exactly one endpoint watcher, got "
            f"{wk_wakeup['watcher_threads']}")
    for r in results["cr_scaling_wakeup"]:
        if r["monitor_threads_peak"] > r["monitor_workers"] + 1:
            raise RuntimeError(
                f"{r['label']}: monitor threads grew past pool+watcher "
                f"({r['monitor_threads_peak']} > {r['monitor_workers'] + 1})")
    # 6. no terminal transition lost or duplicated under either cadence
    for r in results["cr_scaling_wakeup"]:
        if (r["terminal_transitions_lost"]
                or r["terminal_transitions_duplicated"]):
            raise RuntimeError(
                f"{r['label']}: lost={r['terminal_transitions_lost']} "
                f"dup={r['terminal_transitions_duplicated']}")

    print(f"== large-array wakeups ({array_event['crs']} CRs x "
          f"{array_event['count']} indices, watch vs wakeup) ==")
    for cadence in ("watch", "wakeup"):
        r = run_array_event_case(cadence, **array_event)
        results["array_wakeup"].append(r)
        print(f"  {r['label']:<28} ids_polled={r['ids_polled']:>8} "
              f"(per-index {r['ids_polled_per_index']}) "
              f"req={r['rest_requests']:>6} "
              f"threads={r['monitor_threads_peak']}")

    ar_watch, ar_wakeup = results["array_wakeup"]
    # id-filtered BATCH_STATUS: a wakeup drain tick touches only the
    # CHANGED indices, so it polls a fraction of the job ids the watch
    # cadence re-polls on every version bump
    if not (ar_wakeup["ids_polled"] < ar_watch["ids_polled"] * 0.5):
        raise RuntimeError(
            f"id-filtered polling did not halve ids polled: "
            f"{ar_wakeup['ids_polled']} vs {ar_watch['ids_polled']}")
    for r in results["array_wakeup"]:
        if r["monitor_threads_peak"] > r["monitor_workers"] + 1:
            raise RuntimeError(
                f"{r['label']}: monitor threads grew past pool+watcher "
                f"({r['monitor_threads_peak']} > {r['monitor_workers'] + 1})")

    print("== elastic resize (delta submit/cancel latency) ==")
    for mode in MODES:
        r = run_resize_case(mode, *resize)
        results["resize"].append(r)
        print(f"  {r['label']:<24} up={r['scale_up_latency_s']:>6.3f}s "
              f"down={r['scale_down_latency_s']:>6.3f}s "
              f"req={r['rest_requests']:>4}")

    print("== sharded placement (2 uneven resources, strategy spread) ==")
    for mode in MODES:
        r = run_sliced_case(mode, interval=interval, **sliced)
        results["sliced_placement"].append(r)
        print(f"  {r['label']:<28} split={r['split']} "
              f"sliced={r['wall_time_s_sliced']:>6.2f}s "
              f"pinned={r['wall_time_s_single_resource']:>6.2f}s "
              f"({r['speedup_x']}x)")

    print("== service scale (replicated serving, replica kill mid-traffic) ==")
    for mode in MODES:
        r = run_service_case(mode, interval=interval, **service)
        results["service_scale"].append(r)
        print(f"  {r['label']:<24} rps={r['throughput_rps']:>7.1f} "
              f"p99={r['latency_p99_ms']:>7.3f}ms "
              f"recover={r['recovery_s']:>6.3f}s "
              f"dead-routed={r['requests_to_dead_after_drop']}")

    print("== service autoscale (4x load ramp, scale to max, idle to min) ==")
    for mode in MODES:
        r = run_autoscale_case(mode, **autoscale)
        results["service_autoscale"].append(r)
        print(f"  {r['label']:<24} "
              f"ramp={r['ramp_to_max_s']:>6.3f}s "
              f"idle={r['idle_to_min_s']:>6.3f}s "
              f"req={r['requests_total']:>5} errors={r['errors']}")

    print("== slice failover (kill one of two resources mid-array) ==")
    for mode in MODES:
        r = run_failover_case(mode, **failover)
        results["slice_failover"].append(r)
        print(f"  {r['label']:<24} detect={r['detection_s']:>6.3f}s "
              f"evacuate={r['evacuation_s']:>6.3f}s "
              f"moved={r['evacuated_indices']:>3} "
              f"lost={r['missing_indices']} dup={r['duplicated_completions']}")

    print("== single-job wall time (latency regression guard) ==")
    for mode in MODES:
        walls = [run_case(mode, count=1, duration=0.1)["wall_time_s"]
                 for _ in range(single_repeats)]
        results["single_job"].append(
            {"mode": mode, "wall_time_s_median": statistics.median(walls),
             "wall_time_s_all": walls})
        print(f"  {mode:<14} median={statistics.median(walls):.3f}s")

    def _find(rows, **match):
        for r in rows:
            if all(r.get(k) == v for k, v in match.items()):
                return r
        raise KeyError(match)

    batched = _find(results["array_scaling"], mode="multiplexed",
                    array_count=baseline_count)
    per_index = _find(results["baselines"], batched_status=False)
    always = _find(results["baselines"], coalesced_writes=False)
    mux_threads = [r["monitor_threads_peak"] for r in results["cr_scaling"]
                   if r["mode"] == "multiplexed"]
    results["headline"] = {
        "array_count": baseline_count,
        "rest_requests_per_tick_batched": batched["rest_requests_per_tick"],
        "rest_requests_per_tick_per_index": per_index["rest_requests_per_tick"],
        "rest_request_reduction_x": round(
            per_index["rest_requests_per_tick"]
            / max(batched["rest_requests_per_tick"], 1e-9), 1),
        "cm_flushes_coalesced": batched["cm_flushes"],
        "cm_flushes_always_write": always["cm_flushes"],
        "cm_flush_reduction_x": round(
            always["cm_flushes"] / max(batched["cm_flushes"], 1), 1),
        "multiplexed_threads_by_cr_count": dict(zip(
            [str(c) for c in cr_counts], mux_threads)),
        "single_job_wall_s": {r["mode"]: r["wall_time_s_median"]
                              for r in results["single_job"]},
        "resize_latency_s": {r["mode"]: {"up": r["scale_up_latency_s"],
                                         "down": r["scale_down_latency_s"]}
                             for r in results["resize"]},
        "sliced_placement": {
            r["mode"]: {"split": r["split"], "speedup_x": r["speedup_x"]}
            for r in results["sliced_placement"]},
        "event_driven": {
            r["cadence"]: {
                "rest_requests": r["rest_requests"],
                "requests_per_cr_tick": r["rest_requests_per_cr_tick"],
                "staleness_p99_s": r["status_staleness_p99_s"],
                "monitor_threads_peak": r["monitor_threads_peak"]}
            for r in results["cr_scaling_event"]},
        "wakeup": {
            r["cadence"]: {
                "crs": r["crs"],
                "rest_requests": r["rest_requests"],
                "status_route_requests":
                    r["server_stats"].get(status_route, 0),
                "staleness_p99_s": r["status_staleness_p99_s"],
                "wakeup_latency_p99_s": r["wakeup_latency_p99_s"],
                "monitor_threads_peak": r["monitor_threads_peak"],
                "watcher_threads": r["watcher_threads"],
                "pokes_delivered": r["pokes_delivered"],
                "pokes_coalesced": r["pokes_coalesced"],
                "terminal_transitions_lost": r["terminal_transitions_lost"],
                "terminal_transitions_duplicated":
                    r["terminal_transitions_duplicated"]}
            for r in results["cr_scaling_wakeup"]},
        "array_wakeup": {
            r["cadence"]: {
                "ids_polled": r["ids_polled"],
                "ids_polled_per_index": r["ids_polled_per_index"],
                "monitor_threads_peak": r["monitor_threads_peak"]}
            for r in results["array_wakeup"]},
        "service_scale": {
            r["mode"]: {"throughput_rps": r["throughput_rps"],
                        "latency_p99_ms": r["latency_p99_ms"],
                        "recovery_s": r["recovery_s"],
                        "requests_to_dead_after_drop":
                            r["requests_to_dead_after_drop"]}
            for r in results["service_scale"]},
        "service_autoscale": {
            r["mode"]: {"ramp_to_max_s": r["ramp_to_max_s"],
                        "idle_to_min_s": r["idle_to_min_s"],
                        "reached_max": r["reached_max"],
                        "returned_to_min": r["returned_to_min"],
                        "requests_total": r["requests_total"],
                        "errors": r["errors"]}
            for r in results["service_autoscale"]},
        "slice_failover": {
            r["mode"]: {"detection_s": r["detection_s"],
                        "evacuation_s": r["evacuation_s"],
                        "evacuated_indices": r["evacuated_indices"],
                        "missing_indices": r["missing_indices"],
                        "duplicated_completions": r["duplicated_completions"]}
            for r in results["slice_failover"]},
    }

    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    h = results["headline"]
    print(f"\nheadline @ {baseline_count} indices: "
          f"req/tick {h['rest_requests_per_tick_per_index']} -> "
          f"{h['rest_requests_per_tick_batched']} "
          f"({h['rest_request_reduction_x']}x), "
          f"flushes {h['cm_flushes_always_write']} -> "
          f"{h['cm_flushes_coalesced']} ({h['cm_flush_reduction_x']}x), "
          f"mux threads {h['multiplexed_threads_by_cr_count']}")
    sv = h["service_scale"]
    print("service scale: "
          + ", ".join(f"{m}: {v['throughput_rps']} rps "
                      f"p99={v['latency_p99_ms']}ms "
                      f"recover={v['recovery_s']}s"
                      for m, v in sv.items()))
    asc = h["service_autoscale"]
    print("service autoscale: "
          + ", ".join(f"{m}: ramp={v['ramp_to_max_s']}s "
                      f"idle={v['idle_to_min_s']}s "
                      f"errors={v['errors']}"
                      for m, v in asc.items()))
    fo = h["slice_failover"]
    print("slice failover: "
          + ", ".join(f"{m}: detect={v['detection_s']}s "
                      f"evacuate={v['evacuation_s']}s "
                      f"lost={v['missing_indices']} "
                      f"dup={v['duplicated_completions']}"
                      for m, v in fo.items()))
    ev = h["event_driven"]
    print(f"event-driven @ {event['crs']} CRs: requests "
          + " vs ".join(f"{c}={ev[c]['rest_requests']}"
                        for c in ("fixed", "adaptive", "watch"))
          + ", p99 staleness "
          + " / ".join(f"{c}={ev[c]['staleness_p99_s']}s"
                       for c in ("fixed", "adaptive", "watch")))
    wk = h["wakeup"]
    print(f"wakeup @ {wakeup['crs']} CRs: status-route "
          f"watch={wk['watch']['status_route_requests']} vs "
          f"wakeup={wk['wakeup']['status_route_requests']}, "
          f"p99 staleness watch={wk['watch']['staleness_p99_s']}s vs "
          f"wakeup={wk['wakeup']['staleness_p99_s']}s, "
          f"wakeup latency p99={wk['wakeup']['wakeup_latency_p99_s']}s, "
          f"watcher threads={wk['wakeup']['watcher_threads']}")
    aw = h["array_wakeup"]
    print(f"array wakeup @ {array_event['crs']}x{array_event['count']}: "
          f"ids polled watch={aw['watch']['ids_polled']} vs "
          f"wakeup={aw['wakeup']['ids_polled']}")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
