"""Core layers: norms, RoPE, GQA attention (train/prefill/decode), MLPs.

All layers follow the same convention: ``<layer>_defs(cfg)`` returns a pytree
of ParamDef; ``<layer>(params, x, ...)`` applies it.  Compute-sensitive
reductions (softmax, norms) run in f32 and cast back to the activation dtype.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef

Params = Dict[str, Any]


def adtype(cfg) -> Any:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_defs(cfg, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    out = {"scale": ParamDef((d,), ("embed",), init="ones", dtype=jnp.float32)}
    if cfg.norm == "layernorm":
        out["bias"] = ParamDef((d,), ("embed",), init="zeros", dtype=jnp.float32)
    return out


def apply_norm(p: Params, x: jax.Array, kind: str) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (llama-style half-rotation)
# ---------------------------------------------------------------------------


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA), full + windowed + decode-over-cache
# ---------------------------------------------------------------------------


def attention_defs(cfg) -> Params:
    d, h = cfg.d_model, cfg.resolved_head_dim
    dt = adtype(cfg)
    return {
        "wq": ParamDef((d, cfg.n_heads, h), ("embed", "heads", "head_dim"), dtype=dt),
        "wk": ParamDef((d, cfg.n_kv_heads, h), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "wv": ParamDef((d, cfg.n_kv_heads, h), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "wo": ParamDef((cfg.n_heads, h, d), ("heads", "head_dim", "embed"), dtype=dt),
    }


def cross_attention_defs(cfg) -> Params:
    return attention_defs(cfg)


def _gqa_scores(q: jax.Array, k: jax.Array, n_rep: int) -> jax.Array:
    """q: (B,Sq,Hq,D), k: (B,Sk,Hkv,D) -> scores (B,Hkv,G,Sq,Sk)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    qg = q.reshape(b, sq, hkv, n_rep, d)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg, k)


def _gqa_out(w: jax.Array, v: jax.Array) -> jax.Array:
    """w: (B,Hkv,G,Sq,Sk), v: (B,Sk,Hkv,D) -> (B,Sq,Hq,D)."""
    b, hkv, g, sq, sk = w.shape
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(b, sq, hkv * g, out.shape[-1])


def _masked_softmax(scores: jax.Array, mask: jax.Array, dtype) -> jax.Array:
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    return jax.nn.softmax(scores, axis=-1).astype(dtype)


def _blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         n_rep: int, hd: int, window: int,
                         block_q: int, unroll: bool = False) -> jax.Array:
    """q-chunked causal attention (XLA flash stand-in): the (S,S) score
    matrix never materializes — per chunk only (B,Hkv,G,bq,S) lives.
    Matches the Pallas kernel's memory behaviour in a form the dry-run can
    lower on any backend."""
    b, s, hq, d = q.shape
    bq = min(block_q, s)
    pad = (-s) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = q.shape[1] // bq
    qc = q.reshape(b, nb, bq, hq, d).transpose(1, 0, 2, 3, 4)  # (nb,B,bq,H,D)
    kt = jax.lax.broadcasted_iota(jnp.int32, (1, s), 1)

    def chunk(ci, qb):
        # qb: (B,bq,Hq,D); rows are global positions ci*bq + i
        scores = _gqa_scores(qb, k, n_rep) / jnp.sqrt(hd).astype(jnp.float32)
        rows = ci * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
        mask = kt <= rows  # (bq, S)
        if window > 0:
            mask &= kt > rows - window
        w = _masked_softmax(scores, mask, qb.dtype)
        return _gqa_out(w, v)  # (B,bq,Hq,D)

    if unroll:
        # python-unrolled chunk loop: identical numerics; every chunk's ops
        # are explicit in HLO so cost_analysis counts them (a lax.scan body
        # is visited ONCE by XLA's cost analysis — see dryrun.py probes)
        out = jnp.stack([chunk(ci, qc[ci]) for ci in range(nb)])
    else:
        out = jax.lax.scan(
            lambda c, args: (c, chunk(args[0], args[1])),
            jnp.zeros((), jnp.int32), (jnp.arange(nb), qc))[1]  # (nb,B,bq,H,D)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nb * bq, hq, d)
    return out[:, :s]


def _maybe_seq_shard(x: jax.Array, cfg, seq_axis: int = 1) -> jax.Array:
    """attention_partitioning="seq": constrain the seq dim over "model"
    (batch keeps its dp axes).  No-op without an installed mesh."""
    if getattr(cfg, "attention_partitioning", "auto") != "seq":
        return x
    from jax.sharding import PartitionSpec as P

    from repro.parallel.ep import current_mesh
    from repro.sharding import dp_axes

    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    if x.shape[seq_axis] % mesh.shape["model"] != 0:
        return x
    dp = dp_axes(mesh)
    dpsz = 1
    for a in dp:
        dpsz *= mesh.shape[a]
    entries = [None] * x.ndim
    if x.shape[0] % dpsz == 0 and dpsz > 1:
        entries[0] = dp
    entries[seq_axis] = "model"
    return jax.lax.with_sharding_constraint(x, P(*entries))


def attn_forward(
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg,
    window: int = 0,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
    causal: bool = True,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Training / prefill attention.  Returns (out, (k, v)) for cache fill.

    ``kv_override`` turns this into cross-attention (positions are ignored for
    rope on kv).  ``window`` > 0 limits attention to the last ``window`` keys.
    """
    hd = cfg.resolved_head_dim
    n_rep = cfg.n_heads // cfg.n_kv_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    else:
        kv_src_k, kv_src_v = kv_override
        k = kv_src_k if kv_src_k.ndim == 4 else jnp.einsum("bsd,dhk->bshk", kv_src_k, p["wk"])
        v = kv_src_v if kv_src_v.ndim == 4 else jnp.einsum("bsd,dhk->bshk", kv_src_v, p["wv"])

    impl = getattr(cfg, "attention_impl", "xla")
    if impl in ("pallas", "pallas_interpret") and kv_override is None and causal and window == 0:
        from repro.kernels import ops as kops

        # "pallas" = auto (compat picks Mosaic on TPU / interpret on CPU);
        # "pallas_interpret" pins interpret mode for bit-exact test sweeps
        out = kops.flash_attention(
            q, k, v, causal=True,
            interpret=True if impl == "pallas_interpret" else None,
        )
    elif impl in ("blockwise", "blockwise_u") and kv_override is None and causal:
        q = _maybe_seq_shard(q, cfg)
        out = _blockwise_attention(q, k, v, n_rep, hd, window,
                                   getattr(cfg, "attention_block_q", 512),
                                   unroll=(impl == "blockwise_u"))
    else:
        if kv_override is None and causal:
            q = _maybe_seq_shard(q, cfg)
        scores = _gqa_scores(q, k, n_rep) / jnp.sqrt(hd).astype(jnp.float32)
        sq, sk = scores.shape[-2], scores.shape[-1]
        if kv_override is None and causal:
            iq = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 0)
            ik = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
            mask = ik <= iq
            if window > 0:
                mask &= ik > iq - window
        else:
            mask = jnp.ones((sq, sk), dtype=bool)
        w = _masked_softmax(scores, mask, x.dtype)
        out = _gqa_out(w, v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k, v)


def attn_decode(
    p: Params,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    cfg,
    write_pos: Optional[jax.Array] = None,
    cross: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Single-token decode.  x: (B,1,d); cache_{k,v}: (B,M,Hkv,D).

    ``pos`` (B,) is the ABSOLUTE position of the new token (drives RoPE and the
    valid-length mask).  ``write_pos`` (B,) is the cache slot to write —
    defaults to ``pos``; pass ``pos % M`` for circular sliding-window buffers.
    For ``cross=True`` the cache is the fixed encoder KV; nothing is written
    and every slot is attended.
    """
    hd = cfg.resolved_head_dim
    n_rep = cfg.n_heads // cfg.n_kv_heads
    b = x.shape[0]
    M = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if not cross:
        if write_pos is None:
            write_pos = pos
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)
        # write the new kv at slot `write_pos` (per-batch dynamic index)
        oh = jax.nn.one_hot(write_pos, M, dtype=cache_k.dtype)  # (B, M)
        cache_k = cache_k * (1 - oh)[:, :, None, None] + oh[:, :, None, None] * k_new
        cache_v = cache_v * (1 - oh)[:, :, None, None] + oh[:, :, None, None] * v_new
    impl = getattr(cfg, "attention_impl", "xla")
    if impl in ("pallas", "pallas_interpret") and not cross:
        from repro.kernels import ops as kops

        valid = jnp.minimum(pos + 1, M)
        out = kops.decode_attention(
            q, cache_k, cache_v, valid,
            interpret=True if impl == "pallas_interpret" else None)
    else:
        scores = _gqa_scores(q, cache_k, n_rep) / jnp.sqrt(hd).astype(jnp.float32)
        ik = jax.lax.broadcasted_iota(jnp.int32, (b, 1, M), 2)
        if cross:
            mask = jnp.ones((b, 1, M), dtype=bool)
        else:
            # number of valid slots after the write: min(pos+1, M)
            valid = jnp.minimum(pos + 1, M)[:, None, None]
            mask = ik < valid
        w = _masked_softmax(scores, mask[:, None, None], x.dtype)  # (B,Hkv,G,1,M)
        out = _gqa_out(w, cache_v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (cache_k, cache_v)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_defs(cfg, d_ff: Optional[int] = None) -> Params:
    d, dff = cfg.d_model, d_ff or cfg.d_ff
    dt = adtype(cfg)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w1": ParamDef((d, dff), ("embed", "mlp"), dtype=dt),
            "w3": ParamDef((d, dff), ("embed", "mlp"), dtype=dt),
            "w2": ParamDef((dff, d), ("mlp", "embed"), dtype=dt),
        }
    return {
        "w1": ParamDef((d, dff), ("embed", "mlp"), dtype=dt),
        "w2": ParamDef((dff, d), ("mlp", "embed"), dtype=dt),
    }


def apply_mlp(p: Params, x: jax.Array, activation: str) -> jax.Array:
    h = x @ p["w1"]
    if activation == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"])
    elif activation == "geglu":
        h = jax.nn.gelu(h) * (x @ p["w3"])
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(h))
    elif activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(activation)
    return h @ p["w2"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_defs(cfg) -> Params:
    dt = adtype(cfg)
    out = {
        "embedding": ParamDef(
            (cfg.vocab, cfg.d_model), ("vocab", "embed"), init="embed", scale=1.0, dtype=dt
        )
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"), dtype=dt)
    return out


def embed_tokens(p: Params, tokens: jax.Array, cfg) -> jax.Array:
    x = p["embedding"][tokens]  # gather
    if cfg.embed_scale:
        x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
    return x


def unembed(p: Params, x: jax.Array, cfg) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["embedding"])
    return jnp.einsum("bsd,dv->bsv", x, p["lm_head"])


def posembed_defs(cfg, max_len: int) -> Params:
    return {
        "pos": ParamDef((max_len, cfg.d_model), (None, "embed"), init="embed", scale=0.02,
                        dtype=adtype(cfg))
    }
