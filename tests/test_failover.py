"""Slice failover: sustained resource failure is detected, the LOST slice's
unfinished indices migrate to the surviving candidates, and the whole dance
is exercised under deterministic fault injection.

The invariants under test:

  * a permanent blackout of one candidate (endpoint dark + cluster powered
    off) ends in COMPLETED, not UNKNOWN: every index of the desired set ran
    to completion EXACTLY once while live, the dead slice is reported LOST
    with ``migratedTo``, and completed indices' results survive on it;
  * a transient flap below ``unreachableThreshold`` does NOT migrate — the
    job completes on its original placement with zero evacuations;
  * killing the operator pod mid-evacuation loses nothing: the replacement
    pod resumes the persisted migration (LOST flags, orphan ledger, index
    holes) and still converges to COMPLETED with at-most-once semantics;
  * when the LAST candidate dies too there is nowhere to evacuate: the CR
    stays pinned UNKNOWN (black-box honesty) and the message names the
    unreachable endpoint;
  * with failover disabled (the default) the config-map shape is unchanged
    byte-for-byte — no failover keys, no orphans ledger, today's behaviour;
  * per-slice degradation (failures / lastError / outageSeconds) surfaces
    through ``status.placements`` BEFORE any threshold trips;
  * the transport layer retries idempotent GETs in-call (bounded, jittered
    backoff), so one blip never bumps a slice's UNKNOWN counter.
"""
import json
import time

import pytest

from repro.core import (ArraySpec, BridgeEnvironment, DONE, FailoverSpec,
                        FaultProfile, IMAGES, LOST, PlacementCandidate,
                        PlacementSpec, UNKNOWN, URLS)
from repro.core.backends import base as B
from repro.core.rest import Channel, RestServer, TransportError

MODES = ["multiplexed", "pod-per-cr"]
OPERATORS = [(m, "fixed") for m in MODES] + [
    ("multiplexed", "adaptive"), ("multiplexed", "watch")]


def _wait(predicate, timeout=30, interval=0.005):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


def _ids(handle):
    return [s for s in handle.status().job_id.split(",") if s]


def _placement(kinds, failover=None, strategy="spread"):
    return PlacementSpec(candidates=[
        PlacementCandidate(URLS[k], IMAGES[k], f"{k}-secret")
        for k in kinds], strategy=strategy, failover=failover)


def _index_of(cluster_job):
    p = cluster_job.params
    if "SLURM_ARRAY_TASK_ID" in p:
        return int(p["SLURM_ARRAY_TASK_ID"])
    if "BRIDGE_ARRAY_INDEX" in p:
        return int(p["BRIDGE_ARRAY_INDEX"])
    if "LSB_JOBINDEX" in p:
        return int(p["LSB_JOBINDEX"]) - 1
    return None


def _completions_per_index(env, kinds):
    """index -> number of COMPLETED runs across the given clusters."""
    runs = {}
    for k in kinds:
        for job in env.clusters[k].jobs.values():
            if job.state == B.COMPLETED:
                idx = _index_of(job)
                runs[idx] = runs.get(idx, 0) + 1
    return runs


def _assert_migrated_clean(env, h, count, dead="slurm", kinds=("slurm", "lsf")):
    """The shared post-blackout invariant bundle: COMPLETED CR, full desired
    set, at-most-once completions, LOST slice reported with migratedTo."""
    job = h.wait(timeout=120)
    assert job.status.state == DONE, job.status.message
    assert sorted(job.status.index_states, key=int) == [
        str(i) for i in range(count)]
    assert set(job.status.index_states.values()) == {DONE}
    # at-most-once-while-live: every index ran to completion EXACTLY once
    runs = _completions_per_index(env, kinds)
    assert sorted(runs) == list(range(count)), "final results == desired set"
    assert set(runs.values()) == {1}, f"duplicated completions: {runs}"
    placements = h.placements()
    lost = [p for p in placements if p["state"] == LOST]
    assert len(lost) == 1 and lost[0]["resourceURL"] == URLS[dead]
    assert URLS[dead] not in lost[0]["migratedTo"]
    assert lost[0]["migratedTo"], "LOST slice records where its work went"
    # completed indices' results were kept on the dead slice, the rest moved
    survivors = [p for p in placements if p["state"] != LOST]
    union = sorted(i for p in placements for i in p["indices"])
    assert union == list(range(count))
    assert all(i not in lost[0]["indices"]
               for p in survivors for i in p["indices"])


# ---------------------------------------------------------------------------
# tentpole: permanent blackout migrates, zero lost / duplicated indices
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,cadence", OPERATORS)
def test_blackout_migrates_unfinished_indices(mode, cadence):
    """Kill one of two resources mid-array (endpoint blackout + cluster
    power-off): the slice is promoted LOST after the policy threshold and
    its unfinished indices finish on the survivor, exactly once each."""
    fp = FaultProfile(seed=7)
    with BridgeEnvironment(default_duration=0.3, slots=8,
                           fault_profiles={"slurm": fp},
                           operator_kwargs={"mode": mode,
                                            "cadence": cadence}) as env:
        count = 12
        h = env.bridge.submit("chaos", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "0.3"},
            array=ArraySpec(count=count),
            placement=_placement(
                ["slurm", "lsf"],
                failover=FailoverSpec(enabled=True, unreachable_threshold=3,
                                      grace_seconds=0.0))))
        # let the whole fan-out land, then kill the slurm resource for good
        assert _wait(lambda: len(_ids(h)) == count, timeout=60)
        fp.schedule_blackout(start_in=0.0, duration=None)
        env.clusters["slurm"].power_off()
        _assert_migrated_clean(env, h, count)
        # the evacuation is durable: LOST flag and plan survive in the cm
        cm = env.statestore.get("default/chaos-bridge-cm").data
        defs = json.loads(cm["slices"])
        assert [d.get("lost", False) for d in defs][0] is True


def test_blackout_with_completed_indices_keeps_their_results():
    """Indices that finished on the dying slice before the blackout are NOT
    re-run: their pairs (and results) stay on the LOST slice."""
    fp = FaultProfile(seed=3)
    with BridgeEnvironment(default_duration=0.05, slots=8,
                           fault_profiles={"slurm": fp}) as env:
        # no WallSeconds: each cluster's default_duration rules, so slurm's
        # share finishes fast while lsf's is still running at blackout time
        env.clusters["slurm"].default_duration = 0.05
        env.clusters["lsf"].default_duration = 0.6
        count = 8
        h = env.bridge.submit("keepres", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            array=ArraySpec(count=count),
            placement=_placement(
                ["slurm", "lsf"],
                failover=FailoverSpec(enabled=True, unreachable_threshold=3))))

        def slurm_share_done_and_observed():
            jobs = env.clusters["slurm"].jobs
            return bool(jobs) and len(_ids(h)) == count and all(
                j.state == B.COMPLETED
                and h.status().index_states.get(str(_index_of(j))) == DONE
                for j in jobs.values())
        assert _wait(slurm_share_done_and_observed, timeout=60)
        done_before = {_index_of(j)
                       for j in env.clusters["slurm"].jobs.values()}
        fp.schedule_blackout()
        env.clusters["slurm"].power_off()
        job = h.wait(timeout=120)
        assert job.status.state == DONE, job.status.message
        lost = [p for p in h.placements() if p["state"] == LOST][0]
        # everything that completed on slice 0 before the kill is still
        # listed there — completed work is never evacuated or duplicated
        assert set(lost["indices"]) == done_before
        runs = _completions_per_index(env, ("slurm", "lsf"))
        assert sorted(runs) == list(range(count))
        assert set(runs.values()) == {1}


# ---------------------------------------------------------------------------
# transient flap below the threshold: no migration
# ---------------------------------------------------------------------------


def test_flap_below_threshold_does_not_migrate():
    """A flapping endpoint (short down windows, each under the threshold)
    degrades but never trips failover: the job completes on its original
    placement, no slice goes LOST, no orphan ledger appears."""
    fp = FaultProfile(seed=11)
    with BridgeEnvironment(default_duration=0.1, slots=8,
                           fault_profiles={"slurm": fp}) as env:
        count = 8
        h = env.bridge.submit("flap", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "0.1"},
            array=ArraySpec(count=count),
            placement=_placement(
                ["slurm", "lsf"],
                failover=FailoverSpec(enabled=True,
                                      unreachable_threshold=25))))
        assert _wait(lambda: len(_ids(h)) == count, timeout=60)
        # three 60 ms blackouts: ~3 failed polls each, far below 25
        fp.schedule_flaps(start_in=0.0, count=3, down_for=0.06, up_for=0.06)
        job = h.wait(timeout=120)
        assert job.status.state == DONE, job.status.message
        assert all(p["state"] != LOST for p in h.placements())
        cm = env.statestore.get("default/flap-bridge-cm").data
        assert "orphans" not in cm
        assert not any(d.get("lost") for d in json.loads(cm["slices"]))
        runs = _completions_per_index(env, ("slurm", "lsf"))
        assert sorted(runs) == list(range(count))
        assert set(runs.values()) == {1}


# ---------------------------------------------------------------------------
# chaos: operator pod killed mid-evacuation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_pod_killed_mid_evacuation_resumes_cleanly(mode):
    """Kill the controller pod the moment the evacuation is committed to the
    config map: the replacement resumes from the persisted LOST flags and
    index holes, and the job still converges with at-most-once semantics."""
    fp = FaultProfile(seed=23)
    with BridgeEnvironment(default_duration=0.3, slots=8,
                           fault_profiles={"slurm": fp},
                           operator_kwargs={"mode": mode}) as env:
        count = 10
        h = env.bridge.submit("midkill", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "0.3"},
            array=ArraySpec(count=count),
            placement=_placement(
                ["slurm", "lsf"],
                failover=FailoverSpec(enabled=True, unreachable_threshold=3))))
        assert _wait(lambda: len(_ids(h)) == count, timeout=60)
        fp.schedule_blackout()
        env.clusters["slurm"].power_off()
        # the evacuation commit writes the LOST flag + orphan ledger first;
        # kill the pod as soon as that lands (resubmissions may be anywhere
        # between none and all — exactly the window that must be safe)
        cm_name = "default/midkill-bridge-cm"
        assert _wait(lambda: any(
            d.get("lost") for d in json.loads(
                env.statestore.get(cm_name).get("slices") or "[]")),
            timeout=60)
        env.operator.pods["default/midkill"].kill_pod()
        _assert_migrated_clean(env, h, count)


# ---------------------------------------------------------------------------
# nowhere to go: last candidate dead keeps the CR UNKNOWN
# ---------------------------------------------------------------------------


def test_last_candidate_dead_stays_unknown_with_endpoint_in_message():
    """When EVERY other candidate is dark too there is nowhere to evacuate:
    the slice is NOT promoted (black-box honesty — a promotion we cannot act
    on would just lie), the CR pins UNKNOWN and the message names the
    unreachable endpoint and outage duration."""
    fps = {"slurm": FaultProfile(seed=5), "lsf": FaultProfile(seed=6)}
    with BridgeEnvironment(default_duration=60, slots=8,
                           fault_profiles=fps) as env:
        count = 6
        h = env.bridge.submit("stuck", env.make_spec(
            "slurm", script="member", updateinterval=0.02, unknown_after=3,
            jobproperties={"WallSeconds": "60"},
            array=ArraySpec(count=count),
            placement=_placement(
                ["slurm", "lsf"],
                failover=FailoverSpec(enabled=True, unreachable_threshold=3))))
        assert _wait(lambda: len(_ids(h)) == count, timeout=60)
        for k in ("slurm", "lsf"):
            fps[k].schedule_blackout()
            env.clusters[k].power_off()
        assert _wait(lambda: h.status().state == UNKNOWN, timeout=60)
        # ... and it STAYS unknown: no candidate is reachable, so failover
        # must not fire (nothing is promoted, nothing evacuated)
        time.sleep(0.3)
        st = h.status()
        assert st.state == UNKNOWN
        assert "resource unreachable" in st.message
        assert URLS["slurm"] in st.message or URLS["lsf"] in st.message, \
            "message names the dead endpoint"
        assert "failed polls" in st.message
        assert all(p["state"] != LOST for p in h.placements())
        cm = env.statestore.get("default/stuck-bridge-cm").data
        assert "orphans" not in cm


# ---------------------------------------------------------------------------
# compat: failover off == today's config-map shape, byte for byte
# ---------------------------------------------------------------------------


def test_failover_disabled_keeps_configmap_shape():
    """A placement spec without failover — and one with an explicitly
    disabled FailoverSpec — both produce a cm with NO failover keys: the
    feature is invisible until opted into."""
    with BridgeEnvironment(default_duration=0.05, slots=8) as env:
        specs = {
            "plaino": _placement(["slurm", "lsf"]),
            "offo": _placement(["slurm", "lsf"],
                               failover=FailoverSpec(enabled=False)),
        }
        for name, plc in specs.items():
            h = env.bridge.submit(name, env.make_spec(
                "slurm", script="member", updateinterval=0.02,
                jobproperties={"WallSeconds": "0.05"},
                array=ArraySpec(count=4), placement=plc))
            assert h.wait(timeout=60).status.state == DONE
        for name in specs:
            cm = env.statestore.get(f"default/{name}-bridge-cm").data
            for key in ("failover_threshold", "failover_grace", "candidates",
                        "placement_strategy", "orphans"):
                assert key not in cm, f"{key} leaked into {name}"
        assert set(env.statestore.get("default/plaino-bridge-cm").data) == \
            set(env.statestore.get("default/offo-bridge-cm").data)


def test_failover_enabled_writes_policy_keys():
    """Opting in persists the policy (threshold/grace/candidates/strategy)
    so a restarted pod enforces the same policy the spec asked for."""
    with BridgeEnvironment(default_duration=0.05, slots=8) as env:
        h = env.bridge.submit("keyed", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "0.05"},
            array=ArraySpec(count=4),
            placement=_placement(
                ["slurm", "lsf"],
                failover=FailoverSpec(enabled=True, unreachable_threshold=7,
                                      grace_seconds=0.5))))
        assert _wait(
            lambda: env.statestore.exists("default/keyed-bridge-cm"))
        cm = env.statestore.get("default/keyed-bridge-cm").data
        assert cm["failover_threshold"] == "7"
        assert cm["failover_grace"] == "0.5"
        assert cm["placement_strategy"] == "spread"
        cands = json.loads(cm["candidates"])
        assert [c["resourceURL"] for c in cands] == [URLS["slurm"],
                                                     URLS["lsf"]]
        assert h.wait(timeout=60).status.state == DONE


def test_failover_spec_roundtrip_and_validation():
    from repro.core.resource import (placement_from_dict, placement_to_dict)
    plc = _placement(["slurm"], failover=FailoverSpec(
        enabled=True, unreachable_threshold=4, grace_seconds=1.5))
    again = placement_from_dict(placement_to_dict(plc))
    assert again.failover == plc.failover
    assert placement_to_dict(_placement(["slurm"])).get("failover") is None
    with pytest.raises(ValueError):
        FailoverSpec(unreachable_threshold=0).validate()
    with pytest.raises(ValueError):
        FailoverSpec(grace_seconds=-1).validate()


# ---------------------------------------------------------------------------
# degradation surfaces before any threshold trips
# ---------------------------------------------------------------------------


def test_degradation_surfaces_in_placements_before_failover():
    """An outage shorter than the failover policy still shows up: the slice
    reports failures/lastError/outageSeconds through status.placements, and
    the UNKNOWN message names the endpoint — then the job completes once the
    outage lifts, with nothing migrated."""
    fp = FaultProfile(seed=2)
    with BridgeEnvironment(default_duration=0.4, slots=8,
                           fault_profiles={"slurm": fp}) as env:
        count = 6
        h = env.bridge.submit("degrade", env.make_spec(
            "slurm", script="member", updateinterval=0.02, unknown_after=3,
            jobproperties={"WallSeconds": "0.4"},
            array=ArraySpec(count=count),
            placement=_placement(["slurm", "lsf"])))  # no failover at all
        assert _wait(lambda: len(_ids(h)) == count, timeout=60)
        fp.begin_outage()

        def degraded():
            pl = h.placements()
            return pl and pl[0].get("failures", 0) >= 1 and \
                pl[0].get("lastError")
        assert _wait(degraded, timeout=60)
        assert _wait(lambda: h.status().state == UNKNOWN, timeout=60)
        msg = h.status().message
        assert "slice 0 resource unreachable" in msg
        assert URLS["slurm"] in msg and "failed polls" in msg
        pl = h.placements()
        assert pl[0]["outageSeconds"] > 0
        fp.end_outage()
        job = h.wait(timeout=120)
        assert job.status.state == DONE, job.status.message
        # healthy again: the degradation keys disappear from the snapshot
        assert all("failures" not in p and "lastError" not in p
                   for p in h.placements())
        assert all(p["state"] != LOST for p in h.placements())


# ---------------------------------------------------------------------------
# transport: bounded GET retry + reply-lost partitions
# ---------------------------------------------------------------------------


def test_channel_retries_idempotent_gets_once_per_blip():
    fp = FaultProfile()
    srv = RestServer(fault=fp)
    hits = {"GET": 0, "POST": 0}

    def ping(groups, body):
        hits["GET"] += 1
        from repro.core.rest import HttpResponse
        return HttpResponse(200, {"ok": True})

    def poke(groups, body):
        hits["POST"] += 1
        from repro.core.rest import HttpResponse
        return HttpResponse(200, {"ok": True})

    srv.route("GET", "/ping", ping)
    srv.route("POST", "/poke", poke)
    ch = Channel(srv, url="http://unit")

    # one blip: the GET retries in-call and succeeds
    fp.fail_next(1)
    assert ch.request("GET", "/ping").status == 200
    assert ch.retries == 1 and hits["GET"] == 1

    # blips exceeding the budget (1 + GET_RETRIES) surface as the error
    fp.fail_next(1 + Channel.GET_RETRIES)
    with pytest.raises(TransportError):
        ch.request("GET", "/ping")

    # writes are NEVER retried by the transport (idempotency is the
    # protocol layer's job): one blip = one failure, handler untouched
    before = ch.retries
    fp.fail_next(1)
    with pytest.raises(TransportError):
        ch.request("POST", "/poke")
    assert ch.retries == before and hits["POST"] == 0


def test_partition_runs_handler_but_loses_reply():
    """begin_partition(): the request EXECUTES server-side but the client
    sees a TransportError — the at-most-once hazard failover must respect.
    A GET rides its in-call retries; each retry re-runs the handler."""
    fp = FaultProfile()
    srv = RestServer(fault=fp)
    hits = {"n": 0}

    def ping(groups, body):
        hits["n"] += 1
        from repro.core.rest import HttpResponse
        return HttpResponse(200, {"n": hits["n"]})

    srv.route("GET", "/ping", ping)
    ch = Channel(srv, url="http://part")
    fp.begin_partition()
    with pytest.raises(TransportError):
        ch.request("GET", "/ping")
    assert hits["n"] == 1 + Channel.GET_RETRIES, \
        "handler ran despite every reply being lost"
    fp.end_partition()
    assert ch.request("GET", "/ping").json["n"] == hits["n"]
