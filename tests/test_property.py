"""Property-based tests (hypothesis) on the system's invariants."""
import json
import string

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

SETTINGS = dict(deadline=None, max_examples=30,
                suppress_health_check=[HealthCheck.too_slow])

keys = st.text(string.ascii_lowercase + string.digits + "_-", min_size=1,
               max_size=12)
vals = st.text(max_size=24)


# -- StateStore vs dict model -----------------------------------------------


@settings(**SETTINGS)
@given(ops=st.lists(st.tuples(st.sampled_from(["update", "replace"]),
                              st.dictionaries(keys, vals, max_size=4)),
                    max_size=12))
def test_statestore_matches_dict_model(tmp_path_factory, ops):
    from repro.core.statestore import StateStore

    root = tmp_path_factory.mktemp("ss")
    store = StateStore(root=str(root))
    cm = store.create("ns/cm", {})
    model = {}
    for op, data in ops:
        if op == "update":
            cm.update(data)
            model.update({k: str(v) for k, v in data.items()})
        else:
            cm.replace(data)
            model = {k: str(v) for k, v in data.items()}
    assert cm.data == model
    # durability: a fresh store over the same root sees identical state
    assert StateStore(root=str(root)).get("ns/cm").data == model


# -- ObjectStore vs dict model ---------------------------------------------


@settings(**SETTINGS)
@given(ops=st.lists(st.tuples(st.sampled_from(["put", "delete"]), keys,
                              st.binary(max_size=64)), max_size=16))
def test_objectstore_matches_dict_model(ops):
    from repro.core.objectstore import NoSuchKey, ObjectStore

    store = ObjectStore()
    model = {}
    for op, key, data in ops:
        if op == "put":
            store.put("b", key, data)
            model[key] = data
        else:
            store.delete("b", key)
            model.pop(key, None)
    assert store.list("b") == sorted(model)
    for k, v in model.items():
        assert store.get("b", k) == v


# -- Registry: versions increase, watch stream is complete --------------------


@settings(**SETTINGS)
@given(n_jobs=st.integers(1, 5), n_kills=st.integers(0, 5))
def test_registry_watch_and_versions(n_jobs, n_kills):
    import dataclasses

    from repro.core.registry import ResourceRegistry
    from repro.core.resource import BridgeJob, BridgeJobSpec

    reg = ResourceRegistry()
    q = reg.watch()
    spec = BridgeJobSpec(resourceURL="u", image="slurmpod:1",
                         resourcesecret="s")
    versions = []
    for i in range(n_jobs):
        j = reg.create(BridgeJob(name=f"j{i}", spec=spec))
        versions.append(j.resource_version)
    for i in range(min(n_kills, n_jobs)):
        j = reg.update_spec(f"j{i}", lambda s: dataclasses.replace(s, kill=True))
        versions.append(j.resource_version)
    assert versions == sorted(versions) and len(set(versions)) == len(versions)
    events = []
    while not q.empty():
        events.append(q.get())
    adds = [e for e in events if e[0] == "ADDED"]
    mods = [e for e in events if e[0] == "MODIFIED"]
    assert len(adds) == n_jobs
    assert len(mods) == min(n_kills, n_jobs)


# -- Pipeline toposort respects dependencies -----------------------------------


@settings(**SETTINGS)
@given(st.data())
def test_pipeline_toposort_respects_deps(data):
    from repro.workflows.pipeline import Pipeline, PipelineOp

    n = data.draw(st.integers(2, 8))
    # random DAG: op i may depend on any subset of ops < i (acyclic by
    # construction)
    deps = {i: data.draw(st.lists(st.integers(0, i - 1), unique=True,
                                  max_size=i)) if i else []
            for i in range(n)}
    order = []
    pipe = Pipeline("p")
    for i in range(n):
        pipe.add(PipelineOp(f"op{i}",
                            (lambda i_: lambda ctx: order.append(i_))(i),
                            after=[f"op{d}" for d in deps[i]]))
    pipe.run()
    pos = {i: order.index(i) for i in range(n)}
    for i, ds in deps.items():
        for d in ds:
            assert pos[d] < pos[i], f"op{d} must run before op{i}"


# -- Controller state machine: never invents terminal states -------------------


@settings(**SETTINGS)
@given(states=st.lists(
    st.sampled_from(["QUEUED", "RUNNING", "COMPLETED", "FAILED", "CANCELLED"]),
    min_size=1, max_size=8))
def test_bridge_state_mapping_is_sound(states):
    """For ANY backend state sequence, the bridge status mapping is the
    documented lifecycle and terminality is decided only by the backend."""
    from repro.core.backends import base as B
    from repro.core.controller import _CANON_TO_BRIDGE
    from repro.core.resource import DONE, FAILED, KILLED, TERMINAL_STATES

    for s in states:
        mapped = _CANON_TO_BRIDGE[s]
        if s in B.TERMINAL:
            assert mapped in TERMINAL_STATES
        else:
            assert mapped not in TERMINAL_STATES
    assert _CANON_TO_BRIDGE["COMPLETED"] == DONE
    assert _CANON_TO_BRIDGE["FAILED"] == FAILED
    assert _CANON_TO_BRIDGE["CANCELLED"] == KILLED


# -- Sharding: spec_for never duplicates axes, always divides ----------------


@settings(**SETTINGS)
@given(st.data())
def test_spec_for_invariants(data):
    import jax
    from repro.sharding import make_rules, spec_for

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # (mesh axes have size 1 here; divisibility is trivially satisfied —
    # exercise the duplicate-axis logic with a fake 16x16 mesh dict instead)
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    rules = make_rules(mesh, "fsdp_tp")
    logical = ["embed", "heads", "kv_heads", "mlp", "vocab", "expert",
               "inner", None]
    rank = data.draw(st.integers(1, 4))
    shape = tuple(data.draw(st.sampled_from([1, 8, 16, 24, 32, 48, 256]))
                  for _ in range(rank))
    axes = tuple(data.draw(st.sampled_from(logical)) for _ in range(rank))
    spec = spec_for(shape, axes, rules, FakeMesh())
    used = []
    for dim, entry in zip(shape, tuple(spec)):
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            assert ax not in used, f"duplicate {ax} in {spec}"
            used.append(ax)
            assert dim % FakeMesh.shape[ax] == 0


# -- Quantization error bound -------------------------------------------------


@settings(**SETTINGS)
@given(st.data())
def test_quantize_error_bound(data):
    from repro.optim.compression import dequantize_int8, quantize_int8

    n = data.draw(st.integers(1, 64))
    scale_mag = data.draw(st.floats(1e-4, 1e4))
    arr = np.asarray(data.draw(st.lists(
        st.floats(-1.0, 1.0, allow_nan=False), min_size=n, max_size=n)),
        np.float32) * scale_mag
    q, s = quantize_int8(jnp.asarray(arr))
    err = np.abs(np.asarray(dequantize_int8(q, s)) - arr)
    # half-step bound, with an f32-rounding allowance on the scale itself
    assert err.max() <= float(s) * 0.5 * (1 + 1e-4) + 1e-9


# -- Data pipeline: tokens in range, affine law holds -------------------------


@settings(**SETTINGS)
@given(vocab=st.integers(2, 1000), step=st.integers(0, 10_000),
       seed=st.integers(0, 100))
def test_dataset_affine_law(vocab, step, seed):
    from repro.data import DataConfig, SyntheticDataset

    ds = SyntheticDataset(DataConfig(vocab=vocab, seq_len=8, global_batch=2,
                                     seed=seed))
    b = ds.batch(step)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < vocab
    np.testing.assert_array_equal(
        b["targets"], (ds._a * b["tokens"].astype(np.int64) + ds._c) % vocab)
