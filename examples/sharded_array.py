"""Sharded placement: one 48-index array CR split across TWO external
resources (SLURM + LSF), load-proportionally, then rebalanced.

The CR declares placement *candidates* instead of a single resourceURL; the
scheduler splits the index space into per-resource slices sized by free
capacity, each slice submits natively on its own endpoint, and an elastic
scale-up routes the delta to the least-loaded slice.

  PYTHONPATH=src python examples/sharded_array.py
"""
from repro.core import (ArraySpec, BridgeEnvironment, IMAGES,
                        PlacementCandidate, PlacementSpec, URLS)


def main() -> None:
    with BridgeEnvironment(default_duration=0.3, slots=8) as env:
        env.clusters["lsf"].slots = 4  # uneven capacity: 8 vs 4 slots

        spec = env.make_spec(
            "slurm", script="member", updateinterval=0.05,
            jobproperties={"WallSeconds": "0.3"},
            array=ArraySpec(count=48),
            placement=PlacementSpec(candidates=[
                PlacementCandidate(URLS["slurm"], IMAGES["slurm"],
                                   "slurm-secret"),
                PlacementCandidate(URLS["lsf"], IMAGES["lsf"], "lsf-secret"),
            ], strategy="spread"))
        handle = env.bridge.submit("shard-demo", spec)
        print("sliced BridgeJob created; operator planning slices...")

        handle.wait_reconciled(timeout=60)
        for p in handle.placements():
            print(f"  slice {p['slice']}: {len(p['indices'])} indices on "
                  f"{p['resourceURL']} [{p['state']}]")

        print("scaling 48 -> 60: delta goes to the least-loaded slice")
        handle.scale(60)
        handle.wait_reconciled(timeout=60)
        for p in handle.placements():
            print(f"  slice {p['slice']}: {len(p['indices'])} indices on "
                  f"{p['resourceURL']} [{p['state']}]")

        job = handle.wait(timeout=120)
        print(f"final: {job.status.state} with "
              f"{len(job.status.index_states)} indices across "
              f"{len(job.status.placements)} resources "
              f"(slurm={len(env.clusters['slurm'].jobs)} jobs, "
              f"lsf={len(env.clusters['lsf'].jobs)} jobs)")
        assert job.status.state == "DONE"
        union = sorted(i for p in job.status.placements for i in p["indices"])
        assert union == list(range(60)), "union of slices == desired set"


if __name__ == "__main__":
    main()
