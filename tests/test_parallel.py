"""Multi-device shard_map checks, run in a subprocess so the forced
8-device XLA flag never leaks into this process."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(*names, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "parallel_checks.py"),
         *names],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_ep_shard_map_matches_dropping():
    out = _run("ep")
    assert "OK ep_matches_dropping" in out


def test_pipeline_parallel():
    out = _run("pipeline")
    assert "OK pipeline_apply" in out


def test_compressed_mean_collective():
    out = _run("compressed")
    assert "OK compressed_mean" in out


def test_sharded_train_step_three_families():
    out = _run("train")
    assert out.count("OK sharded_train_step") == 3


def test_checkpoint_reshard_on_load():
    out = _run("reshard")
    assert "OK checkpoint_reshard_on_load" in out
