"""Version-portable "make this mesh current" context manager.

API churn absorbed here (newest first):
  * ``jax.sharding.set_mesh(mesh)``   — jax >= 0.6 context manager;
  * ``jax.sharding.use_mesh(mesh)``   — the 0.5.x experimental spelling;
  * ``with mesh:``                    — the classic ``Mesh.__enter__``
    global-mesh context, which is what 0.4.x provides.

All three establish the mesh for subsequent ``jax.jit`` calls whose
shardings name its axes; call sites always write
``with use_mesh(mesh): ...`` and never touch the underlying API.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Callable, Iterator

import jax
from jax.sharding import Mesh


@functools.lru_cache(maxsize=None)
def _resolve() -> Callable[[Mesh], object]:
    for name in ("set_mesh", "use_mesh"):
        fn = getattr(jax.sharding, name, None)
        if fn is not None:
            return fn
    return lambda mesh: mesh  # Mesh is itself a context manager


@functools.lru_cache(maxsize=None)
def use_mesh_source() -> str:
    fn = _resolve()
    name = getattr(fn, "__name__", "")
    if name in ("set_mesh", "use_mesh"):
        return f"jax.sharding.{name}"
    return "jax.sharding.Mesh.__enter__"


@contextlib.contextmanager
def use_mesh(mesh: Mesh) -> Iterator[Mesh]:
    """``with use_mesh(mesh):`` — portable across every supported JAX."""
    with _resolve()(mesh):
        yield mesh
