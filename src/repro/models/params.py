"""Parameter definitions: one source of truth for shape, logical axes, init.

A model is described as a pytree of ``ParamDef``s.  From that single tree we
derive:
  * concrete initialized parameters (``init_params``),
  * abstract ``ShapeDtypeStruct`` stand-ins for the dry-run (``abstract_params``),
  * ``PartitionSpec``s via logical-axis rules (``repro.sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = replicated)
    init: str = "normal"  # normal | zeros | ones | scaled | embed
    scale: float = 1.0  # stddev multiplier / fan-in override
    dtype: Any = jnp.bfloat16

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def is_paramdef(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(rng: jax.Array, d: ParamDef) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "normal":
        # truncated-normal, stddev = scale / sqrt(fan_in); fan_in = second-to-last
        # dim for matrices (stacked-layer leading dims excluded by convention:
        # the last two dims are the matmul dims).
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale / np.sqrt(max(fan_in, 1))
        x = jax.random.truncated_normal(rng, -2.0, 2.0, d.shape, jnp.float32) * std
        return x.astype(d.dtype)
    if d.init == "embed":
        x = jax.random.truncated_normal(rng, -2.0, 2.0, d.shape, jnp.float32) * d.scale
        return x.astype(d.dtype)
    if d.init == "scaled":  # uniform in +-scale (conv/ssm misc params)
        x = jax.random.uniform(rng, d.shape, jnp.float32, -d.scale, d.scale)
        return x.astype(d.dtype)
    raise ValueError(f"unknown init {d.init!r}")


def init_params(rng: jax.Array, defs: Any) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_paramdef)
    rngs = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(r, d) for r, d in zip(rngs, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(defs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_paramdef
    )


def param_axes(defs: Any) -> Any:
    """Tree of logical-axes tuples, mirroring the param tree."""
    return jax.tree_util.tree_map(lambda d: d.axes, defs, is_leaf=is_paramdef)


def count_params(defs: Any) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_paramdef)
    return int(sum(int(np.prod(d.shape)) for d in leaves))


def param_bytes(defs: Any) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_paramdef)
    return int(sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize for d in leaves))


def cast_tree(tree: Any, dtype: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
