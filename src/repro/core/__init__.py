"""The paper's primary contribution: the Bridge Operator control plane.

Public surface:
  BridgeJob / BridgeJobSpec        — the versioned CRD analogue (resource.py)
  convert / ConversionError         — v1alpha1 <-> v1beta1 conversion layer
  Bridge / JobHandle                — the one client facade (api.py)
  Capability                        — typed adapter capabilities (backends/base.py)
  ResourceRegistry                  — declarative store + watch (registry.py)
  StateStore / ConfigMap            — the ConfigMap analogue (statestore.py)
  ObjectStore                       — S3 analogue (objectstore.py)
  SecretStore                       — secret mounts (secrets.py)
  ControllerPod / JobProtocol       — paper Figs. 2-3 (controller.py)
  MonitorRuntime / MonitorTask      — multiplexed monitor pool (monitor.py)
  BridgeOperator                    — the reconciler (operator.py)
  LoadAwareScheduler                — paper §7 future work (scheduler.py)
  BridgeEnvironment                 — cluster-in-a-box wiring (cluster.py)
  BridgeService / BridgeServiceSpec — replicated serving CRD (resource.py)
  ServiceProtocol                   — health-checked reconcile (service.py)
  ServiceHandle / ServiceEndpoint   — serving client + router (router.py)
"""
from repro.core.resource import (API_V1ALPHA1, API_V1BETA1, API_VERSIONS,
                                 ArraySpec, AutoscaleSpec, BridgeJob,
                                 BridgeJobSpec,
                                 BridgeJobStatus, BridgeService,
                                 BridgeServiceSpec, BridgeServiceStatus,
                                 ConversionError, FailoverSpec, HealthProbeSpec,
                                 JobData, PlacementCandidate, PlacementSpec,
                                 RetryPolicy, S3Storage, SERVICE_KIND,
                                 ValidationError,
                                 PENDING, SUBMITTED, RUNNING, DONE, FAILED,
                                 KILLED, UNKNOWN, LOST, TERMINAL_STATES,
                                 convert, load_bridgejob, service_spec_from_dict,
                                 service_spec_to_dict)
from repro.core.registry import ResourceRegistry
from repro.core.statestore import ConfigMap, StateStore
from repro.core.objectstore import NoSuchKey, ObjectStore
from repro.core.secrets import SecretNotFound, SecretStore
from repro.core.rest import (Channel, FaultProfile,
                             ResourceManagerDirectory, RestClient,
                             RestServer, TransportError)
from repro.core.backends.base import (BATCH_STATUS_CHUNK, Capability,
                                      resolve_adapter)
from repro.core.api import Bridge, JobHandle
from repro.core.controller import ControllerPod, JobProtocol, TickObs
from repro.core.monitor import (AdaptiveCadence, Cadence, FixedCadence,
                                MonitorRuntime, MonitorTask)
from repro.core.operator import BridgeOperator, default_adapters
from repro.core.scheduler import (Candidate, LoadAwareScheduler, LoadProbe,
                                  plan_placement, plan_slices)
from repro.core.service import ServiceProtocol
from repro.core.router import (NoReadyReplicas, ServiceEndpoint,
                               ServiceHandle)
from repro.core.cluster import IMAGES, TOKENS, URLS, BridgeEnvironment
