"""Chunked selective-scan Pallas TPU kernel (hymba's SSM hot-spot).

The recurrence h_t = dA_t * h_{t-1} + dBx_t is memory-bound: the XLA
associative-scan materializes all (B,S,di,N) intermediates in HBM
(O(S log S) traffic).  The kernel streams (chunk, di, N) tiles through VMEM,
carries h in scratch across the sequential chunk grid dim, and fuses the
y_t = <h_t, C_t> contraction so h never round-trips to HBM — one read of
dA/dBx/C and one write of y total.

Grid: (B, n_chunks), chunks innermost/sequential.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import mosaic_params, resolve_interpret


def _ssm_kernel(dA_ref, dBx_ref, C_ref, y_ref, h_last_ref, h_scr, *,
                chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    dA = dA_ref[0].astype(jnp.float32)       # (chunk, di, N)
    dBx = dBx_ref[0].astype(jnp.float32)
    C = C_ref[0].astype(jnp.float32)         # (chunk, N)

    def step(t, carry):
        h, y = carry
        h = dA[t] * h + dBx[t]               # (di, N)
        y = y.at[t].set(h @ C[t])            # (di,)
        return h, y

    h0 = h_scr[...]
    y0 = jnp.zeros((chunk, dA.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h0, y0))
    h_scr[...] = h
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        h_last_ref[0] = h_scr[...]


def _ssm_fused_kernel(delta_ref, b_ref, c_ref, x_ref, a_ref, y_ref,
                      h_last_ref, h_scr, *, chunk: int, n_chunks: int):
    """Fused-discretization variant: dA/dBx are built IN VMEM from
    (delta, B, x, A) — HBM reads drop from O(S·di·N) to O(S·(di+N)),
    ~(di·N)/(di+N) x less traffic (e.g. 32x for di=3200, N=16).
    The math must stay in lockstep with ref.ssm_discretize (the XLA
    fallback path in ops.py uses that definition)."""
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    delta = delta_ref[0].astype(jnp.float32)   # (chunk, di)
    Bm = b_ref[0].astype(jnp.float32)          # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)          # (chunk, N)
    x = x_ref[0].astype(jnp.float32)           # (chunk, di)
    A = a_ref[...].astype(jnp.float32)         # (di, N)

    def step(t, carry):
        h, y = carry
        dA = jnp.exp(delta[t][:, None] * A)            # (di, N) in VMEM
        dBx = delta[t][:, None] * Bm[t][None, :] * x[t][:, None]
        h = dA * h + dBx
        y = y.at[t].set(h @ Cm[t])
        return h, y

    h0 = h_scr[...]
    y0 = jnp.zeros((chunk, delta.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h0, y0))
    h_scr[...] = h
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        h_last_ref[0] = h_scr[...]


def ssm_scan_fused(delta: jax.Array, B: jax.Array, C: jax.Array,
                   x: jax.Array, A: jax.Array, *, chunk: int = 16,
                   interpret: Optional[bool] = None):
    """delta,x: (B,S,di); B,C: (B,S,N); A: (di,N).  S % chunk == 0.
    Returns (y (B,S,di) f32, h_last (B,di,N) f32)."""
    interpret = resolve_interpret(interpret)
    b, s, di = delta.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk}")
    n_chunks = s // chunk
    kernel = functools.partial(_ssm_fused_kernel, chunk=chunk,
                               n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(b, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, di), lambda b_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk, di), lambda b_, ci: (b_, ci, 0)),
            pl.BlockSpec((di, n), lambda b_, ci: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, di), lambda b_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, di, n), lambda b_, ci: (b_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, di), jnp.float32),
            jax.ShapeDtypeStruct((b, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((di, n), jnp.float32)],
        interpret=interpret,
        **mosaic_params(dimension_semantics=("parallel", "arbitrary")),
    )(delta, B, C, x, A)


def ssm_scan_chunked(dA: jax.Array, dBx: jax.Array, C: jax.Array, *,
                     chunk: int = 16, interpret: Optional[bool] = None):
    """dA, dBx: (B,S,di,N); C: (B,S,N).  S must be a multiple of ``chunk``.
    Returns (y (B,S,di) f32, h_last (B,di,N) f32)."""
    interpret = resolve_interpret(interpret)
    b, s, di, n = dA.shape
    chunk = min(chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk}")
    n_chunks = s // chunk

    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(b, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, di, n), lambda b_, ci: (b_, ci, 0, 0)),
            pl.BlockSpec((1, chunk, di, n), lambda b_, ci: (b_, ci, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, ci: (b_, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, di), lambda b_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, di, n), lambda b_, ci: (b_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, di), jnp.float32),
            jax.ShapeDtypeStruct((b, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((di, n), jnp.float32)],
        interpret=interpret,
        **mosaic_params(dimension_semantics=("parallel", "arbitrary")),
    )(dA, dBx, C)
