"""Load-aware backend selection — the paper's named FUTURE WORK (§7):

    "Future work will focus on creating companion operator using the same
    approach to monitor current load on these remote resources and make
    intelligent decisions on which remote resource ... to use for execution."

Beyond-paper feature: a companion that polls each registered resource
manager's queue via the SAME HTTP surface the bridge uses, scores load, and
picks a target.  Also provides speculative (straggler-mitigation) execution:
launch the same payload on the two least-loaded resources, keep the first
finisher, kill the other.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Mapping, Optional, Tuple, Type

from repro.core.backends import base as B
from repro.core.registry import ResourceRegistry
from repro.core.resource import BridgeJob, BridgeJobSpec, DONE, KILLED
from repro.core.rest import ResourceManagerDirectory, TransportError
from repro.core.secrets import SecretStore


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One schedulable target: where + how to talk to it."""
    resourceURL: str
    image: str           # selects the controller-pod adapter
    resourcesecret: str


class LoadAwareScheduler:
    def __init__(self, directory: ResourceManagerDirectory, secrets: SecretStore,
                 adapters: Mapping[str, Type[B.ResourceAdapter]],
                 candidates: List[Candidate]):
        self.directory = directory
        self.secrets = secrets
        self.adapters = dict(adapters)
        self.candidates = list(candidates)

    def load_of(self, cand: Candidate) -> Optional[float]:
        """Normalized load: (queued + running) / slots.  None if unreachable."""
        try:
            token = self.secrets.mount(cand.resourcesecret).get("token", "")
            client = self.directory.connect(cand.resourceURL, token)
            adapter = self.adapters[cand.image.split(":")[0]](client)
            q = adapter.queue_load()
        except (TransportError, KeyError):
            return None
        if not q or not q.get("slots"):
            return None
        return (q["queued"] + q["running"]) / q["slots"]

    def rank(self) -> List[Tuple[float, Candidate]]:
        scored = []
        for c in self.candidates:
            load = self.load_of(c)
            if load is not None:
                scored.append((load, c))
        scored.sort(key=lambda t: t[0])
        return scored

    def pick(self) -> Candidate:
        ranked = self.rank()
        if not ranked:
            raise RuntimeError("no reachable candidate resource")
        return ranked[0][1]

    def place(self, spec: BridgeJobSpec) -> BridgeJobSpec:
        """Rewrite a spec to target the least-loaded candidate."""
        best = self.pick()
        return dataclasses.replace(spec, resourceURL=best.resourceURL,
                                   image=best.image,
                                   resourcesecret=best.resourcesecret)

    # -- speculative execution (straggler mitigation) ------------------------

    def submit_speculative(self, operator, base_name: str, spec: BridgeJobSpec,
                           n: int = 2, namespace: str = "default",
                           timeout: float = 60.0) -> BridgeJob:
        """Run the payload on the ``n`` least-loaded resources; return the
        first DONE job and kill the rest.  Raises if all replicas fail."""
        ranked = self.rank()
        if not ranked:
            raise RuntimeError("no reachable candidate resource")
        names = []
        for i, (_, cand) in enumerate(ranked[:n]):
            s = dataclasses.replace(spec, resourceURL=cand.resourceURL,
                                    image=cand.image,
                                    resourcesecret=cand.resourcesecret)
            name = f"{base_name}-spec{i}"
            operator.registry.create(BridgeJob(name=name, spec=s,
                                               namespace=namespace))
            names.append(name)
        deadline = time.time() + timeout
        winner: Optional[BridgeJob] = None
        while time.time() < deadline and winner is None:
            done = [operator.registry.get(n_, namespace) for n_ in names]
            for job in done:
                if job and job.status.state == DONE:
                    winner = job
                    break
            if all(j and j.status.terminal() and j.status.state != DONE
                   for j in done):
                raise RuntimeError(
                    f"all speculative replicas failed: "
                    f"{[(j.name, j.status.state) for j in done]}")
            time.sleep(0.01)
        if winner is None:
            raise TimeoutError("speculative execution timed out")
        for n_ in names:  # kill the stragglers
            if n_ != winner.name:
                job = operator.registry.get(n_, namespace)
                if job and not job.status.terminal():
                    operator.kill(n_, namespace)
        return winner
