"""BridgeService: replicated serving with health-checked replicas and a
load-balanced request router — the chaos suite.

The tentpole guarantees under test:

  * replicas are long-lived: a serve-mode remote job is never treated as
    terminal-success (walltime expiry included) — only a kill ends the
    service;
  * a replica that dies (or stops answering its health probe while RUNNING)
    is condemned and replaced IN PLACE within the health-check budget, under
    the same at-most-once-while-live invariants as job arrays: a live
    replica's remote job is never resubmitted;
  * the router only ever routes to replicas the control plane reports ready
    — a condemned replica is drained the same tick its probe budget runs
    out, and the cluster-side ``invocations`` counter proves no request
    reached it after the drop;
  * ``status.endpoints`` lives in the config map, so it survives operator
    pod death: the restarted pod resumes monitoring the SAME remote jobs.

Both operator modes run the same ServiceProtocol and every assertion is
cadence-agnostic (services pin a fixed probe cadence regardless of the
operator's cadence flag), so the suite runs the full (mode, cadence) matrix
on the lifecycle + chaos paths.
"""
import threading
import time

import pytest

from repro.core import (ArraySpec, AutoscaleSpec, BridgeEnvironment,
                        BridgeService,
                        BridgeServiceSpec, HealthProbeSpec, IMAGES, KILLED,
                        PlacementCandidate, PlacementSpec, RUNNING, URLS,
                        ValidationError)
from repro.core.backends import base as B

MODES = ["multiplexed", "pod-per-cr"]
OPERATORS = [(m, "fixed") for m in MODES] + [
    ("multiplexed", "adaptive"), ("multiplexed", "watch")]

# every slurm probe tick is one GET per replica; keep the interval small so
# the health budget (threshold x interval) stays well under test timeouts
INTERVAL = 0.02
HEALTH = HealthProbeSpec(failure_threshold=3, startup_failure_threshold=50)


def _wait(predicate, timeout=30, interval=0.005):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


def _env(mode="multiplexed", cadence="fixed", **kw):
    # serve replicas hold a cluster slot for life, so give the simulated
    # managers headroom beyond the default 4 slots
    kw.setdefault("slots", 8)
    return BridgeEnvironment(
        operator_kwargs=dict(mode=mode, cadence=cadence), **kw)


def _service(env, name="svc", replicas=2, kind="slurm", **kw):
    spec = env.make_service_spec(kind, replicas=replicas, script="serve",
                                 updateinterval=INTERVAL,
                                 health=kw.pop("health", HEALTH), **kw)
    return env.bridge.submit_service(name, spec)


def _job_ids(handle):
    return sorted(e["job_id"] for e in handle.endpoints())


# ---------------------------------------------------------------------------
# CRD layer
# ---------------------------------------------------------------------------


def test_service_crd_round_trip():
    env = BridgeEnvironment()  # not started: only the spec factory is used
    spec = env.make_service_spec("slurm", replicas=3, script="serve",
                                 health=HealthProbeSpec(failure_threshold=5))
    svc = BridgeService(name="svc", spec=spec)
    doc = svc.to_dict()
    assert doc["kind"] == "BridgeService"
    assert doc["spec"]["replicas"] == 3
    assert doc["spec"]["health"]["failure_threshold"] == 5
    back = BridgeService.from_dict(doc)
    assert back.spec == spec


def test_service_spec_validation():
    env = BridgeEnvironment()
    spec = env.make_service_spec("slurm", script="serve")
    with pytest.raises(ValidationError):
        BridgeServiceSpec(template=spec.template, replicas=0).validate()
    with pytest.raises(ValidationError):
        BridgeServiceSpec(
            template=env.make_spec("slurm", script="serve",
                                   array=ArraySpec(count=2))).validate()
    with pytest.raises(ValidationError):
        BridgeServiceSpec(
            template=spec.template,
            health=HealthProbeSpec(failure_threshold=0)).validate()


def test_autoscale_spec_validation_and_round_trip():
    env = BridgeEnvironment()
    base = env.make_service_spec("slurm", script="serve")
    good = AutoscaleSpec(min_replicas=1, max_replicas=4,
                         target_outstanding_per_replica=2.0,
                         target_p99_seconds=0.5,
                         scale_up_cooldown_seconds=1.0,
                         scale_down_cooldown_seconds=2.0)
    spec = BridgeServiceSpec(template=base.template, replicas=2,
                             autoscale=good)
    spec.validate()
    # round trip: autoscale survives, and its ABSENCE leaves the serialized
    # spec byte-identical to the pre-autoscale shape
    doc = BridgeService(name="svc", spec=spec).to_dict()
    assert doc["spec"]["autoscale"]["maxReplicas"] == 4
    assert BridgeService.from_dict(doc).spec == spec
    assert "autoscale" not in BridgeService(name="svc", spec=base).to_dict()["spec"]

    with pytest.raises(ValidationError):  # min > max
        AutoscaleSpec(min_replicas=3, max_replicas=2,
                      target_outstanding_per_replica=1.0).validate()
    with pytest.raises(ValidationError):  # no target at all
        AutoscaleSpec(min_replicas=1, max_replicas=2).validate()
    with pytest.raises(ValidationError):  # non-positive target
        AutoscaleSpec(max_replicas=2,
                      target_outstanding_per_replica=0).validate()
    with pytest.raises(ValidationError):  # negative cooldown
        AutoscaleSpec(max_replicas=2, target_p99_seconds=0.5,
                      scale_up_cooldown_seconds=-1).validate()
    with pytest.raises(ValidationError):  # replicas outside [min, max]
        BridgeServiceSpec(template=base.template, replicas=8,
                          autoscale=good).validate()


def test_autoscale_off_keeps_cm_byte_compatible():
    """No spec.autoscale => the service config map carries ZERO autoscale or
    load-report keys (the PR 8 shape, byte for byte); with it, the operator
    writes the autoscale_* contract."""
    with _env() as env:
        h = _service(env, name="plain", replicas=1)
        h.wait_ready(timeout=20)
        r = h.router(request_timeout=10)
        for i in range(5):
            r.request({"i": i})
        time.sleep(0.1)
        data = env.statestore.get("default/plain-bridge-cm").data
        assert not [k for k in data if k.startswith(("autoscale", "loadreport"))]

        spec = env.make_service_spec(
            "slurm", replicas=1, script="serve", updateinterval=INTERVAL,
            health=HEALTH,
            autoscale=AutoscaleSpec(min_replicas=1, max_replicas=2,
                                    target_outstanding_per_replica=4.0))
        h2 = env.bridge.submit_service("scaled", spec)
        h2.wait_ready(timeout=20)
        data = env.statestore.get("default/scaled-bridge-cm").data
        assert data["autoscale_min"] == "1" and data["autoscale_max"] == "2"
        assert data["autoscale_target_outstanding"] == "4.0"
        assert "autoscale_target_p99" not in data  # unset target not written
        for h_ in (h, h2):
            h_.cancel()
            h_.wait(timeout=20)


# ---------------------------------------------------------------------------
# lifecycle: ready / scale / kill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,cadence", OPERATORS)
def test_service_lifecycle(mode, cadence):
    with _env(mode, cadence) as env:
        h = _service(env, replicas=3)
        svc = h.wait_ready(timeout=20)
        assert svc.status.state == RUNNING
        assert svc.status.ready_replicas == 3
        ids = _job_ids(h)
        assert len(set(ids)) == 3, "each replica is its own remote job"

        # scale up: existing replicas keep their remote jobs (at most once)
        h.scale(5)
        h.wait_reconciled(timeout=20)
        h.wait_ready(replicas=5, timeout=20)
        assert set(ids) <= set(_job_ids(h)), "scale-up resubmitted a live replica"

        # scale down: highest replica indices drained, the rest untouched
        before = {e["replica"]: e["job_id"] for e in h.endpoints()}
        h.scale(2)
        h.wait_reconciled(timeout=20)
        assert _wait(lambda: len(h.endpoints()) == 2
                     and h.ready_replicas() == 2, timeout=20)
        after = {e["replica"]: e["job_id"] for e in h.endpoints()}
        assert set(after) == {0, 1}
        assert all(after[i] == before[i] for i in after), (
            "scale-down touched a surviving replica")

        h.cancel()
        svc = h.wait(timeout=20)
        assert svc.status.state == KILLED
        # every remote job the service ever owned is terminal
        assert _wait(lambda: all(
            j.state in (B.COMPLETED, B.FAILED, B.CANCELLED)
            for j in env.clusters["slurm"].jobs.values()), timeout=10)


def test_serve_jobs_never_complete_on_walltime():
    """A serve replica outlives the cluster's walltime default — expiry must
    not be mistaken for success (the whole point is staying up)."""
    with _env(default_duration=0.05) as env:  # tiny default walltime
        h = _service(env, replicas=1,
                     jobproperties={"WallSeconds": "0.05"})
        h.wait_ready(timeout=20)
        time.sleep(0.5)  # 10x the walltime
        assert h.ready_replicas() == 1
        assert h.status().state == RUNNING
        jid = h.endpoints()[0]["job_id"]
        assert env.clusters["slurm"].jobs[jid].state == B.RUNNING
        h.cancel()
        h.wait(timeout=20)


def test_service_scale_guard():
    with _env() as env:
        h = _service(env, replicas=1)
        h.wait_ready(timeout=20)
        with pytest.raises(ValidationError):
            h.scale(0)
        h.cancel()
        h.wait(timeout=20)
        with pytest.raises(ValidationError):
            h.scale(3)


# ---------------------------------------------------------------------------
# placement: replicas spread over multiple resource managers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_service_spreads_replicas_across_resources(mode):
    with _env(mode) as env:
        placement = PlacementSpec(candidates=[
            PlacementCandidate(URLS["slurm"], IMAGES["slurm"], "slurm-secret"),
            PlacementCandidate(URLS["lsf"], IMAGES["lsf"], "lsf-secret"),
        ], strategy="spread")
        h = _service(env, replicas=4, placement=placement)
        h.wait_ready(timeout=20)
        urls = {e["resourceURL"] for e in h.endpoints()}
        assert urls == {URLS["slurm"], URLS["lsf"]}, (
            "spread placement must land replicas on both managers")
        # requests flow to replicas on BOTH managers
        r = h.router(request_timeout=10)
        for i in range(8):
            assert r.request({"i": i})["echo"] == {"i": i}
        served = {s["job_id"] for s in r.stats().values() if s["requests"]}
        assert len(served) >= 2
        h.cancel()
        h.wait(timeout=20)


# ---------------------------------------------------------------------------
# chaos: replica death, unhealthy replicas, router drain, pod death
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,cadence", OPERATORS)
def test_replica_kill_mid_traffic_is_replaced_within_budget(mode, cadence):
    """Kill a replica's remote job while the router is under load: no
    accepted request is lost, the replica is replaced with a fresh remote
    job within the health-check budget, and readyReplicas converges."""
    with _env(mode, cadence) as env:
        h = _service(env, replicas=2)
        h.wait_ready(timeout=20)
        router = h.router(request_timeout=15)

        stop = threading.Event()
        failures = []

        def traffic():
            i = 0
            while not stop.is_set():
                try:
                    out = router.request({"seq": i})
                    if out["echo"] != {"seq": i}:
                        failures.append(("bad-echo", i, out))
                except Exception as exc:  # lost accepted request
                    failures.append(("error", i, repr(exc)))
                i += 1

        threads = [threading.Thread(target=traffic) for _ in range(3)]
        for t in threads:
            t.start()
        time.sleep(0.1)  # requests in flight

        victim = h.endpoints()[0]["job_id"]
        t_kill = time.time()
        env.clusters["slurm"].cancel_if_live(victim)
        assert _wait(lambda: victim not in _job_ids(h)
                     and h.ready_replicas() == 2, timeout=20), (
            "killed replica not replaced")
        recovery = time.time() - t_kill
        # terminal replicas are detected in one status poll; allow generous
        # scheduling slack on top of the probe budget
        budget = HEALTH.failure_threshold * INTERVAL
        assert recovery < budget + 5.0, f"recovery took {recovery:.2f}s"

        time.sleep(0.1)  # traffic over the recovered set
        stop.set()
        for t in threads:
            t.join(timeout=20)
        assert not failures, failures[:5]
        assert victim not in _job_ids(h)
        h.cancel()
        h.wait(timeout=20)


@pytest.mark.parametrize("mode", MODES)
def test_unhealthy_running_replica_condemned_and_drained(mode):
    """A replica that keeps RUNNING but fails its health probe is condemned
    after failure_threshold consecutive misses, drained (ready=False, zero
    further routed requests) and replaced."""
    with _env(mode) as env:
        h = _service(env, replicas=2)
        h.wait_ready(timeout=20)
        victim = h.endpoints()[0]["job_id"]
        vjob = env.clusters["slurm"].jobs[victim]
        vjob.unhealthy.set()  # probe now 503s; the job itself keeps running
        assert _wait(lambda: victim not in _job_ids(h), timeout=20), (
            "unhealthy replica never condemned")
        assert _wait(lambda: h.ready_replicas() == 2, timeout=20)
        # drained: after the drop, no request ever reaches the condemned job
        drained_at = vjob.invocations
        r = h.router(request_timeout=10)
        for i in range(10):
            r.request({"i": i})
        assert vjob.invocations == drained_at, (
            "router sent traffic to a condemned replica")
        h.cancel()
        h.wait(timeout=20)


AUTOSCALE = AutoscaleSpec(min_replicas=1, max_replicas=4,
                          target_outstanding_per_replica=1.0,
                          scale_up_cooldown_seconds=0.1,
                          scale_down_cooldown_seconds=0.2)


@pytest.mark.parametrize("mode", MODES)
def test_service_autoscale_tracks_load_with_replica_kill(mode):
    """The autoscale chaos row: ramp load against a 1-replica service, kill
    a replica mid-ramp, and require (a) replicas converge to max within the
    cooldown budget, (b) the elastic invariants hold — surviving replicas'
    remote jobs are never resubmitted, zero requests are lost — and (c) the
    service returns to minReplicas once the load goes away."""
    with _env(mode, slots=16) as env:
        spec = env.make_service_spec(
            "slurm", replicas=1, script="serve", updateinterval=INTERVAL,
            health=HEALTH, jobproperties={"ServeLatency": "0.05"},
            autoscale=AUTOSCALE)
        h = env.bridge.submit_service("svc", spec)
        h.wait_ready(timeout=20)
        router = h.router(request_timeout=20, report_interval=0.05)

        stop = threading.Event()
        failures = []

        def traffic():
            i = 0
            while not stop.is_set():
                try:
                    out = router.request({"seq": i})
                    if out["echo"] != {"seq": i}:
                        failures.append(("bad-echo", i, out))
                except Exception as exc:
                    failures.append(("error", i, repr(exc)))
                i += 1

        threads = [threading.Thread(target=traffic) for _ in range(8)]
        t_ramp = time.time()
        for t in threads:
            t.start()

        # mid-ramp chaos: kill a replica as soon as a second one exists
        assert _wait(lambda: h.ready_replicas() >= 2, timeout=20)
        survivors = set(_job_ids(h))
        victim = h.endpoints()[0]["job_id"]
        survivors.discard(victim)
        env.clusters["slurm"].cancel_if_live(victim)

        assert _wait(lambda: victim not in _job_ids(h)
                     and h.ready_replicas() == AUTOSCALE.max_replicas,
                     timeout=20), (
            f"never converged to max with the victim replaced: "
            f"ready={h.ready_replicas()} status={h.autoscale_status()}")
        ramp_s = time.time() - t_ramp
        # 1 -> max is at most (max - 1) scale-up decisions plus the replica
        # replacement; budget the cooldown chain with generous CI slack
        budget = (AUTOSCALE.max_replicas
                  * AUTOSCALE.scale_up_cooldown_seconds) + 10.0
        assert ramp_s < budget, f"ramp took {ramp_s:.2f}s"
        assert h.autoscale_status()["desired"] == AUTOSCALE.max_replicas
        # at-most-once: every pre-kill survivor still owns its remote job
        assert survivors <= set(_job_ids(h)), (
            "autoscale/replacement resubmitted a live replica")

        stop.set()
        for t in threads:
            t.join(timeout=20)
        assert not failures, failures[:5]

        # idle: reports expire, the autoscaler walks back to the floor
        # (condemned replicas flip ready=False first, then drain away)
        assert _wait(lambda: h.ready_replicas() == AUTOSCALE.min_replicas
                     and len(h.endpoints()) == AUTOSCALE.min_replicas,
                     timeout=30), (
            f"never returned to min: ready={h.ready_replicas()} "
            f"endpoints={len(h.endpoints())} status={h.autoscale_status()}")
        h.cancel()
        h.wait(timeout=20)


def test_router_stats_pruned_under_replacement_churn():
    """Regression (router memory leak): replaced incarnations and expired
    suspensions must be pruned on resolution — the live tables stay
    O(replicas) while stats() still reports the dead jid from the bounded
    retired ring."""
    with _env() as env:
        h = _service(env, replicas=2)
        h.wait_ready(timeout=20)
        router = h.router(request_timeout=15, suspend_ttl=0.05)
        for i in range(6):
            router.request({"i": i})
        assert len(router._stats) == 2

        victims = []
        for round_ in range(3):  # churn: three successive replacements
            victim = h.endpoints()[0]["job_id"]
            victims.append(victim)
            env.clusters["slurm"].cancel_if_live(victim)
            assert _wait(lambda: victim not in _job_ids(h)
                         and h.ready_replicas() == 2, timeout=20)
            for i in range(4):
                router.request({"round": round_, "i": i})

        # live table: exactly the two current incarnations, dead jids gone
        assert len(router._stats) == 2
        assert set(router._stats) == set(_job_ids(h))
        # the suspension table holds no expired / replaced entries
        time.sleep(0.1)
        router.request({"final": 1})
        assert not [j for j in router._down if j in victims]
        # retired ring: every dead incarnation is still reportable
        stats = router.stats()
        for victim in victims:
            assert stats[victim]["retired"] is True
            assert stats[victim]["requests"] >= 0
        assert all(not stats[j]["retired"] for j in _job_ids(h))
        h.cancel()
        h.wait(timeout=20)


def test_kill_drain_reports_running_with_draining_message():
    """Regression (kill-drain status): while a killed service still has live
    replicas it must report RUNNING with an explicit draining message, not a
    stale 'N/M replicas ready' SUBMITTED.  A long updateinterval keeps the
    one-tick drain window wide enough to observe deterministically."""
    with _env() as env:
        spec = env.make_service_spec("slurm", replicas=2, script="serve",
                                     updateinterval=0.2, health=HEALTH)
        h = env.bridge.submit_service("svc", spec)
        h.wait_ready(timeout=30)
        h.cancel()

        def draining():
            st = h.status()
            return st.state == RUNNING and "draining" in st.message

        assert _wait(draining, timeout=10, interval=0.001), (
            f"no draining status observed (last: {h.status()})")
        svc = h.wait(timeout=30)
        assert svc.status.state == KILLED


@pytest.mark.parametrize("mode", MODES)
def test_endpoints_survive_operator_pod_death(mode):
    """The endpoint map is config-map state: killing the controller pod must
    not lose it, and the restarted pod resumes the SAME remote jobs (a live
    replica is never resubmitted)."""
    with _env(mode) as env:
        h = _service(env, replicas=2)
        h.wait_ready(timeout=20)
        ids = _job_ids(h)
        submitted_before = len(env.clusters["slurm"].jobs)

        env.operator.pods["default/svc"].kill_pod()
        # operator notices, restarts the pod, and readiness converges again
        assert _wait(lambda: h.service().status.restarts >= 1, timeout=20)
        assert _wait(lambda: h.ready_replicas() == 2, timeout=20)
        assert _job_ids(h) == ids, "pod restart resubmitted live replicas"
        assert len(env.clusters["slurm"].jobs) == submitted_before
        # endpoints stayed routable THROUGH the restart window
        r = h.router(request_timeout=10)
        assert r.request({"alive": 1})["echo"] == {"alive": 1}
        h.cancel()
        h.wait(timeout=20)
