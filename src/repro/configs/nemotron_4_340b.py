"""nemotron-4-340b [dense]: GQA, squared-ReLU MLP.  [arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256_000,
    head_dim=192,
    activation="relu2",
    norm="layernorm",
    rope_theta=10_000.0,
    source="arXiv:2402.16819",
)

SMOKE = ModelConfig(
    name="nemotron-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=256,
    head_dim=16,
    activation="relu2",
    norm="layernorm",
    dtype="float32",
)
