"""hymba-1.5b [hybrid]: parallel attention + mamba heads per block.

[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.  Sub-quadratic: eligible for long_500k (attention
heads switch to a sliding window in long mode; SSM state is O(1)/token).
Hymba meta-tokens are not modeled (noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    activation="swiglu",
    norm="rmsnorm",
    hybrid_parallel=True,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    long_window=1024,
    source="arXiv:2411.13676",
)

SMOKE = ModelConfig(
    name="hymba-smoke",
    family="hybrid",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    activation="swiglu",
    hybrid_parallel=True,
    ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
    long_window=16,
    dtype="float32",
)
