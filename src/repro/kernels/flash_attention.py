"""Blockwise online-softmax (flash) attention Pallas TPU kernel.

TPU adaptation notes (vs the CUDA original):
  * tiling is BlockSpec-driven: q tiles (block_q x D) stream through VMEM
    while k/v tiles (block_k x D) iterate on the innermost grid dim, which
    Mosaic executes sequentially per core — the running max / sum / output
    accumulator therefore lives in VMEM scratch and persists across k steps;
  * the MXU wants (128,128)-aligned matmuls: default blocks are 128 and the
    wrapper pads sequence lengths up to a block multiple (causal masking
    makes key padding self-masking);
  * running max/denominator scratch is lane-replicated (block_q, 128) to
    match the TPU vector layout instead of a CUDA-style (block_q,) register.
  * GQA is expressed in the k/v index_map (head h reads kv head h//group) —
    no repeated k/v materialization in HBM.

Grid: (B, Hq, nq, nk), nk innermost/sequential ("arbitrary" semantics).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import mosaic_params, resolve_interpret

NEG_INF = -1e30
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  n_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_scr[:, :1]                         # (bq, 1)
    l_prev = l_scr[:, :1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                        # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
    acc_scr[...] = acc

    @pl.when(ki == n_k - 1)
    def _finish():
        denom = jnp.where(l_scr[:, :1] == 0.0, 1.0, l_scr[:, :1])
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, block_q: int = 128,
                         block_k: int = 128,
                         interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,Hq,Sq,D); k,v: (B,Hkv,Sk,D) -> (B,Hq,Sq,D).

    Sq/Sk must be multiples of the block sizes (wrapper in ops.py pads).
    ``interpret=None`` auto-selects: Mosaic on TPU, interpret elsewhere."""
    interpret = resolve_interpret(interpret)
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"Hq {hq} % Hkv {hkv}")
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(f"seq ({sq},{sk}) not multiples of blocks "
                         f"({block_q},{block_k})")
    n_q, n_k = sq // block_q, sk // block_k
    grid = (b, hq, n_q, n_k)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (d ** 0.5), block_q=block_q,
        block_k=block_k, causal=causal, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, _g=group: (b_, h // _g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki, _g=group: (b_, h // _g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running max
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32),       # output accumulator
        ],
        interpret=interpret,
        **mosaic_params(dimension_semantics=("parallel", "parallel",
                                             "parallel", "arbitrary")),
    )(q, k, v)
