"""End-to-end behaviour of the full system: one environment, every paper
mechanism exercised in a single scenario."""
import json
import time

import pytest

from repro.core import BridgeEnvironment, DONE, FAILED, KILLED


def test_full_scenario():
    """A hybrid scientific workflow: stage data, fan a payload out to three
    resource managers, run a REAL bridged training job, survive a pod kill,
    kill one job, collect outputs — one operator, zero special-casing."""
    with BridgeEnvironment(default_duration=0.1) as env:
        env.s3.put("inputs", "config.json", b'{"x": 1}')

        # fan-out to heterogeneous managers
        for kind in ("slurm", "lsf", "ray"):
            env.submit(f"fan-{kind}", env.make_spec(
                kind, script=f"run {kind}", updateinterval=0.02,
                jobproperties={"OutputFileName": "out.txt"}))

        # a real training payload on the jax backend
        env.submit("fan-train", env.make_spec(
            "jaxlocal", updateinterval=0.05,
            script=json.dumps({"arch": "gemma-2b", "steps": 15, "batch": 2,
                               "seq": 16, "checkpoint_every": 5,
                               "workdir": "ckpts:runs/system"}),
            jobproperties={"OutputFileName": "train.out"}))

        # a job we kill mid-flight
        env.submit("fan-victim", env.make_spec(
            "quantum", script="OPENQASM 3;", updateinterval=0.02,
            jobproperties={"WallSeconds": "10"}))

        # kill the victim once it has a remote id
        deadline = time.time() + 20
        while time.time() < deadline:
            j = env.registry.get("fan-victim")
            if j.status.job_id:
                break
            time.sleep(0.01)
        env.operator.kill("fan-victim")

        # kill the training controller pod mid-run (training must survive)
        deadline = time.time() + 60
        while time.time() < deadline:
            j = env.registry.get("fan-train")
            pod = env.operator.pods.get("default/fan-train")
            if j.status.job_id and pod and pod.alive():
                pod.kill_pod()
                break
            time.sleep(0.01)

        for kind in ("slurm", "lsf", "ray"):
            assert env.operator.wait_for(f"fan-{kind}",
                                         timeout=60).status.state == DONE
        train = env.operator.wait_for("fan-train", timeout=300)
        assert train.status.state == DONE
        assert train.status.restarts >= 1  # pod died, job survived
        victim = env.operator.wait_for("fan-victim", timeout=60)
        assert victim.status.state == KILLED

        # training artifacts exist in the shared object store
        assert any("MANIFEST" in k for k in env.s3.list("ckpts", "runs/system/"))
        assert any("history" in k for k in env.s3.list("ckpts", "runs/system/"))

        # cleanup deletes every trace
        for name in ("fan-slurm", "fan-lsf", "fan-ray", "fan-train",
                     "fan-victim"):
            env.registry.delete(name)
        deadline = time.time() + 20
        while time.time() < deadline and list(env.statestore.list()):
            time.sleep(0.02)
        assert list(env.statestore.list()) == []
        assert env.registry.list() == []
