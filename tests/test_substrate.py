"""Substrate units: data determinism, checkpoint manager, serving engine,
gradient compression."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.objectstore import ObjectStore
from repro.data import DataConfig, SyntheticDataset


# -- data pipeline -----------------------------------------------------------


def test_data_determinism():
    ds1 = SyntheticDataset(DataConfig(vocab=101, seq_len=16, global_batch=8))
    ds2 = SyntheticDataset(DataConfig(vocab=101, seq_len=16, global_batch=8))
    b1 = ds1.batch(step=7, shard=2, n_shards=4)
    b2 = ds2.batch(step=7, shard=2, n_shards=4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps/shards differ
    assert not np.array_equal(b1["tokens"], ds1.batch(8, 2, 4)["tokens"])
    assert not np.array_equal(b1["tokens"], ds1.batch(7, 3, 4)["tokens"])


def test_data_affine_task_consistent():
    ds = SyntheticDataset(DataConfig(vocab=97, seq_len=12, global_batch=4))
    b = ds.batch(0)
    # targets are the affine map of tokens: t[i+1] = (a t[i] + c) % V
    a, c = ds._a, ds._c
    np.testing.assert_array_equal(
        b["targets"], (a * b["tokens"].astype(np.int64) + c) % 97)


def test_data_shard_shapes():
    ds = SyntheticDataset(DataConfig(vocab=31, seq_len=8, global_batch=16))
    b = ds.batch(0, shard=1, n_shards=4)
    assert b["tokens"].shape == (4, 8)
    with pytest.raises(ValueError):
        ds.batch(0, 0, 3)  # 16 % 3 != 0


# -- checkpoint manager ----------------------------------------------------


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"x": jnp.ones((2,), jnp.bfloat16),
                  "step": jnp.zeros((), jnp.int32)}}


def test_checkpoint_roundtrip_bf16():
    store = ObjectStore()
    mgr = CheckpointManager(store, "ck", "run1")
    tree = _tree()
    mgr.save(5, tree, extra={"loss": 1.5})
    assert mgr.latest_step() == 5
    restored, extra = mgr.restore(5, jax.eval_shape(lambda: tree))
    assert extra == {"loss": 1.5}
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["b"]["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["b"]["x"], np.float32),
                                  np.ones((2,), np.float32))


def test_checkpoint_gc_keep_last_k():
    store = ObjectStore()
    mgr = CheckpointManager(store, "ck", "run2", keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.latest_step() == 4
    manifests = [k for k in store.list("ck", "run2/") if "MANIFEST" in k]
    assert len(manifests) == 2  # steps 3 and 4 only
    with pytest.raises(Exception):
        mgr.restore(1, jax.eval_shape(_tree))


def test_checkpoint_async_save():
    store = ObjectStore()
    mgr = CheckpointManager(store, "ck", "run3")
    mgr.save_async(7, _tree())
    mgr.wait()
    assert mgr.latest_step() == 7


def test_checkpoint_partial_write_invisible():
    """A checkpoint missing its manifest must be ignored (commit marker)."""
    store = ObjectStore()
    mgr = CheckpointManager(store, "ck", "run4")
    mgr.save(1, _tree())
    # simulate an interrupted later save: leaves but no manifest
    store.put("ck", "run4/step_00000002/leaf_00000.npy", b"garbage")
    assert mgr.latest_step() == 1


def test_checkpoint_shape_mismatch_rejected():
    store = ObjectStore()
    mgr = CheckpointManager(store, "ck", "run5")
    mgr.save(1, _tree())
    bad = {"w": jnp.zeros((4, 4)), "b": {"x": jnp.ones((2,), jnp.bfloat16),
                                         "step": jnp.zeros((), jnp.int32)}}
    with pytest.raises(ValueError, match="shape"):
        mgr.restore(1, jax.eval_shape(lambda: bad))


# -- serving engine -----------------------------------------------------------


@pytest.mark.parametrize("arch", ["gemma-2b", "granite-moe-3b-a800m",
                                  "xlstm-125m", "hymba-1.5b"])
def test_serving_engine_families(arch):
    from repro.configs.base import get_smoke_config
    from repro.serving import ServingEngine
    from repro.steps import init_model

    cfg = get_smoke_config(arch)
    _, params = init_model(cfg, max_seq=64)
    eng = ServingEngine(cfg, params, max_batch=2, max_len=48, prefill_len=8)
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.vocab, size=8)) for _ in range(5)]
    ids = [eng.submit(p, max_new_tokens=4) for p in prompts]
    results = eng.run_until_idle()
    assert set(results) == set(ids)
    for toks in results.values():
        assert len(toks) == 4
        assert all(0 <= t < cfg.vocab for t in toks)
    # continuous batching actually reused slots: 5 requests, 2 slots
    assert eng.stats["prefills"] == 5


def test_serving_matches_unbatched_decode():
    """Engine output == straight prefill+decode for the same prompt."""
    from repro.configs.base import get_smoke_config
    from repro.models import decoding as DEC
    from repro.serving import ServingEngine
    from repro.steps import init_model

    cfg = get_smoke_config("granite-3-8b")
    _, params = init_model(cfg, max_seq=64)
    prompt = list(np.random.RandomState(1).randint(1, cfg.vocab, size=6))

    eng = ServingEngine(cfg, params, max_batch=3, max_len=32, prefill_len=8)
    rid = eng.submit(prompt, max_new_tokens=5)
    got = eng.run_until_idle()[rid]

    toks = jnp.asarray([prompt], jnp.int32)
    logits, cache = DEC.prefill(params, cfg, {"tokens": toks}, max_len=32)
    want = []
    cur = jnp.argmax(logits[:, -1], -1)[:, None]
    for _ in range(5):
        want.append(int(cur[0, 0]))
        logits, cache = DEC.decode_step(params, cfg, cache, cur)
        cur = jnp.argmax(logits[:, -1], -1)[:, None]
    assert got == want


# -- gradient compression ------------------------------------------------------


def test_int8_quantize_roundtrip():
    from repro.optim.compression import dequantize_int8, quantize_int8

    x = jnp.asarray(np.random.RandomState(0).randn(256).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_reduces_bias():
    """With feedback, the MEAN of dequantized grads over steps converges to
    the true mean (quantization noise is not a persistent bias)."""
    from repro.optim.compression import compress_with_feedback, dequantize_int8

    rng = np.random.RandomState(0)
    true = rng.randn(64).astype(np.float32) * 1e-3  # tiny grads: harsh case
    err = jnp.zeros(64, jnp.float32)
    acc = np.zeros(64, np.float64)
    n = 200
    for _ in range(n):
        g = jnp.asarray(true)
        q, s, err = compress_with_feedback(g, err)
        acc += np.asarray(dequantize_int8(q, s), np.float64)
    drift = np.abs(acc / n - true).max()
    assert drift < 1e-4, drift


# -- chunked selective scan matches the associative baseline ---------------


def test_chunked_ssm_matches_assoc():
    import dataclasses

    from repro.configs.base import get_smoke_config
    from repro.models import ssm as SSM
    from repro.models.params import init_params

    cfg = get_smoke_config("hymba-1.5b")
    p = init_params(jax.random.PRNGKey(0), SSM.ssm_defs(cfg))
    x = jnp.asarray(np.random.RandomState(0).randn(2, 50, cfg.d_model),
                    jnp.float32)
    y0, st0 = SSM.ssm_forward(p, x, cfg)
    cfg_c = dataclasses.replace(
        cfg, ssm=dataclasses.replace(cfg.ssm, scan_impl="chunked", chunk=16))
    y1, st1 = SSM.ssm_forward(p, x, cfg_c)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st0["ssm"]), np.asarray(st1["ssm"]),
                               rtol=2e-4, atol=2e-5)


def test_blockwise_attention_matches_xla_path():
    import dataclasses

    from repro.configs.base import get_smoke_config
    from repro.models import layers as L
    from repro.models.params import init_params

    cfg = get_smoke_config("granite-3-8b", d_model=64, n_heads=4,
                           n_kv_heads=2, head_dim=16)
    p = init_params(jax.random.PRNGKey(0), L.attention_defs(cfg))
    x = jnp.asarray(np.random.RandomState(1).randn(2, 50, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(50, dtype=jnp.int32), (2, 50))
    out0, _ = L.attn_forward(p, x, pos, cfg)
    cfg_b = dataclasses.replace(cfg, attention_impl="blockwise",
                                attention_block_q=16)
    out1, _ = L.attn_forward(p, x, pos, cfg_b)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                               rtol=2e-4, atol=2e-4)
    # windowed variant agrees too
    out0w, _ = L.attn_forward(p, x, pos, cfg, window=8)
    out1w, _ = L.attn_forward(p, x, pos, cfg_b, window=8)
    np.testing.assert_allclose(np.asarray(out0w), np.asarray(out1w),
                               rtol=2e-4, atol=2e-4)
