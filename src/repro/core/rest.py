"""HTTP/HTTPS transport simulation.

The paper's only assumption on an external system is that it "exposes a
HTTP/HTTPS API for its control/management".  We preserve that boundary: the
controller pods talk to backends EXCLUSIVELY through ``RestClient.request``
(method, path, json) and never call backend internals.  The transport injects
the unreliable-network character (latency, fault windows, auth failures) that
the bridge's retry/UNKNOWN logic exists to survive.
"""
from __future__ import annotations

import random
import re
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple
from urllib.parse import parse_qsl


class TransportError(ConnectionError):
    """Network-level failure (timeout / connection refused)."""


@dataclass
class HttpResponse:
    status: int
    json: Any = None

    @property
    def ok(self) -> bool:
        return 200 <= self.status < 300


@dataclass
class FaultProfile:
    """Deterministic (seeded) fault injection for the simulated network."""
    drop_rate: float = 0.0        # probability a request raises TransportError
    latency: float = 0.0          # fixed per-request latency (seconds)
    seed: int = 0
    # hard outage window: every request fails while ``outage`` is set
    _outage: threading.Event = field(default_factory=threading.Event, repr=False)
    _rng: random.Random = field(default=None, repr=False)
    # one shared seeded Random serves every concurrent caller; the lock keeps
    # each check() consuming exactly one draw so drop injection stays
    # deterministic however many pods/workers hit the server at once
    _rng_lock: threading.Lock = field(default_factory=threading.Lock,
                                      repr=False)

    def __post_init__(self):
        self._rng = random.Random(self.seed)

    def begin_outage(self) -> None:
        self._outage.set()

    def end_outage(self) -> None:
        self._outage.clear()

    def check(self) -> None:
        if self.latency:
            time.sleep(self.latency)
        if self._outage.is_set():
            raise TransportError("simulated network outage")
        if self.drop_rate:
            with self._rng_lock:
                drop = self._rng.random() < self.drop_rate
            if drop:
                raise TransportError("simulated packet loss")


Handler = Callable[[Dict[str, str], Any], HttpResponse]


class RestServer:
    """Route table + bearer-token auth for one simulated resource manager."""

    def __init__(self, token: str = "", fault: Optional[FaultProfile] = None):
        self._routes: List[Tuple[str, re.Pattern, Handler]] = []
        self._token = token
        self.fault = fault or FaultProfile()
        self.request_count = 0
        self._lock = threading.Lock()

    def route(self, method: str, pattern: str, handler: Handler) -> None:
        """pattern: '/jobs/{id}' -> named groups."""
        rx = re.compile("^" + re.sub(r"\{(\w+)\}", r"(?P<\1>[^/]+)", pattern) + "$")
        self._routes.append((method.upper(), rx, handler))

    def handle(self, method: str, path: str, json_body: Any = None,
               headers: Optional[Dict[str, str]] = None) -> HttpResponse:
        self.fault.check()
        with self._lock:
            self.request_count += 1
        headers = headers or {}
        if self._token:
            auth = headers.get("Authorization", "")
            if auth != f"Bearer {self._token}":
                return HttpResponse(401, {"error": "unauthorized"})
        # query string: merged into the handler's groups dict (path groups
        # win on collision), so 'GET /jobs?ids=a,b' routes like 'GET /jobs'
        path, _, query = path.partition("?")
        params = dict(parse_qsl(query)) if query else {}
        for m, rx, handler in self._routes:
            if m != method.upper():
                continue
            match = rx.match(path)
            if match:
                try:
                    return handler({**params, **match.groupdict()}, json_body)
                except Exception as e:  # backend bug -> 500, not a crash
                    return HttpResponse(500, {"error": f"{type(e).__name__}: {e}"})
        return HttpResponse(404, {"error": f"no route {method} {path}"})


class RestClient:
    """What a controller pod holds: endpoint + credentials, nothing else."""

    def __init__(self, server: RestServer, token: str = "", timeout: float = 5.0):
        self._server = server
        self._token = token
        self.timeout = timeout

    def request(self, method: str, path: str, json: Any = None) -> HttpResponse:
        headers = {"Authorization": f"Bearer {self._token}"} if self._token else {}
        return self._server.handle(method, path, json, headers)

    def get(self, path: str) -> HttpResponse:
        return self.request("GET", path)

    def post(self, path: str, json: Any = None) -> HttpResponse:
        return self.request("POST", path, json)

    def delete(self, path: str) -> HttpResponse:
        return self.request("DELETE", path)

    def put(self, path: str, json: Any = None) -> HttpResponse:
        return self.request("PUT", path, json)


class ResourceManagerDirectory:
    """Maps resourceURL -> RestServer (DNS + ingress analogue)."""

    def __init__(self) -> None:
        self._servers: Dict[str, RestServer] = {}

    def register(self, url: str, server: RestServer) -> None:
        self._servers[url] = server

    def connect(self, url: str, token: str = "") -> RestClient:
        if url not in self._servers:
            raise TransportError(f"cannot resolve {url!r}")
        return RestClient(self._servers[url], token)

    def urls(self) -> List[str]:
        return sorted(self._servers)
