"""Step builders: train_step / prefill_step / decode_step as pjit-able
functions with in/out shardings, plus ``input_specs`` (ShapeDtypeStruct
stand-ins for every model input — weak-type-correct, shardable, no device
allocation) for every (arch x shape) cell.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as SH
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.models import decoding as DEC
from repro.models import transformer as TF
from repro.models.params import abstract_params, init_params
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class StepBundle:
    """Everything needed to lower/compile/run one cell."""
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    input_specs: Dict[str, Any]  # kwargs of abstract inputs (incl. params/state)
    donate_argnames: Tuple[str, ...] = ()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Model input specs per (cfg, shape)
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for a cell (excluding params/optimizer/cache)."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {}
        s_text = s - (cfg.n_img_tokens if cfg.family == "vlm" else 0)
        specs["tokens"] = _sds((b, s_text), jnp.int32)
        if cfg.family == "vlm":
            specs["img_embeds"] = _sds((b, cfg.n_img_tokens, cfg.d_model), dt)
        if cfg.family == "encdec":
            specs["enc_frames"] = _sds((b, cfg.enc_frames, cfg.d_model), dt)
        if shape.kind == "train":
            specs["targets"] = _sds((b, s_text), jnp.int32)
            specs["mask"] = _sds((b, s_text), jnp.float32)
        return specs
    # decode: one new token against a cache of length seq_len
    return {"tokens": _sds((b, 1), jnp.int32)}


def make_synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0
                         ) -> Dict[str, jax.Array]:
    """Concrete random batch matching ``batch_specs`` (for smoke tests/examples)."""
    specs = batch_specs(cfg, shape)
    rng = jax.random.PRNGKey(seed)
    out = {}
    for k, v in specs.items():
        rng, sub = jax.random.split(rng)
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(sub, v.shape, 0, cfg.vocab, jnp.int32)
        elif k == "mask":
            out[k] = jnp.ones(v.shape, v.dtype)
        else:
            out[k] = jax.random.normal(sub, v.shape, jnp.float32).astype(v.dtype)
    return out


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                    opt_cfg: Optional[AdamWConfig] = None, strategy: str = "tp",
                    zero1: bool = True, remat: bool = True) -> StepBundle:
    opt_cfg = opt_cfg or AdamWConfig()
    defs = TF.model_defs(cfg, max_seq=shape.seq_len)
    rules = SH.make_rules(mesh, strategy)
    p_specs = SH.param_pspecs(defs, rules, mesh)
    from repro.optim.adamw import opt_pspecs as make_opt_pspecs

    o_specs = make_opt_pspecs(defs, rules, mesh, zero1=zero1)
    b_specs_abs = batch_specs(cfg, shape)
    b_pspecs = SH.batch_pspecs(b_specs_abs, mesh)

    def train_step(params, opt_state, batch):
        from repro.parallel.ep import ep_mesh

        with ep_mesh(mesh):  # trace-time mesh for EP / seq-sharded attention
            def loss_fn(p):
                return TF.forward_train(p, cfg, batch, remat=remat)

            (loss, metrics), grads = jax.value_and_grad(loss_fn,
                                                        has_aux=True)(params)
        new_params, new_opt, opt_metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, **opt_metrics)
        return new_params, new_opt, metrics

    abs_params = abstract_params(defs)
    abs_opt = jax.eval_shape(adamw_init, abs_params)
    in_shardings = (p_specs, o_specs, b_pspecs)
    out_shardings = (p_specs, o_specs, None)
    return StepBundle(
        fn=train_step,
        in_shardings=in_shardings,
        out_shardings=out_shardings,
        input_specs={"params": abs_params, "opt_state": abs_opt, "batch": b_specs_abs},
        donate_argnames=("params", "opt_state"),
    )


# ---------------------------------------------------------------------------
# Prefill / decode steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                      strategy: str = "tp") -> StepBundle:
    defs = TF.model_defs(cfg, max_seq=shape.seq_len)
    rules = SH.make_rules(mesh, strategy)
    p_specs = SH.param_pspecs(defs, rules, mesh)
    b_specs_abs = batch_specs(cfg, shape)
    b_pspecs = SH.batch_pspecs(b_specs_abs, mesh)
    cache_abs = DEC.cache_specs(cfg, shape.global_batch, shape.seq_len)
    c_pspecs = SH.cache_pspecs(cfg, cache_abs, mesh)

    def prefill_step(params, batch):
        from repro.parallel.ep import ep_mesh

        with ep_mesh(mesh):
            return DEC.prefill(params, cfg, batch, max_len=shape.seq_len)

    return StepBundle(
        fn=prefill_step,
        in_shardings=(p_specs, b_pspecs),
        out_shardings=(None, c_pspecs),
        input_specs={"params": abstract_params(defs), "batch": b_specs_abs},
    )


def make_decode_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                     strategy: str = "tp") -> StepBundle:
    window = cfg.long_window if (shape.name == "long_500k" and cfg.long_window) else 0
    defs = TF.model_defs(cfg, max_seq=shape.seq_len)
    rules = SH.make_rules(mesh, strategy)
    p_specs = SH.param_pspecs(defs, rules, mesh)
    b_specs_abs = batch_specs(cfg, shape)
    b_pspecs = SH.batch_pspecs(b_specs_abs, mesh)
    cache_abs = DEC.cache_specs(cfg, shape.global_batch, shape.seq_len, window)
    c_pspecs = SH.cache_pspecs(cfg, cache_abs, mesh)

    def decode_step(params, cache, batch):
        from repro.parallel.ep import ep_mesh

        with ep_mesh(mesh):
            logits, new_cache = DEC.decode_step(params, cfg, cache,
                                                batch["tokens"], window=window)
        return logits, new_cache

    return StepBundle(
        fn=decode_step,
        in_shardings=(p_specs, c_pspecs, b_pspecs),
        out_shardings=(None, c_pspecs),
        input_specs={"params": abstract_params(defs), "cache": cache_abs,
                     "batch": b_specs_abs},
        donate_argnames=("cache",),
    )


def make_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, **kw) -> StepBundle:
    if shape.kind == "train":
        return make_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape,
                                 **{k: v for k, v in kw.items() if k == "strategy"})
    return make_decode_step(cfg, mesh, shape,
                            **{k: v for k, v in kw.items() if k == "strategy"})


# ---------------------------------------------------------------------------
# Concrete initialization (for smoke tests / real training)
# ---------------------------------------------------------------------------


def init_model(cfg: ModelConfig, seed: int = 0, max_seq: int = 128):
    defs = TF.model_defs(cfg, max_seq=max_seq)
    params = init_params(jax.random.PRNGKey(seed), defs)
    return defs, params
