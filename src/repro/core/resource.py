"""BridgeJob — the paper's Custom Resource (CRD analogue), in two API versions.

``v1alpha1`` mirrors the ``BridgeJob`` yaml of paper Fig. 1:

    kind: BridgeJob
    apiVersion: bridgeoperator.repro/v1alpha1
    metadata: {name: slurmjob-test}
    spec:
      resourceURL: http://my-slurm-cluster@hpc.com
      image: slurmpod:0.1
      resourcesecret: mysecret
      imagepullpolicy: Always
      updateinterval: 20
      jobdata: {jobscript: ..., scriptlocation: s3|remote|inline, ...}
      jobproperties: {...}
      s3storage: {s3secret: ..., endpoint: ..., secure: ...}

``v1beta1`` is a strict superset adding:

    spec:
      array: {count: 4, indexed_params: [{...}, ...]}   # one CR -> N remote jobs
      retry: {limit: 2, backoff_seconds: 0.0}           # per-index resubmission
      ttlSecondsAfterFinished: 30                       # auto-GC the CR
      dependencies: [other-job, ...]                    # gate on sibling CRs
      placement:                                        # sharded placement
        candidates: [{resourceURL, image, resourcesecret, weight}, ...]
        strategy: single|spread|weighted                # how to split indices
        maxSlices: 2                                    # cap on resources used
        failover:                                       # slice failover policy
          enabled: true
          unreachable_threshold: 5                      # polls before LOST
          grace_seconds: 0                              # min outage wall time

``spec.array`` is MUTABLE on a live CR (elastic arrays): every spec mutation
bumps ``metadata.generation`` and the reconciler records the generation it
has fully applied in ``status.observedGeneration`` — the standard Kubernetes
convergence handshake.  A client knows a resize has landed when
``observedGeneration == generation``.

``convert()`` is the conversion-webhook analogue: it moves a full CR dict
between versions.  Every v1alpha1 document upgrades losslessly; downgrading a
v1beta1 document that uses beta-only features raises ``ConversionError``.

The spec is declarative; the operator reconciles it.  Status carries the
paper's terminal states DONE/KILLED/FAILED/UNKNOWN plus start/end times and,
for job arrays, the per-index state map.
"""
from __future__ import annotations

import copy
import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

API_V1ALPHA1 = "bridgeoperator.repro/v1alpha1"
API_V1BETA1 = "bridgeoperator.repro/v1beta1"
API_VERSIONS = (API_V1ALPHA1, API_V1BETA1)
API_VERSION = API_V1ALPHA1  # seed-era alias; v1alpha1 remains fully served
KIND = "BridgeJob"

# spec keys that exist only in v1beta1 (the conversion layer gates on these)
BETA_ONLY_SPEC_KEYS = ("array", "retry", "ttlSecondsAfterFinished",
                       "dependencies", "placement")

PLACEMENT_STRATEGIES = ("single", "spread", "weighted")

# Lifecycle states (paper §5.1 + DESIGN.md §8).
PENDING = "PENDING"
SUBMITTED = "SUBMITTED"
RUNNING = "RUNNING"
DONE = "DONE"
FAILED = "FAILED"
KILLED = "KILLED"
UNKNOWN = "UNKNOWN"

TERMINAL_STATES = (DONE, FAILED, KILLED)
ALL_STATES = (PENDING, SUBMITTED, RUNNING, DONE, FAILED, KILLED, UNKNOWN)

# Slice-level state (NOT a CR state, so not in ALL_STATES): a placement
# slice whose resource failed its failover policy and whose unfinished
# indices were migrated elsewhere.  Surfaces in status.placements only.
LOST = "LOST"

SCRIPT_LOCATIONS = ("inline", "s3", "remote")


class ValidationError(ValueError):
    pass


class ConversionError(ValidationError):
    """A document cannot be represented in the requested API version."""


@dataclass(frozen=True)
class JobData:
    """spec.jobdata — what to run and where the script lives."""
    jobscript: str = ""          # inline text | "bucket:key" | remote path
    scriptlocation: str = "inline"
    scriptmd: str = ""           # optional integrity digest
    additionaldata: str = ""     # comma-sep "bucket:key" files staged to the resource
    jobparams: Dict[str, str] = field(default_factory=dict)


@dataclass(frozen=True)
class S3Storage:
    """spec.s3storage — object-store endpoint used for staging/uploads."""
    s3secret: str = ""
    endpoint: str = ""
    secure: bool = False
    uploadfiles: str = ""        # comma-sep output files to upload on completion
    uploadbucket: str = ""


@dataclass(frozen=True)
class ArraySpec:
    """spec.array (v1beta1) — one CR fans out ``count`` remote jobs.

    ``indexed_params[i]`` overlays ``jobdata.jobparams`` for index ``i``; the
    controller additionally injects ``BRIDGE_ARRAY_INDEX`` per index.
    """
    count: int = 1
    indexed_params: List[Dict[str, str]] = field(default_factory=list)

    def validate(self) -> None:
        if self.count < 1:
            raise ValidationError("spec.array.count must be >= 1")
        if self.indexed_params and len(self.indexed_params) != self.count:
            raise ValidationError(
                f"spec.array.indexed_params has {len(self.indexed_params)} "
                f"entries for count={self.count}")


@dataclass(frozen=True)
class PlacementCandidate:
    """One schedulable target a sliced array may land on: where + how to
    talk to it.  ``weight`` only matters under ``strategy: weighted``."""
    resourceURL: str = ""
    image: str = ""
    resourcesecret: str = ""
    weight: float = 1.0

    def validate(self) -> None:
        if not (self.resourceURL and self.image and self.resourcesecret):
            raise ValidationError(
                "placement candidates need resourceURL, image and "
                "resourcesecret")
        if self.weight <= 0:
            raise ValidationError("placement candidate weight must be > 0")


@dataclass(frozen=True)
class FailoverSpec:
    """spec.placement.failover (v1beta1) — slice failover policy.

    Default OFF: without it an unreachable slice pins the CR UNKNOWN until
    the resource answers again (the pre-failover behaviour, byte-compatible).
    With ``enabled``, a slice that misses ``unreachable_threshold``
    consecutive polls AND has been dark for at least ``grace_seconds`` is
    promoted to LOST: its unfinished indices are cancelled best-effort and
    resubmitted on the remaining healthy candidates; its completed indices'
    results are kept.
    """
    enabled: bool = False
    unreachable_threshold: int = 5   # consecutive failed polls before LOST
    grace_seconds: float = 0.0       # minimum outage wall time before LOST

    def validate(self) -> None:
        if self.unreachable_threshold < 1:
            raise ValidationError(
                "spec.placement.failover.unreachable_threshold must be >= 1")
        if self.grace_seconds < 0:
            raise ValidationError(
                "spec.placement.failover.grace_seconds must be >= 0")


@dataclass(frozen=True)
class PlacementSpec:
    """spec.placement (v1beta1) — sharded placement of one array CR.

    The scheduler partitions the array's index space into per-resource
    SLICES, each slice owning a contiguous initial index range plus its own
    adapter/endpoint/secret and per-slice state-store keys:

      * ``single``   — the whole array lands on the least-loaded candidate
        (one slice; byte-for-byte identical to today's single-resource CR);
      * ``spread``   — indices split load-proportionally (by free slots)
        across the reachable candidates;
      * ``weighted`` — indices split by the candidates' static weights.

    ``maxSlices`` caps how many resources are used (0 = no cap).
    """
    candidates: List[PlacementCandidate] = field(default_factory=list)
    strategy: str = "single"
    max_slices: int = 0
    failover: Optional[FailoverSpec] = None

    def validate(self) -> None:
        if not self.candidates:
            raise ValidationError(
                "spec.placement requires at least one candidate")
        if self.strategy not in PLACEMENT_STRATEGIES:
            raise ValidationError(
                f"spec.placement.strategy {self.strategy!r} not in "
                f"{PLACEMENT_STRATEGIES}")
        if self.max_slices < 0:
            raise ValidationError("spec.placement.maxSlices must be >= 0")
        if self.failover is not None:
            self.failover.validate()
        for c in self.candidates:
            c.validate()


@dataclass(frozen=True)
class RetryPolicy:
    """spec.retry (v1beta1) — per-index resubmission on FAILED."""
    limit: int = 0               # extra submissions allowed after a failure
    backoff_seconds: float = 0.0

    def validate(self) -> None:
        if self.limit < 0:
            raise ValidationError("spec.retry.limit must be >= 0")
        if self.backoff_seconds < 0:
            raise ValidationError("spec.retry.backoff_seconds must be >= 0")


@dataclass(frozen=True)
class BridgeJobSpec:
    resourceURL: str
    image: str                     # controller-pod image == backend kind ("slurmpod:0.1")
    resourcesecret: str
    imagepullpolicy: str = "IfNotPresent"
    updateinterval: float = 20.0   # poll seconds (paper: CR poll parameter)
    jobdata: JobData = field(default_factory=JobData)
    jobproperties: Dict[str, str] = field(default_factory=dict)
    s3storage: Optional[S3Storage] = None
    # kill signal: "a user can also update the CR with a kill signal" (§5.1)
    kill: bool = False
    # UNKNOWN after this many consecutive unreachable polls (DESIGN.md §8)
    unknown_after: int = 5
    # -- v1beta1 additions (all default to "absent" == v1alpha1 semantics) --
    array: Optional[ArraySpec] = None
    retry: Optional[RetryPolicy] = None
    ttl_seconds_after_finished: Optional[float] = None
    dependencies: List[str] = field(default_factory=list)
    placement: Optional[PlacementSpec] = None

    def uses_beta_features(self) -> bool:
        """True iff this spec cannot be expressed in v1alpha1."""
        return bool((self.array and (self.array.count > 1
                                     or self.array.indexed_params))
                    or (self.retry and (self.retry.limit
                                        or self.retry.backoff_seconds))
                    or self.ttl_seconds_after_finished is not None
                    or self.dependencies
                    or (self.placement and self.placement.candidates))

    def validate(self) -> None:
        placed = bool(self.placement and self.placement.candidates)
        # with spec.placement the scheduler assigns endpoints per slice, so
        # the top-level target trio becomes optional
        if not self.resourceURL and not placed:
            raise ValidationError("spec.resourceURL is required")
        if not self.image and not placed:
            raise ValidationError("spec.image is required")
        if not self.resourcesecret and not placed:
            raise ValidationError("spec.resourcesecret is required")
        if self.updateinterval <= 0:
            raise ValidationError("spec.updateinterval must be > 0")
        if self.jobdata.scriptlocation not in SCRIPT_LOCATIONS:
            raise ValidationError(
                f"spec.jobdata.scriptlocation {self.jobdata.scriptlocation!r} "
                f"not in {SCRIPT_LOCATIONS}")
        if self.jobdata.scriptlocation == "s3":
            if self.s3storage is None:
                raise ValidationError("scriptlocation=s3 requires spec.s3storage")
            if ":" not in self.jobdata.jobscript:
                raise ValidationError("s3 jobscript must be 'bucket:key'")
        if self.s3storage and self.s3storage.uploadfiles and not self.s3storage.uploadbucket:
            raise ValidationError("s3storage.uploadfiles requires uploadbucket")
        if self.array is not None:
            self.array.validate()
        if self.retry is not None:
            self.retry.validate()
        if self.placement is not None:
            self.placement.validate()
        if (self.ttl_seconds_after_finished is not None
                and self.ttl_seconds_after_finished < 0):
            raise ValidationError("spec.ttlSecondsAfterFinished must be >= 0")
        for dep in self.dependencies:
            if not dep or not isinstance(dep, str):
                raise ValidationError(
                    f"spec.dependencies entries must be job names, got {dep!r}")


@dataclass
class BridgeJobStatus:
    state: str = PENDING
    message: str = ""
    job_id: str = ""               # remote job id(s) (mirrored from the config map)
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    restarts: int = 0              # controller-pod restarts performed by the operator
    # v1beta1 job arrays: per-index bridge state ("0" -> DONE, ...)
    index_states: Dict[str, str] = field(default_factory=dict)
    # last metadata.generation the reconciler fully applied (0 = none yet)
    observed_generation: int = 0
    # sharded placement: one entry per slice, mirrored from the config map —
    # {"slice": k, "resourceURL": ..., "image": ..., "indices": [...],
    #  "state": ...}.  Empty for single-resource (unsliced) jobs.
    placements: List[Dict[str, Any]] = field(default_factory=list)

    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES


@dataclass
class BridgeJob:
    """A full CR object: metadata + spec + status."""
    name: str
    spec: BridgeJobSpec
    namespace: str = "default"
    status: BridgeJobStatus = field(default_factory=BridgeJobStatus)
    # metadata.generation: bumped by the registry on every SPEC change
    # (status updates do not touch it) — paired with
    # status.observed_generation by the reconciler
    generation: int = 1
    # registry bookkeeping
    resource_version: int = 0
    deleted: bool = False

    # class-level kind tag — BridgeService carries SERVICE_KIND; the operator
    # dispatches on this without isinstance checks
    kind = KIND

    @property
    def uid(self) -> str:
        return f"{self.namespace}/{self.name}"

    # -- dict round-trip (yaml-equivalent; json keeps the container offline) --

    def to_dict(self, version: Optional[str] = None) -> Dict[str, Any]:
        """Serialize at ``version``.  Default: v1alpha1 when the spec uses no
        beta features (seed behaviour), else v1beta1."""
        if version is None:
            version = (API_V1BETA1 if self.spec.uses_beta_features()
                       else API_V1ALPHA1)
        d = {
            "apiVersion": version,
            "kind": KIND,
            "metadata": {"name": self.name, "namespace": self.namespace,
                         "generation": self.generation},
            "spec": _spec_to_dict(self.spec, version),
            "status": dataclasses.asdict(self.status),
        }
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "BridgeJob":
        if d.get("kind", KIND) != KIND:
            raise ValidationError(f"kind {d.get('kind')!r} != {KIND}")
        d = convert(d, API_V1BETA1)  # hub version: parse everything as beta
        meta = d.get("metadata", {})
        spec = spec_from_dict(d.get("spec", {}))
        job = BridgeJob(name=meta.get("name", ""), spec=spec,
                        namespace=meta.get("namespace", "default"),
                        generation=int(meta.get("generation", 1)))
        status = d.get("status") or {}
        if "observed_generation" in status:
            job.status.observed_generation = int(status["observed_generation"])
        if status.get("placements"):
            job.status.placements = [dict(p) for p in status["placements"]]
        if not job.name:
            raise ValidationError("metadata.name is required")
        spec.validate()
        return job


def _spec_to_dict(s: BridgeJobSpec, version: str = API_V1BETA1) -> Dict[str, Any]:
    if version not in API_VERSIONS:
        raise ConversionError(f"unknown apiVersion {version!r}")
    if version == API_V1ALPHA1 and s.uses_beta_features():
        raise ConversionError(
            "spec uses v1beta1 features (array/retry/ttl/dependencies) and "
            "cannot be serialized as v1alpha1")
    d: Dict[str, Any] = {
        "resourceURL": s.resourceURL,
        "image": s.image,
        "resourcesecret": s.resourcesecret,
        "imagepullpolicy": s.imagepullpolicy,
        "updateinterval": s.updateinterval,
        "jobdata": dataclasses.asdict(s.jobdata),
        "jobproperties": dict(s.jobproperties),
        "kill": s.kill,
        "unknown_after": s.unknown_after,
    }
    if s.s3storage is not None:
        d["s3storage"] = dataclasses.asdict(s.s3storage)
    if version == API_V1BETA1:
        # beta keys are emitted only when non-default, so a round-trip through
        # v1beta1 reproduces a v1alpha1 document bit-for-bit
        if s.array and (s.array.count > 1 or s.array.indexed_params):
            d["array"] = dataclasses.asdict(s.array)
        if s.retry and (s.retry.limit or s.retry.backoff_seconds):
            d["retry"] = dataclasses.asdict(s.retry)
        if s.ttl_seconds_after_finished is not None:
            d["ttlSecondsAfterFinished"] = s.ttl_seconds_after_finished
        if s.dependencies:
            d["dependencies"] = list(s.dependencies)
        if s.placement and s.placement.candidates:
            d["placement"] = placement_to_dict(s.placement)
    return d


def placement_to_dict(p: PlacementSpec) -> Dict[str, Any]:
    d: Dict[str, Any] = {
        "candidates": [dataclasses.asdict(c) for c in p.candidates],
        "strategy": p.strategy,
        "maxSlices": p.max_slices,
    }
    if p.failover is not None:
        d["failover"] = dataclasses.asdict(p.failover)
    return d


def placement_from_dict(plc: Optional[Dict[str, Any]]) -> Optional[PlacementSpec]:
    if plc is None:
        return None
    fo = plc.get("failover")
    return PlacementSpec(
        candidates=[PlacementCandidate(
            resourceURL=c.get("resourceURL", ""),
            image=c.get("image", ""),
            resourcesecret=c.get("resourcesecret", ""),
            weight=float(c.get("weight", 1.0)),
        ) for c in plc.get("candidates", [])],
        strategy=plc.get("strategy", "single"),
        max_slices=int(plc.get("maxSlices", 0)),
        failover=None if fo is None else FailoverSpec(
            enabled=bool(fo.get("enabled", False)),
            unreachable_threshold=int(fo.get("unreachable_threshold", 5)),
            grace_seconds=float(fo.get("grace_seconds", 0.0)),
        ),
    )


def spec_from_dict(d: Dict[str, Any]) -> BridgeJobSpec:
    jd = d.get("jobdata", {})
    s3 = d.get("s3storage")
    arr = d.get("array")
    retry = d.get("retry")
    ttl = d.get("ttlSecondsAfterFinished")
    plc = d.get("placement")
    spec = BridgeJobSpec(
        resourceURL=d.get("resourceURL", ""),
        image=d.get("image", ""),
        resourcesecret=d.get("resourcesecret", ""),
        imagepullpolicy=d.get("imagepullpolicy", "IfNotPresent"),
        updateinterval=float(d.get("updateinterval", 20.0)),
        jobdata=JobData(
            jobscript=jd.get("jobscript", ""),
            scriptlocation=jd.get("scriptlocation", "inline"),
            scriptmd=jd.get("scriptmd", ""),
            additionaldata=jd.get("additionaldata", ""),
            jobparams=dict(jd.get("jobparams", {})),
        ),
        jobproperties=dict(d.get("jobproperties", {})),
        s3storage=None if s3 is None else S3Storage(
            s3secret=s3.get("s3secret", ""),
            endpoint=s3.get("endpoint", ""),
            secure=bool(s3.get("secure", False)),
            uploadfiles=s3.get("uploadfiles", ""),
            uploadbucket=s3.get("uploadbucket", ""),
        ),
        kill=bool(d.get("kill", False)),
        unknown_after=int(d.get("unknown_after", 5)),
        array=None if arr is None else ArraySpec(
            count=int(arr.get("count", 1)),
            indexed_params=[dict(p) for p in arr.get("indexed_params", [])],
        ),
        retry=None if retry is None else RetryPolicy(
            limit=int(retry.get("limit", 0)),
            backoff_seconds=float(retry.get("backoff_seconds", 0.0)),
        ),
        ttl_seconds_after_finished=None if ttl is None else float(ttl),
        dependencies=list(d.get("dependencies", [])),
        placement=placement_from_dict(plc),
    )
    return spec


# ---------------------------------------------------------------------------
# Conversion layer (the conversion-webhook analogue)
# ---------------------------------------------------------------------------


def convert(doc: Dict[str, Any], to_version: str) -> Dict[str, Any]:
    """Convert a full CR document between API versions.

    v1alpha1 -> v1beta1 is always lossless (the beta schema is a superset and
    beta defaults are exactly the alpha semantics).  v1beta1 -> v1alpha1
    raises ``ConversionError`` when the document uses beta-only features.
    The input is never mutated.
    """
    frm = doc.get("apiVersion", API_V1ALPHA1)
    if frm not in API_VERSIONS:
        raise ConversionError(f"unknown apiVersion {frm!r}")
    if to_version not in API_VERSIONS:
        raise ConversionError(f"unknown target apiVersion {to_version!r}")
    out = copy.deepcopy(doc)
    spec = out.get("spec", {})
    if frm == API_V1ALPHA1:
        stray = [k for k in BETA_ONLY_SPEC_KEYS if k in spec]
        if stray:
            raise ValidationError(
                f"v1alpha1 spec carries v1beta1-only fields {stray}")
    if to_version == API_V1ALPHA1 and frm == API_V1BETA1:
        lossy = [k for k in BETA_ONLY_SPEC_KEYS
                 if not _beta_key_is_default(spec, k)]
        if lossy:
            raise ConversionError(
                f"cannot downgrade to v1alpha1: spec fields {lossy} have no "
                f"v1alpha1 representation")
        for k in BETA_ONLY_SPEC_KEYS:
            spec.pop(k, None)
    out["apiVersion"] = to_version
    return out


def _beta_key_is_default(spec: Dict[str, Any], key: str) -> bool:
    if key not in spec:
        return True
    v = spec[key]
    if key == "array":
        return not v or (int(v.get("count", 1)) <= 1
                         and not v.get("indexed_params"))
    if key == "retry":
        return not v or (not v.get("limit") and not v.get("backoff_seconds"))
    if key == "ttlSecondsAfterFinished":
        return v is None
    if key == "dependencies":
        return not v
    if key == "placement":
        # ANY candidate list makes the document sliced/schedulable — there is
        # no v1alpha1 representation even for strategy "single"
        return not v or not v.get("candidates")
    return False


def load_bridgejob(text: str) -> BridgeJob:
    """Parse a BridgeJob (either API version) from its JSON serialization."""
    return BridgeJob.from_dict(json.loads(text))


# ---------------------------------------------------------------------------
# BridgeService — long-running replicated serving workloads (v1beta1 only)
# ---------------------------------------------------------------------------
#
# Where a BridgeJob runs to DONE, a BridgeService keeps ``spec.replicas``
# remote jobs ALIVE: each replica is a long-lived serve-mode job on an
# external resource, health-checked through the adapter REST channel every
# reconcile tick, and condemned + resubmitted (under the same persisted
# condemned-set / at-most-once invariants as elastic arrays) when it dies or
# stops answering its health probe.  ``status.endpoints`` publishes one entry
# per live replica — the request router (core/router.py) load-balances over
# the ``ready`` subset.

SERVICE_KIND = "BridgeService"


@dataclass(frozen=True)
class HealthProbeSpec:
    """spec.health — when is a RUNNING replica considered dead?

    A replica is probed on every reconcile tick (cadence =
    ``spec.updateinterval``).  After ``failure_threshold`` CONSECUTIVE failed
    probes it is condemned and replaced.  Before its first successful probe a
    replica gets the larger ``startup_failure_threshold`` budget, so a model
    server that spends several ticks loading weights is not condemned while
    booting (the startupProbe/livenessProbe split, collapsed into one probe).
    """
    failure_threshold: int = 3
    startup_failure_threshold: int = 10

    def validate(self) -> None:
        if self.failure_threshold < 1:
            raise ValidationError("spec.health.failure_threshold must be >= 1")
        if self.startup_failure_threshold < 1:
            raise ValidationError(
                "spec.health.startup_failure_threshold must be >= 1")


@dataclass(frozen=True)
class AutoscaleSpec:
    """spec.autoscale — load-driven replica count (default OFF).

    When set, the ServiceProtocol recomputes the desired replica count each
    reconcile tick from the load reports the request routers publish into the
    config map (outstanding requests, request rate, p50/p99 latency) and
    drives the delta through the SAME elastic reconcile a manual
    ``scale()`` uses.  At least one target must be set:

      * ``target_outstanding_per_replica`` — keep total in-flight requests
        near ``target × replicas`` (queue-depth signal, HPA-ratio scaled);
      * ``target_p99_seconds`` — keep observed p99 latency near the target.

    Both signals propose a count; the larger (most demanding) wins, clamped
    to ``[min_replicas, max_replicas]``.  ``scale_up_cooldown_seconds`` /
    ``scale_down_cooldown_seconds`` rate-limit consecutive moves in each
    direction (with a ±10% tolerance band for hysteresis), and the
    autoscaler never moves while a kill, drain, or failover is in flight.
    """
    min_replicas: int = 1
    max_replicas: int = 1
    target_outstanding_per_replica: Optional[float] = None
    target_p99_seconds: Optional[float] = None
    scale_up_cooldown_seconds: float = 5.0
    scale_down_cooldown_seconds: float = 30.0

    def validate(self) -> None:
        if self.min_replicas < 1:
            raise ValidationError("spec.autoscale.minReplicas must be >= 1")
        if self.max_replicas < self.min_replicas:
            raise ValidationError(
                "spec.autoscale.maxReplicas must be >= minReplicas")
        if (self.target_outstanding_per_replica is None
                and self.target_p99_seconds is None):
            raise ValidationError(
                "spec.autoscale needs targetOutstandingPerReplica and/or "
                "targetP99Seconds")
        if (self.target_outstanding_per_replica is not None
                and self.target_outstanding_per_replica <= 0):
            raise ValidationError(
                "spec.autoscale.targetOutstandingPerReplica must be > 0")
        if (self.target_p99_seconds is not None
                and self.target_p99_seconds <= 0):
            raise ValidationError(
                "spec.autoscale.targetP99Seconds must be > 0")
        if self.scale_up_cooldown_seconds < 0:
            raise ValidationError(
                "spec.autoscale.scaleUpCooldownSeconds must be >= 0")
        if self.scale_down_cooldown_seconds < 0:
            raise ValidationError(
                "spec.autoscale.scaleDownCooldownSeconds must be >= 0")


@dataclass(frozen=True)
class BridgeServiceSpec:
    """spec of a BridgeService.

    ``template`` reuses the BridgeJob target/payload shape (resourceURL,
    image, resourcesecret, jobdata, jobproperties, s3storage) but must not
    carry orchestration fields of its own — array/retry/placement/
    dependencies/ttl belong to the service, which fans the template out into
    ``replicas`` live remote jobs.  ``autoscale`` (optional) lets load
    reports, not a human, own the replica count: ``replicas`` then only
    seeds the initial size and must sit inside ``[min, max]``.
    """
    template: BridgeJobSpec
    replicas: int = 1
    placement: Optional[PlacementSpec] = None
    health: HealthProbeSpec = field(default_factory=HealthProbeSpec)
    updateinterval: float = 20.0
    kill: bool = False
    unknown_after: int = 5
    ttl_seconds_after_finished: Optional[float] = None
    dependencies: List[str] = field(default_factory=list)
    autoscale: Optional[AutoscaleSpec] = None

    def validate(self) -> None:
        if self.replicas < 1:
            raise ValidationError("spec.replicas must be >= 1")
        if self.updateinterval <= 0:
            raise ValidationError("spec.updateinterval must be > 0")
        self.health.validate()
        t = self.template
        if t is None:
            raise ValidationError("spec.template is required")
        placed = bool(self.placement and self.placement.candidates)
        if not placed and not (t.resourceURL and t.image and t.resourcesecret):
            raise ValidationError(
                "spec.template needs resourceURL/image/resourcesecret "
                "unless spec.placement provides candidates")
        if (t.array or t.retry or t.placement or t.dependencies
                or t.ttl_seconds_after_finished is not None):
            raise ValidationError(
                "spec.template must not set array/retry/placement/"
                "dependencies/ttl — the service owns replica orchestration")
        if t.kill:
            raise ValidationError("spec.template.kill is not a field; "
                                  "set spec.kill on the service")
        if t.jobdata.scriptlocation not in SCRIPT_LOCATIONS:
            raise ValidationError(
                f"spec.template.jobdata.scriptlocation "
                f"{t.jobdata.scriptlocation!r} not in {SCRIPT_LOCATIONS}")
        if self.placement is not None:
            self.placement.validate()
        if (self.ttl_seconds_after_finished is not None
                and self.ttl_seconds_after_finished < 0):
            raise ValidationError("spec.ttlSecondsAfterFinished must be >= 0")
        for dep in self.dependencies:
            if not dep or not isinstance(dep, str):
                raise ValidationError(
                    f"spec.dependencies entries must be job names, got {dep!r}")
        if self.autoscale is not None:
            self.autoscale.validate()
            if not (self.autoscale.min_replicas <= self.replicas
                    <= self.autoscale.max_replicas):
                raise ValidationError(
                    f"spec.replicas ({self.replicas}) must sit inside "
                    f"spec.autoscale [{self.autoscale.min_replicas}, "
                    f"{self.autoscale.max_replicas}]")


@dataclass
class BridgeServiceStatus:
    """Mirrors the service config map.

    ``endpoints`` carries one entry per live replica:
    ``{"replica": i, "slice": k, "resourceURL": ..., "image": ...,
    "resourcesecret": ..., "job_id": ..., "ready": bool}`` — ``ready`` flips
    false in the SAME reconcile tick the replica is condemned, which is what
    lets the router drain it before routing another request its way.
    """
    state: str = PENDING
    message: str = ""
    job_id: str = ""
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    restarts: int = 0              # controller-pod restarts (operator-level)
    ready_replicas: int = 0
    endpoints: List[Dict[str, Any]] = field(default_factory=list)
    index_states: Dict[str, str] = field(default_factory=dict)
    observed_generation: int = 0
    placements: List[Dict[str, Any]] = field(default_factory=list)
    # autoscaler observability (empty unless spec.autoscale is set):
    # {desired, min, max, signals: {...}, last_scale_up, last_scale_down}
    autoscale: Dict[str, Any] = field(default_factory=dict)

    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES


@dataclass
class BridgeService:
    """A full BridgeService CR.  Duck-types BridgeJob for the registry and
    operator stores: uid/spec.validate()/status.terminal()/generation/
    resource_version/deleted all behave identically."""
    name: str
    spec: BridgeServiceSpec
    namespace: str = "default"
    status: BridgeServiceStatus = field(default_factory=BridgeServiceStatus)
    generation: int = 1
    resource_version: int = 0
    deleted: bool = False

    kind = SERVICE_KIND

    @property
    def uid(self) -> str:
        return f"{self.namespace}/{self.name}"

    def to_dict(self, version: Optional[str] = None) -> Dict[str, Any]:
        if version is None:
            version = API_V1BETA1
        if version != API_V1BETA1:
            raise ConversionError(
                f"{SERVICE_KIND} is served at {API_V1BETA1} only")
        return {
            "apiVersion": API_V1BETA1,
            "kind": SERVICE_KIND,
            "metadata": {"name": self.name, "namespace": self.namespace,
                         "generation": self.generation},
            "spec": service_spec_to_dict(self.spec),
            "status": dataclasses.asdict(self.status),
        }

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "BridgeService":
        if d.get("kind") != SERVICE_KIND:
            raise ValidationError(f"kind {d.get('kind')!r} != {SERVICE_KIND}")
        if d.get("apiVersion", API_V1BETA1) != API_V1BETA1:
            raise ConversionError(
                f"{SERVICE_KIND} is served at {API_V1BETA1} only")
        meta = d.get("metadata", {})
        spec = service_spec_from_dict(d.get("spec", {}))
        svc = BridgeService(name=meta.get("name", ""), spec=spec,
                            namespace=meta.get("namespace", "default"),
                            generation=int(meta.get("generation", 1)))
        status = d.get("status") or {}
        if "observed_generation" in status:
            svc.status.observed_generation = int(status["observed_generation"])
        if status.get("endpoints"):
            svc.status.endpoints = [dict(e) for e in status["endpoints"]]
        if status.get("autoscale"):
            svc.status.autoscale = dict(status["autoscale"])
        if not svc.name:
            raise ValidationError("metadata.name is required")
        spec.validate()
        return svc


def service_spec_to_dict(s: BridgeServiceSpec) -> Dict[str, Any]:
    d: Dict[str, Any] = {
        "replicas": s.replicas,
        "template": _spec_to_dict(s.template, API_V1BETA1),
        "health": dataclasses.asdict(s.health),
        "updateinterval": s.updateinterval,
        "kill": s.kill,
        "unknown_after": s.unknown_after,
    }
    if s.placement and s.placement.candidates:
        d["placement"] = placement_to_dict(s.placement)
    if s.ttl_seconds_after_finished is not None:
        d["ttlSecondsAfterFinished"] = s.ttl_seconds_after_finished
    if s.dependencies:
        d["dependencies"] = list(s.dependencies)
    if s.autoscale is not None:
        a = s.autoscale
        asd: Dict[str, Any] = {
            "minReplicas": a.min_replicas,
            "maxReplicas": a.max_replicas,
            "scaleUpCooldownSeconds": a.scale_up_cooldown_seconds,
            "scaleDownCooldownSeconds": a.scale_down_cooldown_seconds,
        }
        if a.target_outstanding_per_replica is not None:
            asd["targetOutstandingPerReplica"] = (
                a.target_outstanding_per_replica)
        if a.target_p99_seconds is not None:
            asd["targetP99Seconds"] = a.target_p99_seconds
        d["autoscale"] = asd
    return d


def service_spec_from_dict(d: Dict[str, Any]) -> BridgeServiceSpec:
    h = d.get("health", {})
    plc = d.get("placement")
    ttl = d.get("ttlSecondsAfterFinished")
    asd = d.get("autoscale")
    autoscale = None
    if asd is not None:
        tout = asd.get("targetOutstandingPerReplica")
        tp99 = asd.get("targetP99Seconds")
        autoscale = AutoscaleSpec(
            min_replicas=int(asd.get("minReplicas", 1)),
            max_replicas=int(asd.get("maxReplicas", 1)),
            target_outstanding_per_replica=(
                None if tout is None else float(tout)),
            target_p99_seconds=None if tp99 is None else float(tp99),
            scale_up_cooldown_seconds=float(
                asd.get("scaleUpCooldownSeconds", 5.0)),
            scale_down_cooldown_seconds=float(
                asd.get("scaleDownCooldownSeconds", 30.0)),
        )
    return BridgeServiceSpec(
        template=spec_from_dict(d.get("template", {})),
        replicas=int(d.get("replicas", 1)),
        placement=placement_from_dict(plc),
        health=HealthProbeSpec(
            failure_threshold=int(h.get("failure_threshold", 3)),
            startup_failure_threshold=int(
                h.get("startup_failure_threshold", 10)),
        ),
        updateinterval=float(d.get("updateinterval", 20.0)),
        kill=bool(d.get("kill", False)),
        unknown_after=int(d.get("unknown_after", 5)),
        ttl_seconds_after_finished=None if ttl is None else float(ttl),
        dependencies=list(d.get("dependencies", [])),
        autoscale=autoscale,
    )
