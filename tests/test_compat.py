"""repro.compat — the version-portable JAX substrate layer.

Two halves:
  * unit tests that every seam (shard_map / use_mesh / mosaic_params /
    jit_sharded / capability probes) RESOLVES and WORKS on the installed
    JAX, whatever its version;
  * a source-scan regression test enforcing the seam's one rule: nothing
    under src/repro/ outside compat/ (and nothing under tools/) may
    reference the version-sensitive spellings directly.
"""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- resolution --------------------------------------------------------------


def test_describe_reports_every_seam():
    d = compat.describe()
    assert d["jax_version"] == jax.__version__
    assert "shard_map" in d["shard_map"]
    assert d["use_mesh"].startswith("jax.sharding.")
    assert isinstance(d["pallas_available"], bool)
    assert d["best_kernel_path"] in ("pallas_tpu", "pallas_interpret", "xla")


def test_shard_map_resolves_and_runs():
    mesh = jax.make_mesh((1,), ("x",))
    f = compat.shard_map(lambda a: a * 2.0, mesh, in_specs=P(),
                         out_specs=P())
    np.testing.assert_allclose(np.asarray(f(jnp.ones(4))), 2.0)


def test_shard_map_accepts_check_vma_spelling():
    """check_vma must be translated to whatever this JAX calls it."""
    mesh = jax.make_mesh((1,), ("x",))
    for flag in (False, True):
        f = compat.shard_map(lambda a: a + 1.0, mesh, in_specs=P(),
                             out_specs=P(), check_vma=flag)
        np.testing.assert_allclose(np.asarray(f(jnp.zeros(2))), 1.0)


def test_use_mesh_context_manager():
    mesh = jax.make_mesh((1,), ("x",))
    with compat.use_mesh(mesh) as m:
        assert m is mesh
        # re-entrancy: nested contexts must not blow up
        with compat.use_mesh(mesh):
            pass
    assert compat.use_mesh_source().startswith("jax.sharding.")


def test_use_mesh_enables_sharded_jit():
    mesh = jax.make_mesh((1,), ("x",))
    sh = NamedSharding(mesh, P("x"))
    with compat.use_mesh(mesh):
        out = jax.jit(lambda a: a * 3.0, in_shardings=sh,
                      out_shardings=sh)(jnp.ones(8))
    np.testing.assert_allclose(np.asarray(out), 3.0)


def test_mosaic_params_resolves_on_installed_jax():
    got = compat.mosaic_params(
        dimension_semantics=("parallel", "arbitrary"))
    if compat.pallas_available():
        assert set(got) == {"compiler_params"}
        assert type(got["compiler_params"]).__name__.endswith("CompilerParams")
        assert compat.compiler_params_source() is not None
    else:
        assert got == {}


def test_mosaic_params_drops_unknown_fields():
    """Field drift must degrade to 'unset', never TypeError."""
    got = compat.mosaic_params(
        dimension_semantics=("parallel",),
        definitely_not_a_real_mosaic_field_xyz=1)
    if got:
        cp = got["compiler_params"]
        assert not hasattr(cp, "definitely_not_a_real_mosaic_field_xyz")


def test_mosaic_params_accepted_by_pallas_call():
    if not compat.pallas_available():
        pytest.skip("pallas unavailable on this JAX")
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        interpret=True,
        **compat.mosaic_params(dimension_semantics=()),
    )(jnp.ones((8, 128), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), 2.0)


# -- capability probes -------------------------------------------------------


def test_capability_probes_are_consistent():
    assert isinstance(compat.has_tpu(), bool)
    assert isinstance(compat.pallas_available(), bool)
    if "REPRO_PALLAS_INTERPRET" not in os.environ:
        assert compat.pallas_interpret_default() == (not compat.has_tpu())
    path = compat.best_kernel_path()
    if not compat.pallas_available():
        assert path == "xla"
    elif compat.has_tpu():
        assert path == "pallas_tpu"
    else:
        assert path == "pallas_interpret"


def test_resolve_interpret_tristate():
    assert compat.resolve_interpret(True) is True
    assert compat.resolve_interpret(False) is False
    assert compat.resolve_interpret(None) == compat.pallas_interpret_default()


def test_pallas_interpret_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert compat.pallas_interpret_default() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert compat.pallas_interpret_default() is False


# -- jit over PartitionSpec pytrees ------------------------------------------


def test_resolve_shardings_binds_specs_and_keeps_none():
    mesh = jax.make_mesh((1,), ("x",))
    tree = ({"w": P("x"), "b": P()}, None)
    got = compat.resolve_shardings(mesh, tree)
    assert isinstance(got[0]["w"], NamedSharding)
    assert got[0]["w"].spec == P("x")
    assert got[1] is None
    already = NamedSharding(mesh, P())
    assert compat.resolve_shardings(mesh, already) is already


def test_jit_sharded_runs_with_spec_pytrees():
    mesh = jax.make_mesh((1,), ("x",))

    def step(params, batch):
        return {"w": params["w"] + batch.sum()}, None

    with compat.use_mesh(mesh):
        jf = compat.jit_sharded(step, mesh,
                                in_shardings=({"w": P()}, P("x")),
                                out_shardings=({"w": P()}, None))
        out, _ = jf({"w": jnp.zeros(3)}, jnp.ones(4))
    np.testing.assert_allclose(np.asarray(out["w"]), 4.0)


# -- pure-XLA fallback tier --------------------------------------------------


def test_kernel_ops_xla_fallback_matches_ref(monkeypatch):
    """The `pallas unavailable` tier of every kernel wrapper must produce
    ref numerics.  Unreachable on a pin where pallas imports, so force it:
    the wrappers look up ``pallas_available`` at trace time."""
    from repro.kernels import ops, ref

    monkeypatch.setattr(ops, "pallas_available", lambda: False)
    # odd shapes unused elsewhere so the jit caches can't serve a trace
    # made while pallas_available was still True
    ks = jax.random.split(jax.random.PRNGKey(42), 5)

    q = jax.random.normal(ks[0], (1, 56, 6, 24))
    k = jax.random.normal(ks[1], (1, 56, 3, 24))
    v = jax.random.normal(ks[2], (1, 56, 3, 24))
    got = ops.flash_attention(q, k, v)
    want = jnp.swapaxes(ref.flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    qd = jax.random.normal(ks[0], (2, 1, 6, 24))
    ck = jax.random.normal(ks[1], (2, 40, 3, 24))
    cv = jax.random.normal(ks[2], (2, 40, 3, 24))
    lengths = jnp.asarray([13, 40], jnp.int32)
    got = ops.decode_attention(qd, ck, cv, lengths)
    want = ref.decode_attention_ref(qd[:, 0], jnp.swapaxes(ck, 1, 2),
                                    jnp.swapaxes(cv, 1, 2), lengths)[:, None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    dA = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 20, 6, 5)) + 2.0)
    dBx = jax.random.normal(ks[1], (1, 20, 6, 5)) * 0.1
    C = jax.random.normal(ks[2], (1, 20, 5))
    y_got, h_got = ops.ssm_scan(dA, dBx, C, chunk=8)
    y_want, h_want = ref.ssm_scan_ref(dA, dBx, C)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                               rtol=2e-4, atol=2e-5)

    delta = jax.nn.softplus(jax.random.normal(ks[0], (1, 20, 6)))
    B = jax.random.normal(ks[1], (1, 20, 5))
    x = jax.random.normal(ks[3], (1, 20, 6))
    A = -jnp.exp(jax.random.normal(ks[4], (6, 5)))
    y_got, h_got = ops.ssm_scan_fused(delta, B, C, x, A, chunk=8)
    y_want, h_want = ref.ssm_scan_ref(*ref.ssm_discretize(delta, B, x, A), C)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                               rtol=2e-4, atol=2e-5)


# -- source-scan regression --------------------------------------------------

# every documented spelling of the version-sensitive APIs, old and new:
# shard_map (both locations), the mesh context (both spellings), and the
# Pallas compiler-params classes
BANNED = re.compile(r"jax\.shard_map|jax\.experimental\.shard_map"
                    r"|set_mesh|jax\.sharding\.use_mesh|CompilerParams")


def _scan(root, skip_dir=None):
    hits = []
    for dirpath, _dirnames, filenames in os.walk(root):
        if skip_dir and os.path.abspath(dirpath).startswith(skip_dir):
            continue
        for fn in filenames:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path, encoding="utf-8") as f:
                for lineno, line in enumerate(f, 1):
                    if BANNED.search(line):
                        hits.append(f"{os.path.relpath(path, ROOT)}:"
                                    f"{lineno}: {line.strip()}")
    return hits


def test_no_direct_version_sensitive_jax_apis_outside_compat():
    """repro.compat is the single entry point for version-sensitive JAX
    APIs; any direct reference elsewhere re-litters the tree with the
    exact churn this layer exists to absorb."""
    hits = _scan(os.path.join(ROOT, "src", "repro"),
                 skip_dir=os.path.join(ROOT, "src", "repro", "compat"))
    hits += _scan(os.path.join(ROOT, "tools"))
    assert not hits, ("direct version-sensitive JAX API use — route through "
                      "repro.compat:\n" + "\n".join(hits))
