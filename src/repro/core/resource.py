"""BridgeJob — the paper's Custom Resource (CRD analogue).

Mirrors the ``BridgeJob`` yaml of paper Fig. 1:

    kind: BridgeJob
    apiVersion: bridgeoperator.ibm.com/v1alpha1
    metadata: {name: slurmjob-test}
    spec:
      resourceURL: http://my-slurm-cluster@hpc.com
      image: slurmpod:0.1
      resourcesecret: mysecret
      imagepullpolicy: Always
      updateinterval: 20
      jobdata: {jobscript: ..., scriptlocation: s3|remote|inline, ...}
      jobproperties: {...}
      s3storage: {s3secret: ..., endpoint: ..., secure: ...}

The spec is declarative; the operator reconciles it.  Status carries the
paper's terminal states DONE/KILLED/FAILED/UNKNOWN plus start/end times.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

API_VERSION = "bridgeoperator.repro/v1alpha1"
KIND = "BridgeJob"

# Lifecycle states (paper §5.1 + DESIGN.md §8).
PENDING = "PENDING"
SUBMITTED = "SUBMITTED"
RUNNING = "RUNNING"
DONE = "DONE"
FAILED = "FAILED"
KILLED = "KILLED"
UNKNOWN = "UNKNOWN"

TERMINAL_STATES = (DONE, FAILED, KILLED)
ALL_STATES = (PENDING, SUBMITTED, RUNNING, DONE, FAILED, KILLED, UNKNOWN)

SCRIPT_LOCATIONS = ("inline", "s3", "remote")


class ValidationError(ValueError):
    pass


@dataclass(frozen=True)
class JobData:
    """spec.jobdata — what to run and where the script lives."""
    jobscript: str = ""          # inline text | "bucket:key" | remote path
    scriptlocation: str = "inline"
    scriptmd: str = ""           # optional integrity digest
    additionaldata: str = ""     # comma-sep "bucket:key" files staged to the resource
    jobparams: Dict[str, str] = field(default_factory=dict)


@dataclass(frozen=True)
class S3Storage:
    """spec.s3storage — object-store endpoint used for staging/uploads."""
    s3secret: str = ""
    endpoint: str = ""
    secure: bool = False
    uploadfiles: str = ""        # comma-sep output files to upload on completion
    uploadbucket: str = ""


@dataclass(frozen=True)
class BridgeJobSpec:
    resourceURL: str
    image: str                     # controller-pod image == backend kind ("slurmpod:0.1")
    resourcesecret: str
    imagepullpolicy: str = "IfNotPresent"
    updateinterval: float = 20.0   # poll seconds (paper: CR poll parameter)
    jobdata: JobData = field(default_factory=JobData)
    jobproperties: Dict[str, str] = field(default_factory=dict)
    s3storage: Optional[S3Storage] = None
    # kill signal: "a user can also update the CR with a kill signal" (§5.1)
    kill: bool = False
    # UNKNOWN after this many consecutive unreachable polls (DESIGN.md §8)
    unknown_after: int = 5

    def validate(self) -> None:
        if not self.resourceURL:
            raise ValidationError("spec.resourceURL is required")
        if not self.image:
            raise ValidationError("spec.image is required")
        if not self.resourcesecret:
            raise ValidationError("spec.resourcesecret is required")
        if self.updateinterval <= 0:
            raise ValidationError("spec.updateinterval must be > 0")
        if self.jobdata.scriptlocation not in SCRIPT_LOCATIONS:
            raise ValidationError(
                f"spec.jobdata.scriptlocation {self.jobdata.scriptlocation!r} "
                f"not in {SCRIPT_LOCATIONS}")
        if self.jobdata.scriptlocation == "s3":
            if self.s3storage is None:
                raise ValidationError("scriptlocation=s3 requires spec.s3storage")
            if ":" not in self.jobdata.jobscript:
                raise ValidationError("s3 jobscript must be 'bucket:key'")
        if self.s3storage and self.s3storage.uploadfiles and not self.s3storage.uploadbucket:
            raise ValidationError("s3storage.uploadfiles requires uploadbucket")


@dataclass
class BridgeJobStatus:
    state: str = PENDING
    message: str = ""
    job_id: str = ""               # remote job id (mirrored from the config map)
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    restarts: int = 0              # controller-pod restarts performed by the operator

    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES


@dataclass
class BridgeJob:
    """A full CR object: metadata + spec + status."""
    name: str
    spec: BridgeJobSpec
    namespace: str = "default"
    status: BridgeJobStatus = field(default_factory=BridgeJobStatus)
    # registry bookkeeping
    resource_version: int = 0
    deleted: bool = False

    @property
    def uid(self) -> str:
        return f"{self.namespace}/{self.name}"

    # -- dict round-trip (yaml-equivalent; json keeps the container offline) --

    def to_dict(self) -> Dict[str, Any]:
        d = {
            "apiVersion": API_VERSION,
            "kind": KIND,
            "metadata": {"name": self.name, "namespace": self.namespace},
            "spec": _spec_to_dict(self.spec),
            "status": dataclasses.asdict(self.status),
        }
        return d

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "BridgeJob":
        if d.get("kind", KIND) != KIND:
            raise ValidationError(f"kind {d.get('kind')!r} != {KIND}")
        meta = d.get("metadata", {})
        spec = spec_from_dict(d.get("spec", {}))
        job = BridgeJob(name=meta.get("name", ""), spec=spec,
                        namespace=meta.get("namespace", "default"))
        if not job.name:
            raise ValidationError("metadata.name is required")
        spec.validate()
        return job


def _spec_to_dict(s: BridgeJobSpec) -> Dict[str, Any]:
    d: Dict[str, Any] = {
        "resourceURL": s.resourceURL,
        "image": s.image,
        "resourcesecret": s.resourcesecret,
        "imagepullpolicy": s.imagepullpolicy,
        "updateinterval": s.updateinterval,
        "jobdata": dataclasses.asdict(s.jobdata),
        "jobproperties": dict(s.jobproperties),
        "kill": s.kill,
        "unknown_after": s.unknown_after,
    }
    if s.s3storage is not None:
        d["s3storage"] = dataclasses.asdict(s.s3storage)
    return d


def spec_from_dict(d: Dict[str, Any]) -> BridgeJobSpec:
    jd = d.get("jobdata", {})
    s3 = d.get("s3storage")
    spec = BridgeJobSpec(
        resourceURL=d.get("resourceURL", ""),
        image=d.get("image", ""),
        resourcesecret=d.get("resourcesecret", ""),
        imagepullpolicy=d.get("imagepullpolicy", "IfNotPresent"),
        updateinterval=float(d.get("updateinterval", 20.0)),
        jobdata=JobData(
            jobscript=jd.get("jobscript", ""),
            scriptlocation=jd.get("scriptlocation", "inline"),
            scriptmd=jd.get("scriptmd", ""),
            additionaldata=jd.get("additionaldata", ""),
            jobparams=dict(jd.get("jobparams", {})),
        ),
        jobproperties=dict(d.get("jobproperties", {})),
        s3storage=None if s3 is None else S3Storage(
            s3secret=s3.get("s3secret", ""),
            endpoint=s3.get("endpoint", ""),
            secure=bool(s3.get("secure", False)),
            uploadfiles=s3.get("uploadfiles", ""),
            uploadbucket=s3.get("uploadbucket", ""),
        ),
        kill=bool(d.get("kill", False)),
        unknown_after=int(d.get("unknown_after", 5)),
    )
    return spec


def load_bridgejob(text: str) -> BridgeJob:
    """Parse a BridgeJob from its JSON serialization (yaml stand-in)."""
    return BridgeJob.from_dict(json.loads(text))
