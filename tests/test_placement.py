"""Sharded placement: one array CR split across multiple external resources.

The tentpole guarantees under test:

  * a ``spec.placement`` array CR is partitioned into per-resource SLICES
    (contiguous initial index ranges, split load-proportionally for
    ``strategy: spread``), each slice submitted natively on its own
    endpoint and batch-polled independently;
  * slice state lives in per-slice config-map keys (``slice_{k}_id``), the
    plan is assigned ONCE (a restarted pod resumes the recorded plan and
    never resubmits a live index), and per-slice status surfaces through
    ``JobHandle.placements()`` / ``status.placements``;
  * a one-slice plan (``strategy: single``, or maxSlices=1) collapses onto
    the legacy config-map shape byte-for-byte — slice count 1 == today's
    single-resource CR;
  * the elastic verbs (`scale`, `wait_reconciled`) work unchanged on sliced
    jobs, with growth routed to the least-loaded slice.

Everything here is mode-parametrized: both operator modes run the same
protocol object.
"""
import json
import time

import pytest

from repro.core import (ArraySpec, BridgeEnvironment, DONE, FaultProfile,
                        IMAGES, KILLED, PlacementCandidate, PlacementSpec,
                        URLS)
from repro.core.backends import base as B

MODES = ["multiplexed", "pod-per-cr"]
# (mode, cadence) matrix: both runtimes under the default fixed cadence,
# plus the event-driven cadences on the multiplexed runtime.  Sliced CRs
# exercise per-slice watch watermarks and per-chain cadence state; none of
# the assertions below depend on tick timing.
OPERATORS = [(m, "fixed") for m in MODES] + [
    ("multiplexed", "adaptive"), ("multiplexed", "watch")]


def _wait(predicate, timeout=30, interval=0.005):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


def _ids(handle):
    return [s for s in handle.status().job_id.split(",") if s]


def _placement(kinds, strategy="spread", max_slices=0, weights=None):
    return PlacementSpec(candidates=[
        PlacementCandidate(URLS[k], IMAGES[k], f"{k}-secret",
                           weight=(weights or {}).get(k, 1.0))
        for k in kinds], strategy=strategy, max_slices=max_slices)


def _index_of(cluster_job):
    """The global array index a remote job was submitted for (native slurm
    marker, native 1-based LSF marker, or the bridge's own marker)."""
    p = cluster_job.params
    if "SLURM_ARRAY_TASK_ID" in p:
        return int(p["SLURM_ARRAY_TASK_ID"])
    if "BRIDGE_ARRAY_INDEX" in p:
        return int(p["BRIDGE_ARRAY_INDEX"])
    if "LSB_JOBINDEX" in p:
        return int(p["LSB_JOBINDEX"]) - 1
    return None


# ---------------------------------------------------------------------------
# acceptance: 64 indices, strategy spread, slurm + lsf, both modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,cadence", OPERATORS)
def test_spread_64_across_two_resources_runs_to_done(mode, cadence):
    """A 64-index array spread over two UNEVEN resources (8 vs 4 slots)
    splits load-proportionally (43/21), submits each slice natively in one
    call, runs to DONE in both operator modes, and reports per-slice status
    through placements()."""
    with BridgeEnvironment(default_duration=0.1, slots=8,
                           operator_kwargs={"mode": mode,
                                            "cadence": cadence}) as env:
        env.clusters["lsf"].slots = 4  # uneven capacity: free 8 vs free 4
        h = env.bridge.submit("shard", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "0.1"},
            array=ArraySpec(count=64),
            placement=_placement(["slurm", "lsf"])))
        job = h.wait(timeout=120)
        assert job.status.state == DONE, job.status.message

        # load-proportional split: 64 * 8/12 -> 43 on slurm, 21 on lsf
        slurm_jobs = env.clusters["slurm"].jobs
        lsf_jobs = env.clusters["lsf"].jobs
        assert len(slurm_jobs) == 43 and len(lsf_jobs) == 21
        # contiguous ranges: slurm owns [0, 43), lsf owns [43, 64)
        assert sorted(_index_of(j) for j in slurm_jobs.values()) == list(
            range(43))
        assert sorted(_index_of(j) for j in lsf_jobs.values()) == list(
            range(43, 64))
        # every index DONE, exactly once
        assert sorted(job.status.index_states, key=int) == [
            str(i) for i in range(64)]
        assert set(job.status.index_states.values()) == {DONE}

        # per-slice status surfaces through the facade
        placements = h.placements()
        assert [p["slice"] for p in placements] == [0, 1]
        assert placements[0]["resourceURL"] == URLS["slurm"]
        assert placements[1]["resourceURL"] == URLS["lsf"]
        assert all(p["state"] == DONE for p in placements)
        union = sorted(i for p in placements for i in p["indices"])
        assert union == list(range(64)), "union of slices == desired set"

        # per-slice state-store keys, GC'd nowhere (no resize happened)
        cm = env.statestore.get("default/shard-bridge-cm").data
        assert len(json.loads(cm["slices"])) == 2
        assert len([t for t in cm["slice_0_id"].split(",") if t]) == 43
        assert len([t for t in cm["slice_1_id"].split(",") if t]) == 21


@pytest.mark.parametrize("mode,cadence", OPERATORS)
def test_scale_up_routes_delta_to_least_loaded_slice_with_midkill(
        mode, cadence):
    """Acceptance: JobHandle.scale() on a sliced job converges
    (wait_reconciled) with the delta routed to the least-loaded slice, and
    a pod killed mid-rebalance resumes without double-submitting."""
    fp = {"lsf": FaultProfile(latency=0.004)}  # widen the mid-fanout window
    with BridgeEnvironment(default_duration=600, slots=8, fault_profiles=fp,
                           operator_kwargs={"mode": mode,
                                            "cadence": cadence}) as env:
        env.clusters["lsf"].slots = 4
        h = env.bridge.submit("rebal", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "600"},
            array=ArraySpec(count=64),
            placement=_placement(["slurm", "lsf"])))
        assert _wait(lambda: len(_ids(h)) == 64, timeout=60)
        assert len(env.clusters["lsf"].jobs) == 21

        # loads now: slurm 43/8 = 5.375, lsf 21/4 = 5.25 -> lsf is the
        # least-loaded slice and must receive the whole 16-index delta
        h.scale(80)
        assert _wait(lambda: len(_ids(h)) >= 66, timeout=30)
        env.operator.pods["default/rebal"].kill_pod()  # mid-rebalance

        job = h.wait_reconciled(timeout=90)
        assert job.status.restarts >= 1
        assert len(_ids(h)) == 80
        assert len(env.clusters["slurm"].jobs) == 43, (
            "the delta must not land on the more-loaded slice")
        assert len(env.clusters["lsf"].jobs) == 37, (
            "exactly 16 new submissions — the restarted pod must resume the "
            "half-applied rebalance, not redo it")
        assert sorted(_index_of(j)
                      for j in env.clusters["lsf"].jobs.values()) == sorted(
            list(range(43, 64)) + list(range(64, 80)))
        placements = {p["slice"]: p for p in h.placements()}
        assert sorted(placements[1]["indices"]) == sorted(
            list(range(43, 64)) + list(range(64, 80)))

        # scale-down condemns the globally-highest indices (all on lsf here)
        h.scale(60)
        job = h.wait_reconciled(timeout=90)
        cancelled = [j for j in env.clusters["lsf"].jobs.values()
                     if j.state == B.CANCELLED]
        assert {_index_of(j) for j in cancelled} == set(range(60, 80))
        assert [j for j in env.clusters["slurm"].jobs.values()
                if j.state == B.CANCELLED] == []
        union = sorted(i for p in h.placements() for i in p["indices"])
        assert union == list(range(60))


# ---------------------------------------------------------------------------
# plan stability + restart resume
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,cadence", OPERATORS)
def test_pod_restart_resumes_all_slices_without_resubmission(mode, cadence):
    """The slice plan is assigned once, at config-map creation: a pod killed
    after submission resumes EVERY slice from its slice_{k}_id keys — zero
    new remote jobs across both resources."""
    with BridgeEnvironment(default_duration=600, slots=8,
                           operator_kwargs={"mode": mode,
                                            "cadence": cadence}) as env:
        h = env.bridge.submit("resume", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "600"},
            array=ArraySpec(count=12),
            placement=_placement(["slurm", "lsf"])))
        assert _wait(lambda: len(_ids(h)) == 12, timeout=30)
        total0 = (len(env.clusters["slurm"].jobs)
                  + len(env.clusters["lsf"].jobs))
        env.operator.pods["default/resume"].kill_pod()
        assert _wait(lambda: (env.registry.get("resume").status.restarts >= 1
                              and len(_ids(h)) == 12), timeout=30)
        time.sleep(0.2)  # several ticks of the replacement pod
        assert (len(env.clusters["slurm"].jobs)
                + len(env.clusters["lsf"].jobs)) == total0, (
            "restart-resume must not resubmit any slice's live indices")
        assert not h.status().terminal()


def test_kill_signal_cancels_every_slice():
    """The CR kill flag fans out to every slice's resource."""
    with BridgeEnvironment(default_duration=600, slots=8) as env:
        h = env.bridge.submit("skill", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "600"},
            array=ArraySpec(count=8),
            placement=_placement(["slurm", "lsf"])))
        assert _wait(lambda: len(_ids(h)) == 8, timeout=30)
        h.cancel()
        job = h.wait(timeout=60)
        assert job.status.state == KILLED
        for kind in ("slurm", "lsf"):
            assert all(j.state == B.CANCELLED
                       for j in env.clusters[kind].jobs.values()), kind


# ---------------------------------------------------------------------------
# single-winner placement: byte-for-byte the unsliced shape
# ---------------------------------------------------------------------------


def test_single_strategy_collapses_to_legacy_configmap_shape():
    """strategy=single (and any one-slice plan) must produce EXACTLY the
    config-map shape an unplaced CR gets — no slices key, no slice-namespaced
    ids — with the winner's endpoint in the legacy keys."""
    with BridgeEnvironment(default_duration=0.05, slots=4) as env:
        # saturate slurm so the single winner is lsf
        for _ in range(8):
            env.clusters["slurm"].submit("hog", {"WallSeconds": "10"}, {})
        placed = env.bridge.submit("one", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            array=ArraySpec(count=3),
            placement=_placement(["slurm", "lsf"], strategy="single")))
        plain = env.bridge.submit("two", env.make_spec(
            "lsf", script="member", updateinterval=0.02,
            array=ArraySpec(count=3)))
        assert placed.wait(timeout=30).status.state == DONE
        assert plain.wait(timeout=30).status.state == DONE
        cm_placed = env.statestore.get("default/one-bridge-cm").data
        cm_plain = env.statestore.get("default/two-bridge-cm").data
        assert cm_placed["resourceURL"] == URLS["lsf"]
        assert cm_placed["image"] == IMAGES["lsf"]
        assert sorted(cm_placed) == sorted(cm_plain), (
            "one-slice placement must keep the legacy key set byte-for-byte")
        assert placed.placements() == [], (
            "single-resource jobs report no slice map")


def test_max_slices_one_is_single_winner():
    with BridgeEnvironment(default_duration=0.05, slots=4) as env:
        h = env.bridge.submit("cap", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            array=ArraySpec(count=4),
            placement=_placement(["slurm", "lsf"], max_slices=1)))
        assert h.wait(timeout=30).status.state == DONE
        cm = env.statestore.get("default/cap-bridge-cm").data
        assert "slices" not in cm
        assert len(env.clusters["slurm"].jobs) + len(
            env.clusters["lsf"].jobs) == 4


# ---------------------------------------------------------------------------
# per-slice polling independence (the monitor.py layer)
# ---------------------------------------------------------------------------


def test_slow_slice_does_not_stall_healthy_slice_polling():
    """Multiplexed mode schedules one chain per slice: a high-latency
    resource slows ONLY its own slice's cadence — the healthy slice keeps
    getting polled at its own interval."""
    fp = {"lsf": FaultProfile(latency=0.25)}  # lsf answers very slowly
    with BridgeEnvironment(default_duration=600, slots=8, fault_profiles=fp,
                           operator_kwargs={"mode": "multiplexed"}) as env:
        h = env.bridge.submit("slow", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "600"},
            array=ArraySpec(count=8),
            placement=_placement(["slurm", "lsf"])))
        assert _wait(lambda: len(_ids(h)) == 8, timeout=60)
        slurm_req0 = env.servers["slurm"].request_count
        window = 0.6
        time.sleep(window)
        slurm_ticks = env.servers["slurm"].request_count - slurm_req0
        # a shared sequential poll would cap BOTH slices near
        # window/latency ≈ 2.4 polls; independent chains keep slurm near
        # window/interval ≈ 30
        assert slurm_ticks >= 10, (
            f"healthy slice got only {slurm_ticks} polls in {window}s — "
            f"the slow slice is stalling it")


@pytest.mark.parametrize("mode,cadence", OPERATORS)
def test_unreachable_slice_surfaces_unknown_not_masked(mode, cadence):
    """One slice's resource going dark marks the CR UNKNOWN (naming the
    slice) even while the healthy slice keeps answering — the aggregate
    from fresh+stale data must not mask the blackout — and the CR recovers
    once the resource answers again."""
    with BridgeEnvironment(default_duration=600, slots=8,
                           operator_kwargs={"mode": mode,
                                            "cadence": cadence}) as env:
        h = env.bridge.submit("dark", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "600"},
            array=ArraySpec(count=8),
            placement=_placement(["slurm", "lsf"])))
        assert _wait(lambda: len(_ids(h)) == 8, timeout=30)
        env.servers["lsf"].fault.begin_outage()
        try:
            assert _wait(lambda: h.status().state == "UNKNOWN", timeout=30), (
                h.status().state, h.status().message)
            assert "slice 1 resource unreachable" in h.status().message
            # and it STAYS unknown (not flapping back to RUNNING off the
            # healthy slice's ticks)
            time.sleep(0.2)
            assert h.status().state == "UNKNOWN"
        finally:
            env.servers["lsf"].fault.end_outage()
        assert _wait(lambda: h.status().state == "RUNNING", timeout=30)
        assert not h.status().terminal()


# ---------------------------------------------------------------------------
# elastic + placement interplay
# ---------------------------------------------------------------------------


def test_sliced_scale_down_prunes_slice_namespaced_state():
    """Scale-down GC on a sliced job drops the drained indices' per-slice
    keys, so repeated resizes never grow the config map."""
    with BridgeEnvironment(default_duration=600, slots=8) as env:
        h = env.bridge.submit("gc", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "600"},
            array=ArraySpec(count=12),
            placement=_placement(["slurm", "lsf"])))
        assert _wait(lambda: len(_ids(h)) == 12, timeout=30)
        baseline = None
        for count in (4, 12, 4):
            h.scale(count)
            h.wait_reconciled(timeout=60)
            assert _wait(lambda: len(json.loads(env.statestore.get(
                "default/gc-bridge-cm").get("index_states"))) == count,
                timeout=30)
            cm = env.statestore.get("default/gc-bridge-cm").data
            union = sorted(
                int(t.split("=")[0])
                for k in ("slice_0_id", "slice_1_id")
                for t in cm.get(k, "").split(",") if t)
            assert union == list(range(count))
            if count == 4:
                if baseline is None:
                    baseline = len(cm)
                else:
                    assert len(cm) == baseline, (
                        "config-map key count grew across resize cycles")
