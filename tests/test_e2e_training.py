"""End-to-end bridged REAL training (jaxlocal backend) + two-level fault
tolerance: bridge restart-resume composes with checkpoint-resume."""
import json
import time

import numpy as np
import pytest

from repro.core import BridgeEnvironment, DONE, FAILED, KILLED, RUNNING


@pytest.fixture()
def env():
    with BridgeEnvironment(default_duration=0.05) as e:
        yield e


def _train_spec(env, *, steps=30, ckpt=10, workdir="ckpts:runs/t1",
                crash_at=0, arch="gemma-2b", seq=16, batch=2, lr=1e-2):
    script = json.dumps({
        "arch": arch, "steps": steps, "batch": batch, "seq": seq,
        "checkpoint_every": ckpt, "workdir": workdir, "lr": lr,
        "crash_at_step": crash_at,
    })
    return env.make_spec("jaxlocal", script=script, updateinterval=0.05,
                         jobproperties={"OutputFileName": "train.out"})


def test_bridged_training_completes_and_learns(env):
    env.submit("train1", _train_spec(env, steps=80, batch=4,
                                     workdir="ckpts:runs/learn"))
    job = env.operator.wait_for("train1", timeout=300)
    assert job.status.state == DONE
    # loss curve was uploaded by the job
    hist_keys = [k for k in env.s3.list("ckpts", "runs/learn/")
                 if "history" in k]
    assert hist_keys
    hist = json.loads(env.s3.get("ckpts", hist_keys[0]))
    assert len(hist) == 80
    # the affine task is learnable: loss must drop substantially
    assert hist[-1] < hist[0] * 0.7, (hist[0], hist[-1])
    assert np.isfinite(hist).all()


def test_checkpoint_resume_after_job_crash(env):
    """Job crashes at step 15 (injected node failure).  A resubmission with
    the same workdir resumes from the step-10 checkpoint, not step 0."""
    wd = "ckpts:runs/crash"
    env.submit("crashy", _train_spec(env, steps=25, ckpt=10, workdir=wd,
                                     crash_at=15))
    job = env.operator.wait_for("crashy", timeout=120)
    assert job.status.state == FAILED
    assert "injected crash" in job.status.message

    # resubmit (new CR, same workdir) without the fault
    env.submit("crashy2", _train_spec(env, steps=25, ckpt=10, workdir=wd))
    job2 = env.operator.wait_for("crashy2", timeout=120)
    assert job2.status.state == DONE
    # verify resume: the completed job reports start_step == 10
    cm = env.statestore.get(env.operator.cm_name(job2))
    jid = cm.get("id")
    cj = env.clusters["jaxlocal"].jobs[jid]
    result = json.loads(cj.outputs["train.out"])
    assert result["start_step"] == 10, result


def test_pod_kill_does_not_kill_training(env):
    """Bridge-level fault tolerance: the controller pod dies, the REMOTE
    training job keeps running; the restarted pod re-attaches and reports
    completion."""
    env.submit("podkill", _train_spec(env, steps=60, ckpt=20,
                                      workdir="ckpts:runs/podkill"))
    deadline = time.time() + 60
    while time.time() < deadline:
        job = env.registry.get("podkill")
        if job.status.job_id and job.status.state == RUNNING:
            break
        time.sleep(0.01)
    first_id = job.status.job_id
    env.operator.pods["default/podkill"].kill_pod()
    job = env.operator.wait_for("podkill", timeout=120)
    assert job.status.state == DONE
    assert job.status.job_id == first_id
    assert job.status.restarts >= 1
    assert len(env.clusters["jaxlocal"].jobs) == 1


def test_kill_bridged_training(env):
    """CR kill propagates: remote training job is cancelled promptly and a
    checkpoint exists for later resumption."""
    env.submit("stopme", _train_spec(env, steps=5000, ckpt=5,
                                     workdir="ckpts:runs/stopme"))
    deadline = time.time() + 60
    while time.time() < deadline:
        job = env.registry.get("stopme")
        if job.status.state == RUNNING:
            break
        time.sleep(0.01)
    # let it make some checkpoints
    deadline = time.time() + 30
    while time.time() < deadline:
        if any("MANIFEST" in k for k in env.s3.list("ckpts", "runs/stopme/")):
            break
        time.sleep(0.05)
    env.operator.kill("stopme")
    job = env.operator.wait_for("stopme", timeout=60)
    assert job.status.state == KILLED
    assert any("MANIFEST" in k for k in env.s3.list("ckpts", "runs/stopme/"))


def test_deterministic_data_restart_identical_curve(env):
    """Same seed + same workdir-free run twice => identical loss curves
    (determinism contract of the data pipeline)."""
    for name in ("det-a", "det-b"):
        env.submit(name, _train_spec(env, steps=8, ckpt=0, workdir=""))
    ja = env.operator.wait_for("det-a", timeout=120)
    jb = env.operator.wait_for("det-b", timeout=120)
    assert ja.status.state == jb.status.state == DONE
    ca = env.clusters["jaxlocal"].jobs[ja.status.job_id]
    cb = env.clusters["jaxlocal"].jobs[jb.status.job_id]
    ra = json.loads(ca.outputs["train.out"])
    rb = json.loads(cb.outputs["train.out"])
    assert ra["final_loss"] == rb["final_loss"]
