"""Load-aware backend selection — the paper's named FUTURE WORK (§7):

    "Future work will focus on creating companion operator using the same
    approach to monitor current load on these remote resources and make
    intelligent decisions on which remote resource ... to use for execution."

Beyond-paper feature: a companion that polls each registered resource
manager's queue via the SAME HTTP surface the bridge uses, scores load, and
picks a target.  Also provides speculative (straggler-mitigation) execution:
launch the same payload on the two least-loaded resources, keep the first
finisher, kill the other.

The scheduler is a pure ``Bridge`` client: it asks the facade for adapter
capabilities (only ``QUEUE_LOAD``-capable targets are schedulable) and
submits/cancels through it — no hand-wired directory/secrets/adapters.

Sharded placement moved the splitting brain here as well: ``plan_slices()``
partitions one array CR's index space across several candidates
(load-proportionally for ``strategy: spread``, by static weight for
``weighted``, single winner for ``single``), and ``LoadProbe`` is the shared
TTL-cached, concurrently-probing queue-load reader both this scheduler and
the operator's slice assignment use — placing a many-candidate spec costs
one parallel probe round, not N serialized HTTP round-trips.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.api import Bridge, JobHandle
from repro.core.backends.base import (Capability, SubmitError,
                                      normalized_queue_load)
from repro.core.resource import (BridgeJob, BridgeJobSpec, DONE,
                                 PlacementSpec, ValidationError)
from repro.core.rest import TransportError


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One schedulable target: where + how to talk to it."""
    resourceURL: str
    image: str           # selects the controller-pod adapter
    resourcesecret: str
    weight: float = 1.0  # strategy=weighted share


class LoadProbe:
    """TTL-cached, concurrent queue-load probing over any adapter source.

    ``connect(resourceURL, image, resourcesecret)`` must return a connected
    adapter or raise; ``query()`` returns the raw queue dict
    ({queued, running, slots}) or None for unreachable / non-QUEUE_LOAD
    targets.  Results are cached for ``ttl`` seconds per target, and
    ``query_all()`` probes the cache misses on parallel threads, so ranking
    N candidates costs one round-trip time, once per TTL window.
    """

    def __init__(self, connect: Callable[[str, str, str], Any],
                 ttl: float = 0.5):
        self.connect = connect
        self.ttl = ttl
        self._cache: Dict[Tuple[str, str, str], Tuple[float, Optional[dict]]] = {}
        self._lock = threading.Lock()

    def invalidate(self) -> None:
        with self._lock:
            self._cache.clear()

    def _probe(self, cand: Candidate) -> Optional[dict]:
        try:
            adapter = self.connect(cand.resourceURL, cand.image,
                                   cand.resourcesecret)
            if adapter is None or not adapter.supports(Capability.QUEUE_LOAD):
                return None
            q = adapter.queue_load()
        except (TransportError, SubmitError, KeyError):
            return None
        if normalized_queue_load(q) is None:
            return None
        return q

    def query(self, cand: Candidate) -> Optional[dict]:
        key = (cand.resourceURL, cand.image, cand.resourcesecret)
        now = time.time()
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None and now - hit[0] < self.ttl:
                return hit[1]
        q = self._probe(cand)
        with self._lock:
            if q is None:
                # a FAILED probe invalidates the entry instead of negative-
                # caching it: the next query re-probes immediately, rather
                # than serving "unreachable" for a full TTL window after the
                # target has already recovered
                self._cache.pop(key, None)
            else:
                self._cache[key] = (time.time(), q)
        return q

    def query_all(self, cands: List[Candidate]) -> List[Optional[dict]]:
        """``query`` for every candidate, cache misses probed concurrently."""
        results: List[Optional[dict]] = [None] * len(cands)
        now = time.time()
        misses: List[int] = []
        with self._lock:
            for i, c in enumerate(cands):
                hit = self._cache.get((c.resourceURL, c.image, c.resourcesecret))
                if hit is not None and now - hit[0] < self.ttl:
                    results[i] = hit[1]
                else:
                    misses.append(i)
        if not misses:
            return results

        def probe(i: int) -> None:
            results[i] = self.query(cands[i])

        threads = [threading.Thread(target=probe, args=(i,), daemon=True)
                   for i in misses[1:]]
        for t in threads:
            t.start()
        probe(misses[0])  # do one on the calling thread
        for t in threads:
            t.join()
        return results


def plan_slices(count: int, candidates: List[Candidate],
                loads: List[Optional[dict]], strategy: str = "spread",
                max_slices: int = 0) -> List[Dict[str, Any]]:
    """Partition ``count`` array indices across ``candidates`` into placement
    slices: ``[{resourceURL, image, resourcesecret, start, count}, ...]``
    with contiguous index ranges covering exactly [0, count).

    ``loads[i]`` is candidate i's raw queue dict (or None when unreachable):

      * ``single``   — one slice on the least-loaded reachable candidate;
      * ``spread``   — shares proportional to free slots
        (max(slots - queued - running, 0); all-full falls back to slot
        counts, no load info at all to an equal split);
      * ``weighted`` — shares proportional to the static ``weight``.

    Unreachable candidates are dropped unless NOTHING is reachable (then the
    split proceeds optimistically over all of them — submission failures
    surface through the normal retry path).  Zero-share candidates are
    dropped; ``max_slices`` (0 = no cap) keeps the highest-share ones.
    """
    if count < 1:
        raise ValidationError("plan_slices needs count >= 1")
    if not candidates:
        raise ValidationError("plan_slices needs at least one candidate")
    pool = list(zip(candidates, loads))
    reachable = [(c, q) for c, q in pool if q is not None]
    if reachable:
        pool = reachable

    if strategy == "single":
        best = min(pool,
                   key=lambda cq: normalized_queue_load(cq[1]) or 0.0)[0]
        return [{"resourceURL": best.resourceURL, "image": best.image,
                 "resourcesecret": best.resourcesecret,
                 "start": 0, "count": count}]

    if strategy == "weighted":
        shares = [c.weight for c, _ in pool]
    else:  # spread: proportional to free slots
        shares = [max(q["slots"] - q["queued"] - q["running"], 0) if q else 0
                  for _, q in pool]
        if not any(shares):
            shares = [q["slots"] if q else 0 for _, q in pool]
        if not any(shares):
            shares = [1.0] * len(pool)  # no load info anywhere: equal split

    ranked = sorted(range(len(pool)), key=lambda i: -shares[i])
    if max_slices > 0:
        ranked = ranked[:max_slices]
    ranked = [i for i in ranked if shares[i] > 0] or ranked[:1]
    # largest-remainder apportionment of `count` over the kept candidates
    total = sum(shares[i] for i in ranked) or 1.0
    quotas = [(i, count * shares[i] / total) for i in ranked]
    counts = {i: int(q) for i, q in quotas}
    leftover = count - sum(counts.values())
    for i, _ in sorted(quotas, key=lambda iq: -(iq[1] - int(iq[1]))):
        if leftover <= 0:
            break
        counts[i] += 1
        leftover -= 1
    plan, start = [], 0
    for i in ranked:
        n = counts[i]
        if n <= 0:
            continue
        c = pool[i][0]
        plan.append({"resourceURL": c.resourceURL, "image": c.image,
                     "resourcesecret": c.resourcesecret,
                     "start": start, "count": n})
        start += n
    return plan


def plan_failover(count: int, candidates: List[Candidate],
                  probe: LoadProbe, strategy: str = "spread",
                  max_slices: int = 0,
                  exclude_urls: "Optional[set]" = None) -> List[Dict[str, Any]]:
    """Re-plan ``count`` evacuated indices over the candidates that are
    healthy RIGHT NOW.  Unlike initial placement this is never optimistic:
    candidates whose endpoint is lost (``exclude_urls``) or whose probe
    fails are dropped outright, and an empty list means "nowhere to go" —
    the caller keeps the CR UNKNOWN rather than resubmitting into a black
    hole."""
    exclude = exclude_urls or set()
    cands = [c for c in candidates if c.resourceURL not in exclude]
    if not cands:
        return []
    loads = probe.query_all(cands)
    healthy = [(c, q) for c, q in zip(cands, loads) if q is not None]
    if not healthy:
        return []
    return plan_slices(count, [c for c, _ in healthy],
                       [q for _, q in healthy], strategy, max_slices)


def plan_placement(count: int, placement: PlacementSpec,
                   probe: LoadProbe) -> List[Dict[str, Any]]:
    """``plan_slices`` for a ``spec.placement`` block: probe every candidate
    (concurrently, through the TTL cache) and split the index space."""
    cands = [Candidate(c.resourceURL, c.image, c.resourcesecret, c.weight)
             for c in placement.candidates]
    return plan_slices(count, cands, probe.query_all(cands),
                       placement.strategy, placement.max_slices)


class LoadAwareScheduler:
    def __init__(self, bridge: Bridge, candidates: List[Candidate],
                 load_ttl: float = 0.5):
        self.bridge = bridge
        self.candidates = list(candidates)
        self.probe = LoadProbe(bridge.connect_adapter, ttl=load_ttl)

    def load_of(self, cand: Candidate) -> Optional[float]:
        """Normalized load: (queued + running) / slots.  None if the backend
        does not advertise QUEUE_LOAD or is unreachable."""
        return normalized_queue_load(self.probe.query(cand))

    def rank(self) -> List[Tuple[float, Candidate]]:
        """Candidates by ascending load — one concurrent probe round (TTL-
        cached), not N serialized HTTP round-trips."""
        scored = []
        for c, q in zip(self.candidates, self.probe.query_all(self.candidates)):
            load = normalized_queue_load(q)
            if load is not None:
                scored.append((load, c))
        scored.sort(key=lambda t: t[0])
        return scored

    def pick(self) -> Candidate:
        ranked = self.rank()
        if not ranked:
            raise RuntimeError("no reachable candidate resource")
        return ranked[0][1]

    def place(self, spec: BridgeJobSpec) -> BridgeJobSpec:
        """Rewrite a spec to target the least-loaded candidate."""
        best = self.pick()
        return dataclasses.replace(spec, resourceURL=best.resourceURL,
                                   image=best.image,
                                   resourcesecret=best.resourcesecret)

    def submit_placed(self, name: str, spec: BridgeJobSpec,
                      namespace: str = "default") -> JobHandle:
        """Place + submit in one step through the facade."""
        return self.bridge.submit(name, self.place(spec), namespace=namespace)

    def scale_placed(self, name: str, count: int,
                     namespace: str = "default") -> JobHandle:
        """Elastic scale with placement re-consulted: growth onto a
        single-resource CR is refused when its target no longer advertises
        queue load — unreachable, or not a QUEUE_LOAD candidate — instead of
        piling more indices onto a black hole.  Scale-down always proceeds,
        and a SLICED job (spec.placement) delegates routing to the
        reconciler, which sends the delta to its least-loaded slice.
        """
        job = self.bridge.registry.get(name, namespace)
        if job is None:
            raise KeyError(f"BridgeJob {namespace}/{name} not found")
        current = job.spec.array.count if job.spec.array else 1
        sliced = bool(job.spec.placement and job.spec.placement.candidates)
        if count > current and not sliced:
            cand = next((c for c in self.candidates
                         if c.resourceURL == job.spec.resourceURL), None)
            # a safety check, not an optimisation: bypass the TTL cache so
            # "re-consulted" means the target is reachable NOW
            self.probe.invalidate()
            if cand is None or self.load_of(cand) is None:
                raise RuntimeError(
                    f"cannot scale up {namespace}/{name}: target "
                    f"{job.spec.resourceURL!r} is not schedulable")
        return self.bridge.scale(name, count, namespace=namespace)

    # -- speculative execution (straggler mitigation) ------------------------

    def submit_speculative(self, base_name: str, spec: BridgeJobSpec,
                           n: int = 2, namespace: str = "default",
                           timeout: float = 60.0) -> BridgeJob:
        """Run the payload on the ``n`` least-loaded resources; return the
        first DONE job and kill the rest.  Raises if all replicas fail."""
        ranked = self.rank()
        if not ranked:
            raise RuntimeError("no reachable candidate resource")
        handles: List[JobHandle] = []
        for i, (_, cand) in enumerate(ranked[:n]):
            s = dataclasses.replace(spec, resourceURL=cand.resourceURL,
                                    image=cand.image,
                                    resourcesecret=cand.resourcesecret)
            handles.append(self.bridge.submit(f"{base_name}-spec{i}", s,
                                              namespace=namespace))
        deadline = time.time() + timeout
        winner: Optional[BridgeJob] = None
        while time.time() < deadline and winner is None:
            jobs = [h.job() for h in handles]
            for job in jobs:
                if job and job.status.state == DONE:
                    winner = job
                    break
            if all(j and j.status.terminal() and j.status.state != DONE
                   for j in jobs):
                raise RuntimeError(
                    f"all speculative replicas failed: "
                    f"{[(j.name, j.status.state) for j in jobs]}")
            time.sleep(0.01)
        if winner is None:
            raise TimeoutError("speculative execution timed out")
        for h in handles:  # kill the stragglers
            if h.name != winner.name:
                job = h.job()
                if job and not job.status.terminal():
                    h.cancel()
        return winner
