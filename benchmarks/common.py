"""Shared benchmark scaffolding.

Every benchmark entry point used to carry its own copy of the same three
pieces of boilerplate: an argparse block with a ``--smoke`` switch, an
if/else ladder picking full-vs-smoke scenario sizes, and a hand-rolled
percentile expression.  They live here now — one definition each — so a new
scenario adds a line of config, not another parallel ladder.
"""
from __future__ import annotations

import argparse
from typing import Any, List, Optional


def make_parser(description: str) -> argparse.ArgumentParser:
    """The argument surface every benchmark shares (``--smoke``)."""
    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--smoke", action="store_true",
                    help="small, fast variant for CI (same schema)")
    return ap


def pick(smoke: bool, full: Any, small: Any) -> Any:
    """THE smoke-vs-full size switch: ``small`` under ``--smoke``, ``full``
    otherwise.  Scenario configs call this once per knob instead of
    maintaining parallel if/else blocks."""
    return small if smoke else full


def percentile(sorted_samples: List[float], q: float) -> Optional[float]:
    """The ``q``-quantile of an ascending sample list (None when empty) —
    the one definition every latency/staleness report indexes with."""
    if not sorted_samples:
        return None
    return sorted_samples[min(int(len(sorted_samples) * q),
                              len(sorted_samples) - 1)]
