"""ControllerPod — the paper's "workhorse" (Figs. 2-3).

One pod per remote job.  The pod:
  1. reads execution data from the associated config map,
  2. mounts secrets, connects to the remote resource manager over the
     HTTP/HTTPS API (the ONLY channel to the external system),
  3. fetches the job script (inline / s3 / remote) and stages extra data,
  4. submits IF AND ONLY IF the config map holds no job id — a restarted pod
     finds the id and resumes monitoring instead of resubmitting (paper §5.1),
  5. runs the monitor loop: poll status, mirror it into the config map,
     honour the kill flag, tolerate transient network failures (UNKNOWN
     after ``unknown_after`` consecutive failures — never invent a terminal
     state),
  6. on completion downloads outputs and uploads them to S3, then exits
     0 (COMPLETED) / 1 (FAILED or CANCELLED), exactly like Fig. 3.

Pod death is simulated by ``kill_pod()``: the thread aborts at the next
action boundary WITHOUT flushing anything — only config-map state survives,
which is precisely the failure mode the paper's design addresses.

The protocol itself lives in ``JobProtocol`` so it has two drivers: this
thread-per-CR pod (the paper-faithful shape) and the multiplexed
``MonitorRuntime`` (core/monitor.py), where a small fixed worker pool steps
many jobs' state machines off a poll-deadline heap.  ``JobProtocol.tick()``
is ONE iteration of the Fig.-3 monitor loop; the driver owns the inter-tick
wait.

Sharded placement generalized the protocol from "one adapter, one remote
id-set" to an INDEXED SLICE MAP: a sliced array CR (``spec.placement``)
partitions its index space across several ``PlacementSlice``s, each with its
own endpoint/adapter/secret, its own per-slice config-map keys
(``slice_{k}_id``, ``slice_{k}_results_location_{i}``), and its own
independently-polled status.  Elastic reconcile diffs desired-vs-submitted
PER SLICE: scale-up routes the delta to the least-loaded slice, scale-down
still condemns the globally-highest indices first.  A single-resource CR is
the one-slice degenerate case and keeps today's config-map shape
byte-for-byte.  ``tick(slice_k)`` polls just that slice (the multiplexed
runtime schedules one chain per slice so a slow resource cannot stall a
healthy slice's ticks — the remote round-trip happens OUTSIDE the protocol's
state lock); ``tick()`` polls every slice sequentially (the paper-faithful
pod shape).

Two per-tick I/O optimisations live here as well:

  * batched status — adapters declaring ``Capability.BATCH_STATUS`` are
    polled with one ``status_batch()`` request per ``BATCH_STATUS_CHUNK``
    ids instead of one request per index (with per-id fallback otherwise);
  * write-coalescing — the monitor diffs its computed updates against the
    last-written snapshot, so a steady-state RUNNING tick performs zero
    config-map writes (the state store additionally skips flushes for
    value-identical updates).
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Set, Tuple, Type

from repro.core.backends import base as B
from repro.core.objectstore import NoSuchKey, ObjectStore
from repro.core.resource import (DONE, FAILED, KILLED, LOST, RUNNING,
                                 SUBMITTED, UNKNOWN)
from repro.core.rest import ResourceManagerDirectory, TransportError
from repro.core.secrets import SecretStore
from repro.core.statestore import ConfigMap, StateStore, slice_key

# backend canonical -> bridge state
_CANON_TO_BRIDGE = {
    B.QUEUED: SUBMITTED,
    B.RUNNING: RUNNING,
    B.COMPLETED: DONE,
    B.FAILED: FAILED,
    B.CANCELLED: KILLED,
}
# bridge -> backend canonical (restart path: re-seed last-known infos for
# terminal indices kept on a LOST slice, whose endpoint can never re-answer)
_BRIDGE_TO_CANON = {v: k for k, v in _CANON_TO_BRIDGE.items()}


class PodKilled(BaseException):
    """Out-of-band pod termination (node failure / eviction)."""


@dataclass
class TickObs:
    """What one monitor tick observed — the cadence hint the protocol hands
    outward to its driver (``JobProtocol.observation(chain)``), consumed by
    the per-chain ``Cadence`` policy (core/monitor.py) to pick the next
    poll deadline."""
    changed: bool = False   # some index's state (or the id set) moved
    busy: bool = False      # a transition is expected soon: indices still
                            # queued, a mixed done/running tail, an elastic
                            # reconcile/drain in flight, or a kill
    unknown: bool = False   # the ticked slice(s) were unreachable
    skipped: bool = False   # watch: events version proved nothing changed,
                            # the status request was skipped entirely


def killable_sleep(killed: threading.Event, name: str, seconds: float,
                   min_sleep: float = 0.005) -> None:
    """Checkpointed, interruptible wait shared by both protocol drivers
    (ControllerPod thread, MonitorTask worker): raises PodKilled mid-wait so
    kills take effect at ``min_sleep`` granularity."""
    deadline = time.time() + seconds
    while time.time() < deadline:
        if killed.is_set():
            raise PodKilled(name)
        time.sleep(min(min_sleep, max(deadline - time.time(), 0)))


def _encode_pairs(pairs: List[List[Any]]) -> str:
    """Serialize a slice's (global index, remote id) pairs: "0=1000,5=1003"."""
    return ",".join(f"{i}={jid}" for i, jid in pairs)


def _decode_pairs(text: str) -> List[List[Any]]:
    out: List[List[Any]] = []
    for tok in text.split(","):
        if tok:
            i, _, jid = tok.partition("=")
            out.append([int(i), jid])
    return out


class PlacementSlice:
    """One placement slice of a (possibly sharded) array CR: its own
    endpoint + adapter + secret, the contiguous index range it was PLANNED
    to own, and the (global index, remote id) pairs it currently runs."""

    __slots__ = ("k", "url", "image", "secret", "adapter", "plan_start",
                 "plan_count", "pairs", "failures", "last_error",
                 "events_seen", "lost", "outage_start", "migrated_to")

    def __init__(self, k: int, url: str, image: str, secret: str,
                 adapter: B.ResourceAdapter, plan_start: int = 0,
                 plan_count: int = 0):
        self.k = k
        self.url = url
        self.image = image
        self.secret = secret
        self.adapter = adapter
        self.plan_start = plan_start
        self.plan_count = plan_count
        # [global index, remote id] in submit order (a slice's indices need
        # not stay contiguous once rebalancing routes growth here)
        self.pairs: List[List[Any]] = []
        # consecutive unreachable polls (per-slice UNKNOWN accounting)
        self.failures = 0
        self.last_error = ""
        # highest remote events version this slice's statuses are known
        # current for (-1 until the first real poll): the watch fast path
        # skips the status request while the version has not moved past it
        self.events_seen = -1
        # slice failover: a LOST slice's resource failed its policy; its
        # unfinished indices were evacuated and it is never polled again
        # (it keeps its terminal pairs so completed results survive)
        self.lost = False
        # wall time the current unreachable streak began (0 = reachable)
        self.outage_start = 0.0
        # where the evacuated indices went (status.placements observability)
        self.migrated_to = ""

    def indices(self) -> List[int]:
        return sorted(p[0] for p in self.pairs)


class JobProtocol:
    """The Figs. 2-3 bridge protocol for ONE BridgeJob, structured as
    ``start()`` (connect + submit-if-no-id) plus repeated ``tick()`` calls
    (one monitor iteration each) so any driver can own the pacing.

    ``checkpoint`` is called at every action boundary and must raise
    ``PodKilled`` when the driver wants the protocol to die unflushed;
    ``sleep`` is the (checkpointed, interruptible) wait used for retry
    backoff inside a step.

    All shared slice/id/condemned state is guarded by ``self._mu``.  The
    two per-tick bulk remote operations — the status round-trip and the
    scale-up fan-out — run OUTSIDE the lock, so concurrent per-slice ticks
    (multiplexed mode) never wait out a slow resource's polling or growth
    latency.  Rarer per-index actions (retry resubmission with its
    configured backoff, condemned-drain cancels, completion-time output
    downloads) do run under the lock: they briefly serialize the job's
    slices, bounded by one request (or one ``retry.backoff_seconds``) at a
    time — size ``monitor_workers``/backoff accordingly, as with the
    in-step waits documented in core/monitor.py.
    """

    # benchmark-baseline switch, PROCESS-WIDE: False restores the
    # pre-optimisation write-every-tick monitor (pair with
    # StateStore(coalesce=False)).  Not production config — flip it only in
    # single-environment measurement code, saving/restoring the prior value.
    COALESCE_WRITES = True

    def __init__(self, name: str, configmap: ConfigMap, secrets: SecretStore,
                 objectstore: ObjectStore, directory: ResourceManagerDirectory,
                 adapters: Mapping[str, Type[B.ResourceAdapter]],
                 checkpoint: Callable[[], None],
                 sleep: Callable[[float], None],
                 min_sleep: float = 0.005):
        self.name = name
        self.cm = configmap
        self.secrets = secrets
        self.s3 = objectstore
        self.directory = directory
        self.adapters = dict(adapters)
        self.min_sleep = min_sleep
        self._checkpoint = checkpoint
        self._sleep = sleep
        self.exit_code: Optional[int] = None
        self.poll: float = 0.0
        # monitor state (populated by start(), survives across ticks)
        self._mu = threading.RLock()
        # serializes elastic scale-ups across per-slice ticks so the growth
        # fan-out (remote HTTP) can run OUTSIDE _mu without two chains
        # submitting the same index
        self._scale_lock = threading.Lock()
        self._slices: List[PlacementSlice] = []
        self._sliced = False
        self._unknown_after = 5
        self._retry_limit = 0
        self._backoff = 0.0
        self._attempts: Dict[str, int] = {}
        # last-known remote info per live global index
        self._infos: Dict[int, Dict[str, Any]] = {}
        # jids a cancel has been delivered for (kill signal OR scale-down)
        self._cancel_sent: Set[str] = set()
        # jids condemned by an elastic scale-down: always the globally-
        # HIGHEST index suffix; they stay tracked (and polled) until
        # terminal, then drop off the tail together with the per-index
        # config-map keys they owned
        self._condemned: Set[str] = set()
        # last monitor-written snapshot, for write-coalescing
        self._last_pushed: Dict[str, str] = {}
        # event-driven control plane: cadence mode from the cm ("fixed" |
        # "adaptive" | "watch" | "wakeup"), last tick observation per chain
        # (the driver's cadence hint), and how many status requests the
        # watch fast path has skipped (observability + tests)
        self.cadence_mode = "fixed"
        self._watch_enabled = False
        # wakeup mode: the watcher pushes id-level event payloads; ticks
        # merge non-terminal transitions into the cached infos and poll only
        # ids with terminal (or unenumerable) events
        self.wakeup_enabled = False
        # watcher-delivered payloads per chain, consumed by the chain's next
        # tick: chain -> [version, events-or-None]; deliveries coalesce
        self._event_buf: Dict[Optional[int], List[Any]] = {}
        # ids covered by each chain's last handed-out watcher registration:
        # a buffered payload is only trusted when it covers every live pair
        # (subscription lag after a scale-up falls back to a filtered fetch)
        self._watch_reg_ids: Dict[int, Set[str]] = {}
        # chains whose registration just changed (fresh submit, retry,
        # failover): their next safety-net tick must fetch events once —
        # transitions that fired BEFORE the new subscription existed are
        # nobody's push duty, and an instant-terminal job would otherwise
        # wedge.  Cleared by the first successful fetch.
        self._watch_catchup: Set[int] = set()
        self.watch_skips = 0
        self._obs: Dict[Optional[int], TickObs] = {}
        self._prev_states: Dict[Optional[int], Dict[int, str]] = {}
        # lazily-built LoadProbe over this job's own slices (scale-up routing)
        self._slice_probe = None
        # slice failover (spec.placement.failover): threshold 0 == disabled;
        # candidates are the full placement pool the evacuation re-plans over
        self._failover_threshold = 0
        self._failover_grace = 0.0
        self._fo_candidates: List[Dict[str, Any]] = []
        self._fo_strategy = "spread"
        self._fo_probe = None
        # serializes evacuations (and the orphan reaper) the way _scale_lock
        # serializes growth: the migration fan-out runs OUTSIDE _mu
        self._failover_lock = threading.Lock()
        # remote jobs left behind on a LOST slice: cancelled best-effort by
        # the reaper once (if) the endpoint answers again, so a resource that
        # recovers mid-evacuation never double-runs an index
        self._orphans: List[Dict[str, Any]] = []
        self._orphan_next = 0.0

    # -- indexed slice map -------------------------------------------------

    def slice_count(self) -> int:
        with self._mu:
            return max(len(self._slices), 1)

    def _index_map(self) -> Dict[int, Tuple[PlacementSlice, str]]:
        """Global index -> (owning slice, remote id)."""
        return {p[0]: (sl, p[1]) for sl in self._slices for p in sl.pairs}

    def _global_ids(self) -> List[str]:
        """Remote ids ordered by global index (the legacy ``id`` mirror)."""
        imap = self._index_map()
        return [imap[i][1] for i in sorted(imap)]

    def _results_key(self, sl: PlacementSlice, idx: int, is_array: bool) -> str:
        if self._sliced:
            return slice_key(sl.k, f"results_location_{idx}")
        return f"results_location_{idx}" if is_array else "results_location"

    def _flush_ids(self, sl: Optional[PlacementSlice] = None) -> None:
        """Persist the id map: the touched slice's own key plus the global
        ``id`` mirror (single-slice jobs write ONLY the legacy ``id`` key,
        keeping today's config-map shape byte-for-byte)."""
        updates = {"id": ",".join(self._global_ids())}
        if self._sliced:
            for s in (self._slices if sl is None else [sl]):
                updates[slice_key(s.k, "id")] = _encode_pairs(s.pairs)
        if self.wakeup_enabled:
            # a freshly-accepted submission is QUEUED by definition: seed the
            # status cache so the first wakeup-mode tick can ride event
            # payloads instead of paying a submit-stamp status poll
            for s in (self._slices if sl is None else [sl]):
                for idx, _jid in s.pairs:
                    self._infos.setdefault(idx, {"state": B.QUEUED})
        self._push(updates)

    # -- paper Fig. 2: main ----------------------------------------------

    def start(self) -> bool:
        """Connect every slice and ensure the remote job(s) exist.  Returns
        False when the protocol already exited (submission failed or was
        killed — ``exit_code`` is set); True when monitoring should begin."""
        cm_data = self.cm.data
        self.poll = float(cm_data.get("updateinterval", "20"))
        # absent key == "fixed": legacy config maps keep today's byte shape
        # and today's fixed-interval monitor behaviour
        self.cadence_mode = cm_data.get("cadence", "fixed")
        self._watch_enabled = self.cadence_mode in ("watch", "wakeup")
        self.wakeup_enabled = self.cadence_mode == "wakeup"
        self._unknown_after = int(cm_data.get("unknown_after", "5"))
        self._retry_limit = int(cm_data.get("retry_limit", "0") or 0)
        self._backoff = float(cm_data.get("retry_backoff", "0") or 0)
        # per-index resubmission counts survive pod restarts via the cm
        self._attempts = {
            k: int(v) for k, v in
            json.loads(cm_data.get("retry_attempts", "{}") or "{}").items()}
        # slice failover policy (absent keys == disabled: legacy cms keep
        # today's byte shape and today's pin-UNKNOWN-forever behaviour)
        self._failover_threshold = int(
            cm_data.get("failover_threshold", "0") or 0)
        self._failover_grace = float(cm_data.get("failover_grace", "0") or 0)
        self._fo_candidates = json.loads(cm_data.get("candidates", "") or "[]")
        self._fo_strategy = cm_data.get("placement_strategy", "spread")
        self._orphans = json.loads(cm_data.get("orphans", "") or "[]")

        # v1beta1 job arrays: the config map carries the fan-out count; a
        # single v1alpha1 job is the count=1 degenerate case of the same path
        count = max(int(cm_data.get("array_count", "1") or "1"), 1)
        # sharded placement: the scheduler's slice plan, if any; otherwise
        # ONE implicit slice built from the legacy target keys
        defs = json.loads(cm_data.get("slices", "") or "null")
        self._sliced = bool(defs)
        if not defs:
            defs = [{"resourceURL": cm_data["resourceURL"],
                     "image": cm_data["image"],
                     "resourcesecret": cm_data["resourcesecret"],
                     "start": 0, "count": count}]
        slices = []
        for k, d in enumerate(defs):
            # credentials from the mounted secret (never from the spec/cm)
            secret = self.secrets.mount(d["resourcesecret"])
            client = self.directory.connect(d["resourceURL"],
                                            secret.get("token", ""))
            adapter = B.resolve_adapter(self.adapters, d["image"])(client)
            sl = PlacementSlice(k, d["resourceURL"], d["image"],
                                d["resourcesecret"], adapter,
                                int(d.get("start", 0)), int(d.get("count", 0)))
            sl.lost = bool(d.get("lost"))
            sl.migrated_to = d.get("migratedTo", "")
            if self._sliced:
                sl.pairs = _decode_pairs(cm_data.get(slice_key(k, "id"), ""))
            else:
                sl.pairs = [[i, s] for i, s in enumerate(
                    s for s in cm_data.get("id", "").split(",") if s)]
            slices.append(sl)
        with self._mu:
            self._slices = slices
            # the condemned set survives pod death via the config map: a
            # replacement pod must keep draining (and keep blocking growth
            # past) a half-cancelled tail, even when a NEWER scale-up patch
            # already raised the desired count again — otherwise the orphan
            # cancels poke permanent KILLED holes into the live index set
            tracked = {p[1] for sl in slices for p in sl.pairs}
            self._condemned = {t for t in
                               cm_data.get("condemned", "").split(",")
                               if t and t in tracked}
            # a LOST slice keeps its terminal pairs (completed results
            # survive the migration) but its endpoint will never answer a
            # poll again — re-seed their last-known states from the cm so
            # the aggregate can still finish after a pod restart
            idx_states = json.loads(cm_data.get("index_states", "") or "{}")
            for sl in slices:
                if not sl.lost:
                    continue
                for idx, _jid in sl.pairs:
                    st = idx_states.get(str(idx))
                    if st in _BRIDGE_TO_CANON:
                        self._infos[idx] = {"state": _BRIDGE_TO_CANON[st]}
            missing = [i for i in range(count) if i not in self._index_map()]
        if missing:
            if not self._submit_initial(cm_data, count, missing):
                return False  # FAILED already recorded; Fig. 2 klog.Exit path
        else:
            # paper: "Job has ID in ConfigMap. Handling state."
            pass
        return True

    def _planned_slice(self, idx: int) -> PlacementSlice:
        """The slice whose planned contiguous range owns global ``idx``;
        indices beyond every plan (post-plan growth) — and indices whose
        planned slice is LOST (resuming an interrupted evacuation) — go to
        the least-populated surviving slice."""
        alive = [sl for sl in self._slices if not sl.lost] or self._slices
        for sl in alive:
            if sl.plan_start <= idx < sl.plan_start + sl.plan_count:
                return sl
        return min(alive, key=lambda sl: (len(sl.pairs), sl.k))

    def _index_params(self, cm_data: Dict[str, str], index: int,
                      count: int) -> Dict[str, str]:
        """Per-index job params: base jobparams overlaid with the array's
        indexed_params[i], plus the injected BRIDGE_ARRAY_INDEX."""
        params = json.loads(cm_data.get("jobparams", "{}"))
        indexed = json.loads(cm_data.get("indexed_params", "[]") or "[]")
        if index < len(indexed):
            params.update(indexed[index])
        if count > 1:
            params.setdefault("BRIDGE_ARRAY_INDEX", str(index))
        return params

    def _submit_initial(self, cm_data: Dict[str, str], count: int,
                        missing: List[int]) -> bool:
        """Fig. 2 submission: route every missing index to its planned
        slice, natively (one ``submit_array`` call per fresh slice) where
        the dialect allows, facade fan-out otherwise.  Returns False when
        the protocol exited (killed / submit budget exhausted)."""
        self._checkpoint()
        retry_limit = int(cm_data.get("retry_limit", "0") or 0)
        backoff = float(cm_data.get("retry_backoff", "0") or 0)
        # persisted so a restarted pod never re-spends the submit budget
        attempt = int(cm_data.get("submit_attempts", "0") or 0)
        while True:
            if self.cm.get("kill", "false") == "true":
                self._abort_partial()
                self.cm.update({"jobStatus": KILLED,
                                "message": "killed before submission"})
                self._exit(1)
                return False
            try:
                script = self._fetch_script(cm_data)
                properties = json.loads(cm_data.get("jobproperties", "{}"))
                for sl in self._slices:
                    if sl.lost:
                        continue  # dead endpoint: staging would only raise
                    self._stage_additional_data(sl.adapter, cm_data)
                with self._mu:
                    imap = self._index_map()
                    todo_by_slice = []
                    for sl in self._slices:
                        todo = sorted(i for i in missing
                                      if i not in imap
                                      and self._planned_slice(i) is sl)
                        if todo:
                            todo_by_slice.append((sl, todo))
                    for sl, todo in todo_by_slice:
                        if (self.wakeup_enabled and sl.events_seen < 0
                                and sl.adapter.supports(B.Capability.WATCH)):
                            # seed the watermark BEFORE the first submission:
                            # the fresh jobs' own QUEUED bumps land after it
                            # (matching the QUEUED infos _flush_ids seeds), so
                            # the first wakeup tick rides events instead of
                            # paying a submit-stamp status poll.  The memoized
                            # probe only ever lags the true version — lag is
                            # safe (extra events re-derived, never skipped)
                            try:
                                sl.events_seen = \
                                    sl.adapter.events_version_cached(
                                        max(self.poll / 2, 0.001))
                            except (TransportError, B.SubmitError):
                                pass  # watermark stays -1: plain polls
                        contiguous = todo == list(range(todo[0],
                                                        todo[0] + len(todo)))
                        # len(todo) > 1: a slice holding ONE index of a
                        # sharded array is just a job — array dialects
                        # (sbatch --array=i-i) reject degenerate ranges
                        if (count > 1 and len(todo) > 1 and not sl.pairs
                                and contiguous
                                and sl.adapter.supports(
                                    B.Capability.NATIVE_ARRAYS)):
                            # native fan-out: one submission call covers the
                            # slice's whole contiguous range
                            ids = sl.adapter.submit_array(
                                script, properties,
                                [self._index_params(cm_data, i, count)
                                 for i in todo],
                                start_index=todo[0])
                            sl.pairs = [[i, jid]
                                        for i, jid in zip(todo, ids)]
                            self._flush_ids(sl)
                        else:
                            self._fanout_submit(sl, cm_data, todo, count,
                                                script, properties)
                break
            except (B.SubmitError, TransportError, NoSuchKey, KeyError,
                    ValueError) as e:
                attempt += 1
                if attempt > retry_limit:
                    # don't orphan indices already fanned out this CR
                    self._abort_partial()
                    self.cm.update(
                        {"jobStatus": FAILED,
                         "message": f"Failed to submit a job to HPC resource: {e}"})
                    self._exit(1)
                    return False
                self.cm.update({"submit_attempts": str(attempt)})
                self._sleep(backoff or self.min_sleep)
        with self._mu:
            self._flush_ids()
        self.cm.update({"jobStatus": SUBMITTED,
                        "submit_time": str(time.time()), "message": ""})
        return True

    def _fanout_submit(self, sl: PlacementSlice, cm_data: Dict[str, str],
                       todo: List[int], count: int,
                       script: str, properties: Dict[str, str]) -> None:
        """Facade-side fan-out on ONE slice: submit each missing global
        index, flushing the slice's id map after EACH submission so a pod
        killed mid-fan-out (initial, resumed, or mid-scale-up) resumes at
        the next unsubmitted index instead of duplicating a live one.
        Arrays go through resubmit_index so native dialects stamp their
        index marker even on a resumed fan-out."""
        for idx in todo:
            self._checkpoint()
            params = self._index_params(cm_data, idx, count)
            jid = (sl.adapter.resubmit_index(script, properties, params, idx)
                   if count > 1
                   else sl.adapter.submit(script, properties, params))
            sl.pairs.append([idx, jid])
            self._flush_ids(sl)

    def _abort_partial(self) -> None:
        """Best-effort cancel of indices submitted before an aborted fan-out."""
        for sl in self._slices:
            if not sl.pairs or not sl.adapter.supports(B.Capability.CANCEL):
                continue
            for _, jid in sl.pairs:
                try:
                    sl.adapter.cancel(jid)
                except (TransportError, B.SubmitError):
                    pass

    def _fetch_script(self, cm_data: Dict[str, str]) -> str:
        loc = cm_data.get("scriptlocation", "inline")
        script = cm_data.get("jobscript", "")
        if loc == "inline":
            return script
        if loc == "s3":
            bucket, key = ObjectStore.parse_ref(script)
            return self.s3.get_text(bucket, key)
        if loc == "remote":
            return script  # path already on the resource; submit by reference
        raise ValueError(f"scriptlocation {loc!r}")

    def _stage_additional_data(self, adapter: B.ResourceAdapter,
                               cm_data: Dict[str, str]) -> None:
        """Upload extra input files (s3 -> resource) where the API allows.

        The adapter's declared capabilities decide the path — no probing:
        without ``Capability.UPLOAD`` (e.g. slurmrestd) the job script must
        fetch from S3 itself, recorded for observability.
        """
        refs = [r for r in cm_data.get("additionaldata", "").split(",") if r]
        can_upload = adapter.supports(B.Capability.UPLOAD)
        for ref in refs:
            bucket, key = ObjectStore.parse_ref(ref)
            name = key.split("/")[-1]
            if not can_upload:
                self.cm.update({"staging": f"unsupported:{name}"})
                continue
            if not adapter.upload(name, self.s3.get(bucket, key)):
                self.cm.update({"staging": f"failed:{name}"})

    # -- paper Fig. 3: monitor ---------------------------------------------

    def make_cadence(self):
        """The poll-cadence policy this CR's cm asked for, one instance per
        scheduling chain (core/monitor.py owns the classes; imported lazily
        because monitor imports this module at top level).  ``watch`` mode
        keeps the fixed cadence — the transport, not the timer, provides its
        savings — and ``fixed`` remains the default baseline."""
        from repro.core.monitor import (AdaptiveCadence, FixedCadence,
                                        WakeupCadence)
        if self.cadence_mode == "adaptive":
            return AdaptiveCadence(self.poll)
        if self.cadence_mode == "wakeup":
            # pokes carry the urgency; the timer is only the safety net,
            # and it stretches while the push path stays provably healthy
            return WakeupCadence(self.poll)
        return FixedCadence(self.poll)

    def observation(self, chain: Optional[int] = None) -> Optional[TickObs]:
        """What the given chain's most recent tick observed (None before the
        first tick) — the driver feeds this to its ``Cadence``."""
        with self._mu:
            return self._obs.get(chain)

    def _watch_check(self, sl: PlacementSlice, pairs: List[List[Any]],
                     seen: int) -> Tuple[bool, Optional[int]]:
        """Watch fast path: decide whether this slice's status request can
        be skipped because the endpoint's events version proves nothing
        relevant changed since ``seen``.  Returns (skip, advance) where
        ``advance`` is the version to raise ``events_seen`` to (None: keep).

        Two levels: (a) a channel-memo-cached GLOBAL version probe — one
        request per endpoint per half-poll window, amortized across every CR
        on the endpoint, answers the steady state; (b) only when the global
        version moved, one filtered long-poll asking about OUR ids.  A 204
        there proves every event in (seen, probe-version] belonged to other
        CRs (the filtered answer is evaluated later than the probe), so the
        watermark may advance past them.  Any transport failure falls back
        to the plain status poll — watch is an optimisation, never a new
        failure mode."""
        gv = sl.adapter.events_version_cached(max(self.poll / 2, 0.001))
        if gv <= seen:
            return True, None
        v = sl.adapter.watch_events(since=seen,
                                    ids=[jid for _, jid in pairs])
        if v is None:
            return True, gv
        return False, v

    # -- wakeup cadence: watcher pokes + id-filtered polling ----------------

    def deliver_events(self, chain: Optional[int], version: int,
                       events: Optional[List[Tuple[str, str]]]) -> None:
        """Watcher push (wakeup cadence): buffer an event payload for the
        chain's next tick.  Deliveries racing inside one tick window
        coalesce — versions take the max, payloads concatenate, and an
        unknown-scope delivery (events None) poisons the batch so the tick
        re-polls everything it tracks."""
        with self._mu:
            cur = self._event_buf.get(chain)
            if cur is None:
                self._event_buf[chain] = [
                    version, None if events is None else list(events)]
            else:
                cur[0] = max(cur[0], version)
                if events is None or cur[1] is None:
                    cur[1] = None
                else:
                    cur[1].extend(events)

    def _take_events(self, chain: Optional[int]):
        with self._mu:
            return self._event_buf.pop(chain, None)

    def watch_ids(self, chain: Optional[int]):
        """Multiplexed-driver hook (wakeup cadence): the endpoint URL,
        remote ids, and adapter this chain wants watcher pokes for — or None
        when it doesn't participate (non-wakeup cadence, unwatchable
        dialect, LOST slice, nothing submitted yet)."""
        if not self.wakeup_enabled:
            return None
        k = 0 if chain is None else chain
        with self._mu:
            if k >= len(self._slices):
                return None
            sl = self._slices[k]
            if sl.lost or not sl.adapter.supports(B.Capability.WATCH):
                return None
            ids = [jid for _, jid in sl.pairs]
            if not ids:
                return None
            ids_set = set(ids)
            if self._watch_reg_ids.get(k) != ids_set:
                # registration change: the chain owes ONE catch-up fetch
                # for events that predate the new subscription
                self._watch_reg_ids[k] = ids_set
                self._watch_catchup.add(k)
        return sl.url, ids, sl.adapter

    def _wakeup_events(self, sl: PlacementSlice, pairs: List[List[Any]],
                       seen: int):
        """Wakeup fast path: decide, from id-level event payloads, which of
        the slice's ids actually need a status request this tick.  Payloads
        come from the endpoint watcher's delivery buffer when one fired;
        on a plain deadline tick (the safety net) a memoized global probe
        plus one filtered long-poll stand in.  Returns
        (merges, poll_pairs, advance):

          merges      {jid: (idx, canonical state)} — non-terminal
                      transitions folded into the cached infos with ZERO
                      status requests
          poll_pairs  (idx, jid) pairs that need a real status request:
                      terminal events (end_time/exit detail only a poll
                      provides) or events whose scope the ring lost
          advance     events_seen watermark to commit IF the tick's polls
                      succeed (None: keep) — a failed terminal poll must
                      leave the watermark so the event is re-derived

        Raises TransportError/SubmitError like a status poll; the caller
        falls back to the watch/plain path."""
        buffered = self._take_events(sl.k)
        if buffered is not None and buffered[0] <= seen:
            buffered = None  # stale delivery: a poll already covered it
        if buffered is not None:
            # subscription lag: a payload filtered to an OLD registration
            # may omit ids submitted since (scale-up); trust it only when
            # it covers every live pair, else fetch fresh below
            covered = self._watch_reg_ids.get(sl.k)
            if covered is None or any(jid not in covered
                                      for _, jid in pairs):
                buffered = None
        if buffered is None:
            # push-covered safety-net tick: every live id is registered with
            # the endpoint's watcher, no catch-up fetch is owed, and the
            # watcher's heartbeat proves it alive — so any event for this
            # slice WILL arrive as a payload+poke, and this tick may return
            # having spent ZERO requests.  The watermark stays put: only a
            # delivery or a real fetch advances it.  This is what makes the
            # deadline heap O(cheap no-ops) instead of O(event fetches) at
            # 10k CRs — without it, every global version bump makes every
            # chain's safety tick fetch its own filtered event window.
            covered = self._watch_reg_ids.get(sl.k)
            if (covered is not None and sl.k not in self._watch_catchup
                    and all(jid in covered for _, jid in pairs)
                    and sl.adapter.watch_push_healthy(max(2.0, 2 * self.poll))):
                return {}, [], None
            gv = sl.adapter.events_version_cached(max(self.poll / 2, 0.001))
            if gv <= seen:
                self._watch_catchup.discard(sl.k)  # no events at all to miss
                return {}, [], None  # quiescent endpoint: skip everything
            r = sl.adapter.watch_events_ids(
                since=seen, ids=[jid for _, jid in pairs])
            self._watch_catchup.discard(sl.k)  # gap fetched (or proven empty)
            if r is None:
                return {}, [], gv  # every event was another CR's
            version, events = r
        else:
            version, events = buffered
        if events is None:
            # ring overflow / wildcard bump: scope unknown, re-poll all
            return {}, list(pairs), version
        latest: Dict[str, str] = {}
        for jid, state in events:
            latest[jid] = state  # latest-state-wins per id
        jid_to_idx = {jid: idx for idx, jid in pairs}
        merges: Dict[str, Tuple[int, str]] = {}
        poll_pairs: List[List[Any]] = []
        for jid, state in latest.items():
            idx = jid_to_idx.get(jid)
            if idx is None:
                continue  # another CR's (or a superseded) id
            if state in B.TERMINAL:
                poll_pairs.append([idx, jid])
            else:
                merges[jid] = (idx, state)
        return merges, poll_pairs, version

    def _push(self, updates: Dict[str, Any]) -> None:
        """Monitor-side write coalescing: only keys whose value actually
        changed since the last monitor write reach the config map, so a
        steady-state tick costs zero store operations."""
        if not self.COALESCE_WRITES:
            self.cm.update({k: str(v) for k, v in updates.items()})
            return
        changed = {k: str(v) for k, v in updates.items()
                   if self._last_pushed.get(k) != str(v)}
        if changed:
            self.cm.update(changed)
            self._last_pushed.update(changed)

    def _poll_statuses(self, adapter: B.ResourceAdapter,
                       ids: List[str]) -> List[Dict[str, Any]]:
        """One tick's worth of remote status: batched (chunked) when the
        dialect declares BATCH_STATUS, per-id otherwise."""
        if len(ids) > 1 and adapter.supports(B.Capability.BATCH_STATUS):
            infos: List[Dict[str, Any]] = []
            for i in range(0, len(ids), B.BATCH_STATUS_CHUNK):
                infos.extend(
                    adapter.status_batch(ids[i:i + B.BATCH_STATUS_CHUNK]))
            return infos
        return [adapter.status(jid) for jid in ids]

    # -- elastic arrays: spec-patch reconcile (delta submit / cancel) -------

    def _least_loaded_slice(self) -> PlacementSlice:
        """Rebalancing target for scale-up, routed through the shared
        ``LoadProbe`` machinery (core/scheduler.py): the slice whose resource
        reports the lowest normalized queue load (ties broken toward fewer
        owned indices).  The probe's TTL cache is kept to a fraction of the
        poll interval — a failed probe invalidates its entry rather than
        negative-caching it, so an endpoint that just recovered is
        re-considered immediately.  Slices without QUEUE_LOAD — or
        unreachable right now — fall back to an index-count comparison.
        Called WITHOUT _mu held (the probes are remote round-trips); pair
        counts are only a tie-break heuristic.  LOST slices never receive
        growth; a failover may have appended replacement slices, so the
        probe resolves adapters through the live slice list, not a snapshot
        taken at start()."""
        with self._mu:
            alive = [sl for sl in self._slices if not sl.lost]
            if not alive:
                alive = list(self._slices)
        if len(alive) == 1:
            return alive[0]
        from repro.core.scheduler import Candidate, LoadProbe
        if self._slice_probe is None:
            self._slice_probe = LoadProbe(
                self._slice_adapter,
                ttl=min(max(self.poll / 2, 0.0), 0.5))
        cands = [Candidate(sl.url, sl.image, sl.secret) for sl in alive]
        loads = self._slice_probe.query_all(cands)
        with_load = [(B.normalized_queue_load(q), sl)
                     for q, sl in zip(loads, alive)
                     if B.normalized_queue_load(q) is not None]
        if with_load:
            return min(with_load,
                       key=lambda t: (t[0], len(t[1].pairs), t[1].k))[1]
        return min(alive, key=lambda sl: (len(sl.pairs), sl.k))

    def _slice_adapter(self, url: str, image: str,
                       secret: str) -> B.ResourceAdapter:
        """Probe connect hook: the owning slice's already-built adapter."""
        with self._mu:
            for sl in self._slices:
                if (sl.url, sl.image, sl.secret) == (url, image, secret):
                    return sl.adapter
        raise TransportError(f"no slice for {url}")

    def _scale_up(self, sl: PlacementSlice, cm_now: Dict[str, str],
                  desired: int) -> Optional[str]:
        """Submit the missing indices below ``desired`` on slice ``sl`` —
        the top of the range after a plain resize, but arbitrary mid-range
        holes after an interrupted evacuation (this is the self-heal path
        that makes migration convergent).  Each remote submission runs
        OUTSIDE the state lock; the resulting id is committed (pair append +
        incremental flush) under the lock before the next one, and the loop
        revalidates against the live index map every iteration so a racing
        scale-down (condemnation) stops the growth.  A transient error
        leaves the remainder for the next tick; the returned stall
        diagnostic becomes this tick's status message.  Caller holds
        _scale_lock, so at most one chain grows the job."""
        with self._mu:
            imap = self._index_map()
            holes = [i for i in range(desired) if i not in imap]
            idx = holes[0] if holes else desired
        try:
            script = self._fetch_script(cm_now)
            properties = json.loads(cm_now.get("jobproperties", "{}"))
            while True:
                with self._mu:
                    if self._condemned:
                        return None  # a newer patch shrank the job: stop
                    imap = self._index_map()
                    holes = [i for i in range(desired) if i not in imap]
                    if not holes:
                        return None
                    idx = holes[0]
                self._checkpoint()
                params = self._index_params(cm_now, idx, desired)
                jid = (sl.adapter.resubmit_index(script, properties, params,
                                                 idx)
                       if desired > 1
                       else sl.adapter.submit(script, properties, params))
                with self._mu:
                    sl.pairs.append([idx, jid])
                    self._flush_ids(sl)
        except (B.SubmitError, TransportError, NoSuchKey, KeyError,
                ValueError) as e:
            return (f"scale-up to {desired} stalled at index {idx}: {e}")

    def _reconcile_scale(self, cm_now: Dict[str, str],
                         desired: int) -> Optional[str]:
        """Diff desired vs. submitted indices and act on exactly the delta.
        Scale-down condemns the globally-HIGHEST indices first (whichever
        slice owns them); scale-up routes the whole delta to the least-
        loaded slice; growth past a still-draining condemned tail waits
        until the tail is gone (index positions must free up before they
        are reused).  Condemnation is a cheap state change under _mu; the
        growth fan-out (load probes + submissions) runs outside it so a
        slow resource's scale-up never stalls another slice's tick.
        Returns a stall diagnostic when a scale-up could not complete."""
        with self._mu:
            imap = self._index_map()
            n = len(imap)
            n_live = n - len(self._condemned)
            if desired < n_live:
                indices = sorted(imap)
                for idx in indices[desired:n_live]:
                    self._condemned.add(imap[idx][1])
                # persisted so a pod killed mid-drain hands the half-
                # cancelled tail to its replacement instead of orphaning it
                self._push({"condemned": ",".join(sorted(self._condemned))})
                return None
            # growth == any missing index below desired: the top of the
            # range after a resize, mid-range holes after an interrupted
            # slice evacuation (n alone cannot see holes once a failover
            # dropped indices while a condemned tail still pads the count)
            need_growth = (not self._condemned
                           and any(i not in imap for i in range(desired)))
        if not need_growth:
            return None
        if not self._scale_lock.acquire(blocking=False):
            return None  # another chain is already growing this job
        try:
            return self._scale_up(self._least_loaded_slice(), cm_now,
                                  desired)
        finally:
            self._scale_lock.release()

    # -- slice failover: LOST promotion, evacuation, orphan reaping ---------

    def _connect_candidate(self, url: str, image: str,
                           secret_name: str) -> B.ResourceAdapter:
        """Adapter for a placement candidate that may not (yet) own a slice:
        credentials from the mounted secret, dialect from the image."""
        secret = self.secrets.mount(secret_name)
        client = self.directory.connect(url, secret.get("token", ""))
        return B.resolve_adapter(self.adapters, image)(client)

    def _slice_defs(self) -> List[Dict[str, Any]]:
        """The persisted ``slices`` cm value, rebuilt from live state (the
        operator writes the initial plan; the controller owns it afterwards
        so LOST flags and failover-created slices survive pod death)."""
        defs: List[Dict[str, Any]] = []
        for sl in self._slices:
            d: Dict[str, Any] = {
                "resourceURL": sl.url, "image": sl.image,
                "resourcesecret": sl.secret,
                "start": sl.plan_start, "count": sl.plan_count}
            if sl.lost:
                d["lost"] = True
                if sl.migrated_to:
                    d["migratedTo"] = sl.migrated_to
            defs.append(d)
        return defs

    def _failover_due(self) -> List[PlacementSlice]:
        """Slices past the failover policy: threshold consecutive failed
        polls AND grace seconds of wall-clock outage.  Caller holds _mu."""
        if self._failover_threshold <= 0 or not self._fo_candidates:
            return []
        now = time.time()
        return [sl for sl in self._slices
                if not sl.lost
                and sl.failures >= self._failover_threshold
                and sl.outage_start
                and now - sl.outage_start >= self._failover_grace]

    def _attempt_failover(self, cm_now: Dict[str, str],
                          desired: int) -> bool:
        """Non-blocking entry: at most one chain evacuates at a time (a
        second due slice waits for the next tick).  Returns True when at
        least one slice was promoted to LOST this call."""
        if not self._failover_lock.acquire(blocking=False):
            return False
        try:
            return self._do_failover(cm_now, desired)
        finally:
            self._failover_lock.release()

    def _do_failover(self, cm_now: Dict[str, str], desired: int) -> bool:
        """Promote due slices to LOST and migrate their unfinished indices.

        Order matters for the at-most-once-while-live invariant:

        1. Probe the remaining candidates (outside _mu).  If NOTHING else is
           reachable the slice is NOT promoted — the CR stays pinned UNKNOWN
           exactly as with failover disabled (black-box honesty: we only
           declare a resource dead once we can actually act on it).
        2. Under _mu, in one coalesced cm write: mark the slice LOST, strip
           its unfinished pairs, record each stripped remote job in the
           persisted ``orphans`` ledger, keep terminal pairs (completed
           results survive), drop its condemned jids outright (a drain can
           never reach a dead endpoint), and persist the new slice defs.
           After this write a restarted pod sees the holes and finishes the
           migration itself — step 3 is pure optimisation.
        3. Re-plan the evacuated indices over the healthy candidates
           (plan_failover; never optimistic) and resubmit them, one commit
           per index, under _scale_lock so a concurrent elastic scale-up
           cannot double-submit a hole.
        """
        from repro.core.scheduler import Candidate, LoadProbe, plan_failover
        with self._mu:
            due = self._failover_due()
            if not due:
                return False
            dead_urls = ({sl.url for sl in self._slices if sl.lost}
                         | {sl.url for sl in due})
        cands = [Candidate(c["resourceURL"], c["image"], c["resourcesecret"],
                           float(c.get("weight", 1.0)))
                 for c in self._fo_candidates]
        pool = [c for c in cands if c.resourceURL not in dead_urls]
        if self._fo_probe is None:
            self._fo_probe = LoadProbe(
                self._connect_candidate,
                ttl=min(max(self.poll / 2, 0.0), 0.5))
        if not pool or not any(
                q is not None for q in self._fo_probe.query_all(pool)):
            return False  # nowhere to go: stay UNKNOWN, never evacuate

        is_array = ("array_count" in cm_now
                    or len(self._index_map()) > 1)
        with self._mu:
            due = self._failover_due()  # revalidate: a poll may have landed
            if not due:
                return False
            todo: List[int] = []
            pruned: List[str] = []
            for sl in due:
                sl.lost = True
                keep: List[List[Any]] = []
                for idx, jid in sl.pairs:
                    st = (_CANON_TO_BRIDGE[self._infos[idx]["state"]]
                          if idx in self._infos else SUBMITTED)
                    orphan = {"resourceURL": sl.url, "image": sl.image,
                              "resourcesecret": sl.secret, "id": jid}
                    if jid in self._condemned:
                        # the scale-down drain can never reach this endpoint:
                        # drop the index outright, reap the remote best-effort
                        self._condemned.discard(jid)
                        self._cancel_sent.discard(jid)
                        self._infos.pop(idx, None)
                        self._attempts.pop(str(idx), None)
                        pruned.append(self._results_key(sl, idx, is_array))
                        self._orphans.append(orphan)
                        continue
                    if st in (DONE, KILLED) or (
                            st == FAILED
                            and self._attempts.get(str(idx), 0)
                            >= self._retry_limit):
                        keep.append([idx, jid])  # terminal: results survive
                        continue
                    if st == FAILED:
                        # moving a retryable failure is a resubmission:
                        # it spends the same budget the retry path would
                        self._attempts[str(idx)] = \
                            self._attempts.get(str(idx), 0) + 1
                    todo.append(idx)
                    self._infos.pop(idx, None)
                    self._orphans.append(orphan)
                sl.pairs = keep
            updates: Dict[str, Any] = {
                "slices": json.dumps(self._slice_defs()),
                "orphans": json.dumps(self._orphans),
                "id": ",".join(self._global_ids())}
            for s in self._slices:
                updates[slice_key(s.k, "id")] = _encode_pairs(s.pairs)
            if self._retry_limit or "retry_attempts" in cm_now:
                updates["retry_attempts"] = json.dumps(self._attempts)
            if self._condemned:
                updates["condemned"] = ",".join(sorted(self._condemned))
            elif "condemned" in cm_now:
                pruned.append("condemned")
            if pruned:
                self.cm.prune(pruned)
                for k in pruned:
                    self._last_pushed.pop(k, None)
            self._push(updates)

        if not todo:
            return True  # slice marked LOST; nothing unfinished to move
        todo.sort()
        plan = plan_failover(len(todo), cands, self._fo_probe,
                             strategy=self._fo_strategy,
                             exclude_urls=dead_urls)
        if not plan:
            # the pool vanished between probe and plan: the holes are
            # persisted, so _reconcile_scale self-heals them next tick
            return True
        with self._mu:
            targets: List[Tuple[PlacementSlice, List[int]]] = []
            for ent in plan:
                tgt = next(
                    (s for s in self._slices if not s.lost
                     and (s.url, s.image, s.secret)
                     == (ent["resourceURL"], ent["image"],
                         ent["resourcesecret"])), None)
                if tgt is None:
                    tgt = PlacementSlice(
                        len(self._slices), ent["resourceURL"], ent["image"],
                        ent["resourcesecret"],
                        self._connect_candidate(ent["resourceURL"],
                                                ent["image"],
                                                ent["resourcesecret"]))
                    self._slices.append(tgt)
                targets.append(
                    (tgt, todo[ent["start"]:ent["start"] + ent["count"]]))
            for dsl in due:
                dsl.migrated_to = ",".join(
                    sorted({t.url for t, _ in targets}))
            self._push({"slices": json.dumps(self._slice_defs())})
        self._resubmit_evacuated(cm_now, desired, targets)
        return True

    def _resubmit_evacuated(
            self, cm_now: Dict[str, str], desired: int,
            targets: List[Tuple[PlacementSlice, List[int]]]) -> None:
        """Step 3 of _do_failover: the submission fan-out, outside _mu,
        under _scale_lock.  Any index left unsubmitted (transient error,
        lock contention, pod kill) stays a persisted hole that
        _reconcile_scale fills on a later tick."""
        if not self._scale_lock.acquire(blocking=False):
            return  # a concurrent scale-up owns submissions right now
        try:
            script = self._fetch_script(cm_now)
            properties = json.loads(cm_now.get("jobproperties", "{}"))
            arr = desired > 1 or "array_count" in cm_now
            for sl, idxs in targets:
                for idx in idxs:
                    self._checkpoint()
                    with self._mu:
                        if idx in self._index_map():
                            continue  # a racing chain already filled it
                    params = self._index_params(cm_now, idx, desired)
                    try:
                        jid = (sl.adapter.resubmit_index(
                                   script, properties, params, idx)
                               if arr
                               else sl.adapter.submit(script, properties,
                                                      params))
                    except (B.SubmitError, TransportError):
                        continue  # leave the hole for the self-heal path
                    with self._mu:
                        sl.pairs.append([idx, jid])
                        self._flush_ids(sl)
        except (NoSuchKey, KeyError, ValueError):
            pass  # bad script/params surface through the normal paths
        finally:
            self._scale_lock.release()

    def _reap_orphans(self) -> None:
        """Best-effort cancel of remote jobs stranded on LOST slices, so an
        endpoint that recovers mid-evacuation never double-runs an index.
        Throttled to the poll interval; TransportError keeps the orphan in
        the ledger for the next pass."""
        now = time.time()
        with self._mu:
            if not self._orphans or now < self._orphan_next:
                return
            self._orphan_next = now + max(self.poll, self.min_sleep)
            batch = list(self._orphans)
        if not self._failover_lock.acquire(blocking=False):
            return  # an evacuation owns the ledger right now
        try:
            remaining = []
            for o in batch:
                try:
                    adapter = self._connect_candidate(
                        o["resourceURL"], o["image"], o["resourcesecret"])
                    if adapter.supports(B.Capability.CANCEL):
                        adapter.cancel(o["id"])
                except (TransportError, B.SubmitError):
                    remaining.append(o)
            with self._mu:
                self._orphans = remaining
                self._push({"orphans": json.dumps(remaining)})
        finally:
            self._failover_lock.release()

    def chain_retired(self, chain: Optional[int]) -> bool:
        """Multiplexed-driver hook: True when this chain's slice is LOST, so
        the chain leaves the poll heap for good.  Chain 0 never retires — it
        owns the per-tick global duties (cm read, elastic reconcile, kill)
        even when its own slice is gone."""
        if chain is None or chain == 0:
            return False
        with self._mu:
            return chain < len(self._slices) and self._slices[chain].lost

    def _try_cancel(self, adapter: B.ResourceAdapter, jid: str, state: str,
                    can_cancel_queued: bool) -> None:
        """Deliver ONE cancel, capability-gated and at-most-once: skipped for
        terminal/already-cancelled jobs, deferred for queued jobs the dialect
        cannot kill in-queue (wait for RUNNING), retried next poll on a
        transport failure.  Shared by the kill signal and scale-down drain so
        their delivery semantics cannot diverge."""
        if jid in self._cancel_sent or state in (DONE, FAILED, KILLED):
            return
        if state == SUBMITTED and not can_cancel_queued:
            return  # dialect can't kill queued jobs; wait for RUNNING
        try:
            adapter.cancel(jid)
            self._cancel_sent.add(jid)
        except TransportError:
            pass  # retry next poll

    def _drain_condemned(self, cm_now: Dict[str, str],
                         states: Dict[int, str], ticked: Set[int]) -> None:
        """Cancel condemned indices (highest first) respecting each owning
        slice's CANCEL / CANCEL_QUEUED capabilities — cancels go out only on
        the slices this tick polled, so a slow resource's drain never rides
        a healthy slice's tick — then pop the terminal condemned tail,
        GC'ing the per-index config-map keys (retry budget, results
        location) those indices owned."""
        for sl in self._slices:
            if sl.k not in ticked or not sl.adapter.supports(
                    B.Capability.CANCEL):
                continue
            cq = sl.adapter.supports(B.Capability.CANCEL_QUEUED)
            for idx, jid in sorted(sl.pairs, reverse=True):
                if jid in self._condemned:
                    self._try_cancel(sl.adapter, jid,
                                     states.get(idx, SUBMITTED), cq)
        imap = self._index_map()
        indices = sorted(imap)
        is_array = "array_count" in cm_now or len(indices) > 1
        orphaned: List[str] = []
        while indices:
            idx = indices[-1]
            sl, jid = imap[idx]
            if (jid not in self._condemned
                    or states.get(idx) not in (DONE, FAILED, KILLED)):
                break  # condemned jids are the global index suffix
            indices.pop()
            del imap[idx]
            sl.pairs = [p for p in sl.pairs if p[0] != idx]
            self._condemned.discard(jid)
            self._cancel_sent.discard(jid)
            self._infos.pop(idx, None)
            states.pop(idx, None)
            orphaned.append(self._results_key(sl, idx, is_array))
            self._attempts.pop(str(idx), None)
        if orphaned:
            if not self._condemned:
                orphaned.append("condemned")  # drain complete: GC the key
            self.cm.prune(orphaned)
            for k in orphaned:
                self._last_pushed.pop(k, None)
            updates: Dict[str, Any] = {"id": ",".join(self._global_ids())}
            if self._condemned:
                updates["condemned"] = ",".join(sorted(self._condemned))
            if self._sliced:
                for sl in self._slices:
                    updates[slice_key(sl.k, "id")] = _encode_pairs(sl.pairs)
            if self._retry_limit or "retry_attempts" in cm_now:
                updates["retry_attempts"] = json.dumps(self._attempts)
            self._push(updates)

    def _placements_snapshot(self, states: Dict[int, str]) -> List[dict]:
        """Per-slice status for the cm ``placements`` key (mirrored into
        ``status.placements``): which live indices each slice runs, where,
        and the slice-local aggregate state."""
        out = []
        for sl in self._slices:
            idxs = sorted(p[0] for p in sl.pairs
                          if p[1] not in self._condemned)
            sl_states = [states.get(i, SUBMITTED) for i in idxs]
            if not idxs:
                agg = "IDLE"
            elif all(s == DONE for s in sl_states):
                agg = DONE
            elif any(s == FAILED for s in sl_states):
                agg = FAILED
            elif any(s == KILLED for s in sl_states):
                agg = KILLED
            elif any(s == RUNNING for s in sl_states):
                agg = RUNNING
            else:
                agg = SUBMITTED
            ent = {"slice": sl.k, "resourceURL": sl.url,
                   "image": sl.image, "indices": idxs, "state": agg}
            if sl.lost:
                # failover observability: the slice is gone for good; the
                # indices it still lists are the terminal ones whose results
                # it keeps, everything else lives at migratedTo now
                ent["state"] = LOST
                if sl.migrated_to:
                    ent["migratedTo"] = sl.migrated_to
            elif sl.failures:
                # pre-failover degradation, surfaced per slice so clients
                # can see an outage building before the CR goes UNKNOWN
                ent["failures"] = sl.failures
                ent["lastError"] = sl.last_error
                if sl.outage_start:
                    ent["outageSeconds"] = round(
                        time.time() - sl.outage_start, 3)
            out.append(ent)
        return out

    def tick(self, slice_k: Optional[int] = None) -> bool:
        """ONE Fig.-3 monitor iteration.  ``slice_k=None`` polls every slice
        sequentially (the pod-per-CR shape); ``slice_k=k`` polls only that
        slice (the multiplexed runtime runs one chain per slice).  Returns
        True when the protocol finished (``exit_code`` is set); the driver
        waits ``poll`` seconds between calls per slice."""
        cm_now = self.cm.data  # Fig. 3: "Get current config map"
        kill_requested = cm_now.get("kill", "false") == "true"
        desired = max(int(cm_now.get("array_count", "1") or "1"), 1)

        # elastic reconcile: act on a spec patch before polling (a kill
        # supersedes any pending resize — never grow a job being killed).
        # _reconcile_scale does its own locking: condemnation under _mu,
        # growth HTTP outside it behind _scale_lock
        stall_msg = None
        if not kill_requested:
            stall_msg = self._reconcile_scale(cm_now, desired)

        with self._mu:
            all_targets = (self._slices if slice_k is None
                           else [self._slices[slice_k]])
            # LOST slices left the poll set for good: their endpoint already
            # failed the failover policy and their live indices moved away
            targets = [sl for sl in all_targets if not sl.lost]
            # watch eligibility is judged under the lock: the fast path may
            # stand in for a status poll ONLY when the slice is quiescent
            # (no kill, no drain, no stalled growth, nothing mid-retry) and
            # every live index already has a last-known info to reuse
            snapshot = []
            for sl in targets:
                pairs = [list(p) for p in sl.pairs]
                watchable = (self._watch_enabled and bool(pairs)
                             and not kill_requested and not self._condemned
                             and stall_msg is None
                             and sl.adapter.supports(B.Capability.WATCH)
                             and all(p[0] in self._infos for p in pairs))
                snapshot.append((sl, pairs, watchable, sl.events_seen))

        # the remote round-trip happens OUTSIDE the state lock: a slow
        # resource must not stall another slice's tick.  ``infos is None``
        # marks a watch-skipped slice: its last-known infos are provably
        # current, so evaluation proceeds on them without a status request.
        polled, failed = [], []
        skipped = False
        for sl, pairs, watchable, seen in snapshot:
            if not pairs:
                polled.append((sl, pairs, [], None, None))
                continue
            advance = None
            if watchable and self.wakeup_enabled:
                # wakeup fast path: event payloads name WHICH ids moved, so
                # the status request shrinks to the touched subset (terminal
                # transitions only — non-terminal ones merge request-free)
                try:
                    merges, poll_pairs, advance = self._wakeup_events(
                        sl, pairs, seen)
                except (TransportError, B.SubmitError):
                    merges = None  # transport trouble: watch/plain below
                if merges is not None:
                    if not poll_pairs:
                        polled.append((sl, pairs, None, advance, merges))
                        skipped = True
                    else:
                        try:
                            infos = self._poll_statuses(
                                sl.adapter, [jid for _, jid in poll_pairs])
                            polled.append(
                                (sl, poll_pairs, infos, advance, merges))
                        except (TransportError, B.SubmitError) as e:
                            # advance is NOT committed: the terminal event
                            # must be re-derived once the endpoint answers
                            failed.append((sl, e))
                    continue
            if watchable:
                try:
                    skip, advance = self._watch_check(sl, pairs, seen)
                except (TransportError, B.SubmitError):
                    skip = None  # fall through to the plain status poll
                if skip:
                    polled.append((sl, pairs, None, advance, None))
                    skipped = True
                    continue
            try:
                infos = self._poll_statuses(sl.adapter,
                                            [jid for _, jid in pairs])
                polled.append((sl, pairs, infos, advance, None))
            except (TransportError, B.SubmitError) as e:
                failed.append((sl, e))

        with self._mu:
            imap = self._index_map()
            for sl, pairs, infos, advance, merges in polled:
                sl.failures = 0
                sl.last_error = ""
                sl.outage_start = 0.0
                if advance is not None:
                    sl.events_seen = max(sl.events_seen, advance)
                if merges:
                    # fold non-terminal event transitions into a COPY of the
                    # cached info (start_time etc. survive); a cached
                    # terminal state always outranks a late event replay
                    for jid, (idx, state) in merges.items():
                        cur = imap.get(idx)
                        if cur is None or cur[1] != jid:
                            continue
                        info = dict(self._infos.get(idx) or {})
                        if info.get("state") in B.TERMINAL:
                            continue
                        info["state"] = state
                        self._infos[idx] = info
                if infos is None:
                    self.watch_skips += 1
                    continue
                for (idx, jid), info in zip(pairs, infos):
                    cur = imap.get(idx)
                    if cur is not None and cur[1] == jid:
                        self._infos[idx] = info
            for sl, e in failed:
                if sl.failures == 0:
                    sl.outage_start = time.time()
                sl.failures += 1
                sl.last_error = str(e)
            failover_due = bool(failed) and bool(self._failover_due())

        # spec.placement.failover: a slice past its policy is promoted to
        # LOST and its unfinished indices migrate to the surviving healthy
        # candidates.  Remote work (probes, resubmissions) runs OUTSIDE _mu,
        # like a scale-up; a kill supersedes any migration.
        migrated = False
        if failover_due and not kill_requested:
            migrated = self._attempt_failover(cm_now, desired)
        if self._orphans:
            self._reap_orphans()

        with self._mu:
            if not polled and not migrated:
                if failed:
                    # nothing answered this tick: surface unreachability
                    # once the budget is spent (black-box honesty:
                    # unreachable != dead) — never fall through to a
                    # stale-data evaluation
                    for sl, _e in failed:
                        if sl.failures >= self._unknown_after:
                            self._push(
                                {"jobStatus": UNKNOWN,
                                 "message": self._slice_outage_message(sl)})
                    self._obs[slice_k] = TickObs(unknown=True)
                else:
                    # an empty target set: this chain's slice is LOST (the
                    # multiplexed driver retires the chain after this tick)
                    self._obs[slice_k] = TickObs()
                return False
            return self._evaluate(cm_now, desired, kill_requested, stall_msg,
                                  {sl.k for sl, *_ in polled},
                                  chain=slice_k, had_failures=bool(failed),
                                  skipped=skipped)

    def _slice_outage_message(self, sl: PlacementSlice) -> str:
        """The UNKNOWN diagnostic for one unreachable slice: which endpoint,
        for how long, after how many failed polls — not just the index."""
        where = f"slice {sl.k} " if self._sliced else ""
        secs = time.time() - sl.outage_start if sl.outage_start else 0.0
        return (f"{where}resource unreachable ({sl.url}; "
                f"{sl.failures} failed polls over {secs:.1f}s): "
                f"{sl.last_error}")

    def _evaluate(self, cm_now: Dict[str, str], desired: int,
                  kill_requested: bool, stall_msg: Optional[str],
                  ticked: Set[int], chain: Optional[int] = None,
                  had_failures: bool = False,
                  skipped: bool = False) -> bool:
        """The post-poll half of a tick (holding ``self._mu``): drain
        condemned indices, spend retry budget, aggregate, push status, act
        on the kill flag, decide termination, and record this chain's
        ``TickObs`` for the driver's cadence.  Per-slice remote actions
        (cancel, resubmit) run only for the slices this tick polled (a
        watch-skipped slice counts: its states are provably current)."""
        imap = self._index_map()
        states = {
            i: (_CANON_TO_BRIDGE[self._infos[i]["state"]]
                if i in self._infos else SUBMITTED)
            for i in imap}
        if self._condemned:
            self._drain_condemned(cm_now, states, ticked)
            imap = self._index_map()
        indices = sorted(imap)
        is_array = "array_count" in cm_now or len(indices) > 1
        live = [i for i in indices if imap[i][1] not in self._condemned]
        retry_limit, attempts = self._retry_limit, self._attempts

        # spec.retry: resubmit FAILED indices while budget remains
        # (a kill supersedes retries — never resubmit a killed CR; a
        # condemned index is being drained, never resubmitted)
        if retry_limit and not kill_requested:
            for i in live:
                sl = imap[i][0]
                used = attempts.get(str(i), 0)
                if (states[i] != FAILED or used >= retry_limit
                        or sl.k not in ticked):
                    continue
                attempts[str(i)] = used + 1
                if self._backoff:
                    self._sleep(self._backoff)
                try:
                    # arrays go through resubmit_index so native dialects
                    # can restamp their index marker; single jobs resubmit
                    # plainly
                    resubmit = (sl.adapter.resubmit_index if is_array
                                else lambda s, p, q, _i:
                                sl.adapter.submit(s, p, q))
                    new_id = resubmit(
                        self._fetch_script(cm_now),
                        json.loads(cm_now.get("jobproperties", "{}")),
                        self._index_params(cm_now, i,
                                           max(desired, len(indices))), i)
                except (B.SubmitError, TransportError, NoSuchKey,
                        KeyError, ValueError):
                    # budget consumed; surface FAILED when exhausted
                    self._push({"retry_attempts": json.dumps(attempts)})
                    continue
                for p in sl.pairs:
                    if p[0] == i:
                        p[1] = new_id
                        break
                imap[i] = (sl, new_id)
                states[i] = SUBMITTED
                self._infos.pop(i, None)
                updates = {"id": ",".join(self._global_ids()),
                           "retry_attempts": json.dumps(attempts)}
                if self._sliced:
                    updates[slice_key(sl.k, "id")] = _encode_pairs(sl.pairs)
                self._push(updates)

        def exhausted(i: int) -> bool:
            # a kill cancels the remaining budget — FAILED is final then
            return kill_requested or attempts.get(str(i), 0) >= retry_limit

        # terminal only when every LIVE index settled AND the desired count
        # is fully applied: exiting mid-drain would orphan condemned remote
        # jobs, and exiting below a stalled scale-up target would silently
        # drop an accepted patch (a kill supersedes the pending resize)
        finished = (not self._condemned
                    and (kill_requested or len(indices) == desired)
                    and all(
                        states[i] in (DONE, KILLED)
                        or (states[i] == FAILED and exhausted(i))
                        for i in live))
        # aggregate over the LIVE (desired) indices only — a condemned index
        # being drained must not colour the CR's state, times, or results
        if finished:
            if all(states[i] == DONE for i in live):
                agg = DONE
            elif any(states[i] == KILLED for i in live):
                agg = KILLED
            else:
                agg = FAILED
        elif any(states[i] == RUNNING for i in live):
            agg = RUNNING
        else:
            agg = SUBMITTED

        live_infos = [self._infos.get(i, {}) for i in live]
        message = stall_msg or self._aggregate_message(
            [states[i] for i in live], live_infos)
        # an unreachable slice must not be masked by its healthy siblings'
        # aggregate: the CR stays UNKNOWN until every slice answers again
        # (its stale non-terminal states above also keep `finished` False,
        # so we never invent progress OR death from a black-box silence).
        # A LOST slice is past this: its indices already migrated, and the
        # aggregate over the survivors is the truth again.
        unreachable = [sl for sl in self._slices
                       if not sl.lost and sl.failures >= self._unknown_after]
        if unreachable and not finished:
            agg = UNKNOWN
            message = "; ".join(self._slice_outage_message(sl)
                                for sl in unreachable)

        updates = {"jobStatus": agg, "message": message}
        if is_array:
            updates["index_states"] = json.dumps(
                {str(i): states[i] for i in live})
        if self._sliced:
            updates["placements"] = json.dumps(
                self._placements_snapshot(states))
        starts = [info.get("start_time") for info in live_infos
                  if info.get("start_time")]
        ends = [info.get("end_time") for info in live_infos
                if info.get("end_time")]
        if starts:
            updates["start_time"] = str(min(starts))
        if ends and (len(indices) == 1 or finished):
            updates["end_time"] = str(max(ends))
        for i in live:
            info = self._infos.get(i, {})
            if info.get("results_location"):
                updates[self._results_key(imap[i][0], i, is_array)] = \
                    info["results_location"]
        # the Kubernetes convergence handshake: report the generation whose
        # desired state is now fully applied (all indices submitted, nothing
        # draining) so clients can await `observedGeneration == generation`
        if (cm_now.get("generation") and not self._condemned
                and len(indices) == desired):
            updates["observed_generation"] = cm_now["generation"]
        self._push(updates)

        # cadence hint: what this chain's tick saw.  "busy" flags phases
        # where a transition is expected soon (indices still queued, a
        # mixed done/running tail, drain/growth in flight, a kill) so an
        # adaptive cadence holds its tight interval; "changed" resets a
        # backed-off one; a quiet, fully-RUNNING steady state backs off.
        terminal = sum(1 for i in live if states[i] in (DONE, FAILED, KILLED))
        self._obs[chain] = TickObs(
            changed=states != self._prev_states.get(chain),
            busy=bool(kill_requested or self._condemned or stall_msg
                      or any(states[i] == SUBMITTED for i in live)
                      or 0 < terminal < len(live)),
            unknown=had_failures or bool(unreachable),
            skipped=skipped)
        self._prev_states[chain] = dict(states)

        if kill_requested:
            for sl in self._slices:
                if sl.k not in ticked or not sl.adapter.supports(
                        B.Capability.CANCEL):
                    continue
                cq = sl.adapter.supports(B.Capability.CANCEL_QUEUED)
                for idx, jid in list(sl.pairs):
                    self._try_cancel(sl.adapter, jid,
                                     states.get(idx, SUBMITTED), cq)

        if finished:
            if agg == DONE:
                self._finalize_outputs(cm_now)
                self._exit(0)
            else:
                self._exit(1)
            return True
        return False

    @staticmethod
    def _aggregate_message(states: list, infos: list) -> str:
        if len(states) == 1:
            return infos[0].get("reason", "") or ""
        parts = [f"[{i}] {info.get('reason', '')}"
                 for i, info in enumerate(infos) if info.get("reason")]
        return "; ".join(parts)

    def _finalize_outputs(self, cm_data: Dict[str, str]) -> None:
        """Download outputs from each slice's resource; upload to S3 if
        configured.  Array indices land under ``<pod>/<index>/`` prefixes."""
        self._checkpoint()
        props = json.loads(cm_data.get("jobproperties", "{}"))
        bucket = cm_data.get("s3uploadbucket", "")
        names = [n for n in cm_data.get("s3uploadfiles", "").split(",") if n]
        for key in ("OutputFileName", "ErrorFileName"):
            if props.get(key) and props[key] not in names:
                names.append(props[key])
        if not names:
            return
        total = sum(len(sl.pairs) for sl in self._slices)
        uploaded = []
        for sl in self._slices:
            if sl.lost:
                continue  # dead endpoint: nothing to download from it
            can_download = sl.adapter.supports(B.Capability.DOWNLOAD)
            can_logs = sl.adapter.supports(B.Capability.LOGS)
            if not (can_download or can_logs):
                continue
            for idx, jid in sorted(sl.pairs):
                prefix = self.name if total == 1 else f"{self.name}/{idx}"
                for name in names:
                    data = sl.adapter.download(name) if can_download else None
                    if data is None and can_logs:
                        data = sl.adapter.download_logs(jid)  # ray idiom
                    if data is None:
                        continue
                    if bucket:
                        self.s3.put(bucket, f"{prefix}/{name}", data)
                        uploaded.append(f"{bucket}:{prefix}/{name}")
        if uploaded:
            self.cm.update({"outputs": ",".join(uploaded)})

    def _exit(self, code: int) -> None:
        self.exit_code = code


def make_protocol(name: str, configmap: ConfigMap, secrets: SecretStore,
                  objectstore: ObjectStore,
                  directory: ResourceManagerDirectory,
                  adapters: Mapping[str, Type[B.ResourceAdapter]],
                  checkpoint: Callable[[], None],
                  sleep: Callable[[float], None],
                  min_sleep: float = 0.005) -> JobProtocol:
    """Reconcile-protocol dispatch: the config map's ``kind`` key picks the
    state machine — ``BridgeService`` gets the long-running ServiceProtocol,
    everything else (including every legacy cm, which has no ``kind`` key)
    the run-to-terminal JobProtocol.  Both drivers (ControllerPod,
    MonitorTask) construct through here, so a pod restarted over a service
    cm resumes as a service."""
    cls: Type[JobProtocol] = JobProtocol
    if configmap.get("kind", "") == "BridgeService":
        from repro.core.service import ServiceProtocol  # avoids import cycle
        cls = ServiceProtocol
    return cls(name, configmap, secrets, objectstore, directory, adapters,
               checkpoint=checkpoint, sleep=sleep, min_sleep=min_sleep)


class ControllerPod:
    # pod phases (Kubernetes-like)
    PENDING = "Pending"
    RUNNING_PHASE = "Running"
    SUCCEEDED = "Succeeded"
    FAILED_PHASE = "Failed"
    KILLED_PHASE = "Killed"   # external kill (node loss) — operator restarts

    def __init__(self, name: str, configmap: ConfigMap, secrets: SecretStore,
                 objectstore: ObjectStore, directory: ResourceManagerDirectory,
                 adapters: Mapping[str, Type[B.ResourceAdapter]],
                 min_sleep: float = 0.005):
        self.name = name
        self.cm = configmap
        self.min_sleep = min_sleep
        self.phase = self.PENDING
        self.exit_code: Optional[int] = None
        self.error: str = ""
        self._killed = threading.Event()
        self._proto = make_protocol(
            name, configmap, secrets, objectstore, directory, adapters,
            checkpoint=self._checkpoint, sleep=self._sleep,
            min_sleep=min_sleep)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"pod-{name}")

    # -- lifecycle --------------------------------------------------------

    def start(self) -> None:
        self._thread.start()

    def kill_pod(self) -> None:
        """Simulate pod/node failure: abort without flushing state."""
        self._killed.set()

    def poke(self) -> None:
        """Spec-patch notification.  The paper-faithful pod has no wake-up
        channel — it polls the config map every ``updateinterval`` — so a
        resize is picked up at the next tick; the multiplexed MonitorTask
        reschedules immediately instead."""

    def alive(self) -> bool:
        return self._thread.is_alive()

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)

    # -- internals ----------------------------------------------------------

    def _checkpoint(self) -> None:
        """Action boundary: a killed pod dies here, state unflushed."""
        if self._killed.is_set():
            raise PodKilled(self.name)

    def _sleep(self, seconds: float) -> None:
        killable_sleep(self._killed, self.name, seconds, self.min_sleep)

    def _run(self) -> None:
        self.phase = self.RUNNING_PHASE
        try:
            self._main()
        except PodKilled:
            self.phase = self.KILLED_PHASE
        except Exception as e:  # pod crash (bug/unhandled) — operator restarts
            self.error = f"{type(e).__name__}: {e}"
            self.phase = self.KILLED_PHASE

    def _main(self) -> None:
        proto = self._proto
        if not proto.start():
            self._exit(proto.exit_code)
            return
        # the pod's inter-tick wait comes from the CR's cadence policy:
        # FixedCadence reproduces the historical `sleep(poll)` exactly
        cadence = proto.make_cadence()
        while True:
            self._sleep(cadence.next_delay(proto.observation(None)))
            if proto.tick():
                self._exit(proto.exit_code)
                return

    def _exit(self, code: int) -> None:
        self.exit_code = code
        self.phase = self.SUCCEEDED if code == 0 else self.FAILED_PHASE
