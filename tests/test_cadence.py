"""Event-driven control plane: cadence policies, watch/long-poll transport,
per-endpoint channels, and the client-timeout contract.

Three layers under test:
  * the ``Cadence`` policy objects themselves (pure deadline arithmetic),
  * the transport substrate (watch routes, ``Channel`` multiplexing/memo,
    ``RestClient.timeout`` enforcement),
  * the integrated protocol (watch-mode ticks skip status requests; a spec
    patch overrides a backed-off adaptive deadline; dialects without
    Capability.WATCH never see a watch or batch verb).
"""
import threading
import time

import pytest

from repro.core import (AdaptiveCadence, ArraySpec, BridgeEnvironment,
                        TOKENS,
                        Capability, Channel, DONE, FixedCadence, RUNNING,
                        TickObs, TransportError, RestClient, URLS)
from repro.core.backends import base as B
from repro.core.backends.quantum import QuantumAdapter
from repro.core.backends.ray import RayAdapter
from repro.core.backends.slurm import SlurmAdapter, make_server
from repro.core.rest import FaultProfile


def _wait(predicate, timeout=10, interval=0.005):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


# ---------------------------------------------------------------------------
# cadence policy arithmetic
# ---------------------------------------------------------------------------


def test_fixed_cadence_ignores_observations():
    cad = FixedCadence(0.5)
    for obs in (None, TickObs(changed=True), TickObs(busy=True),
                TickObs(unknown=True), TickObs()):
        assert cad.next_delay(obs) == 0.5


def test_adaptive_cadence_backs_off_and_resets():
    cad = AdaptiveCadence(1.0)
    tight = AdaptiveCadence.TIGHT_FACTOR  # 0.25
    # before the first tick: expect a transition soon (just submitted)
    assert cad.next_delay(None) == pytest.approx(tight)
    # a state change drops to base; quiet ticks then double up to the cap
    assert cad.next_delay(TickObs(changed=True)) == pytest.approx(1.0)
    assert cad.next_delay(TickObs()) == pytest.approx(2.0)
    assert cad.next_delay(TickObs()) == pytest.approx(4.0)
    assert cad.next_delay(TickObs()) == pytest.approx(8.0)
    assert cad.next_delay(TickObs()) == pytest.approx(8.0)  # capped
    # busy (transition expected) snaps back to tight, however backed off
    assert cad.next_delay(TickObs(busy=True)) == pytest.approx(tight)
    # reset (spec-patch poke) does the same out-of-band
    cad.next_delay(TickObs(changed=True))
    cad.next_delay(TickObs())
    cad.reset()
    assert cad.next_delay(TickObs()) >= 1.0  # resumes from base, not 2.0


def test_adaptive_cadence_unknown_pins_tight():
    """An unreachable slice must be re-checked at the TIGHT interval — a
    chain must never back off while it cannot see its resource (recovery
    would otherwise be observed up to MAX_FACTOR intervals late)."""
    cad = AdaptiveCadence(1.0)
    tight = 1.0 * AdaptiveCadence.TIGHT_FACTOR
    cad.next_delay(TickObs(changed=True))
    cad.next_delay(TickObs())  # backed off to 2.0
    for _ in range(5):
        assert cad.next_delay(TickObs(unknown=True)) == pytest.approx(tight)


# ---------------------------------------------------------------------------
# transport: client timeout, watch routes, channels
# ---------------------------------------------------------------------------


def _cluster_and_client(timeout=5.0, latency=0.0):
    cluster = B.SimulatedCluster("t", slots=4, default_duration=0.05)
    srv = make_server(cluster, token="tok",
                      fault=FaultProfile(latency=latency))
    client = RestClient(srv, token="tok", timeout=timeout)
    return cluster, srv, client


def test_client_timeout_enforced_on_slow_server():
    """RestClient.timeout is a real contract now: a response slower than the
    client's budget surfaces as a TransportError, not a silent stall."""
    cluster, srv, client = _cluster_and_client(timeout=0.05, latency=0.3)
    t0 = time.time()
    with pytest.raises(TransportError):
        client.get("/slurm/v0.0.37/ping")
    assert time.time() - t0 < 0.25  # gave up at ~timeout, not ~latency
    cluster.shutdown()


def test_watch_route_expires_within_client_timeout():
    """A watch long-poll with a huge requested wait is capped to the
    client's timeout and answers 204 (no content) at expiry."""
    cluster, srv, client = _cluster_and_client(timeout=0.3)
    adapter = SlurmAdapter(client)
    v0 = cluster.events_version()
    t0 = time.time()
    assert adapter.watch_events(since=v0 + 100, wait=30.0) is None
    elapsed = time.time() - t0
    assert 0.2 <= elapsed < 2.0  # waited ~timeout, nowhere near 30s
    cluster.shutdown()


def test_watch_route_wakes_on_relevant_event():
    """A blocked watch answers as soon as a relevant transition lands, and
    a filtered watch ignores OTHER jobs' events."""
    cluster, srv, client = _cluster_and_client(timeout=5.0)
    adapter = SlurmAdapter(client)
    ours = cluster.submit("s", {"WallSeconds": "30"}, {})
    other = cluster.submit("s", {"WallSeconds": "30"}, {})
    # let both QUEUED->RUNNING transitions land first: our own job's start
    # event would otherwise (correctly) satisfy the watch immediately
    assert _wait(lambda: ours.state == B.RUNNING and other.state == B.RUNNING)
    v0 = cluster.events_version()
    # filtered on OUR id: the other job's cancel must not wake it
    result = {}

    def watch():
        result["v"] = adapter.watch_events(since=v0, ids=[ours.id], wait=3.0)

    t = threading.Thread(target=watch)
    t.start()
    time.sleep(0.05)
    cluster.cancel(other.id)  # irrelevant event
    time.sleep(0.2)
    assert t.is_alive()  # still waiting: the event was filtered out
    cluster.cancel(ours.id)
    t.join(timeout=3)
    assert not t.is_alive()
    assert result["v"] is not None and result["v"] > v0
    cluster.shutdown()


def test_directory_shares_one_channel_per_endpoint():
    """Every client the directory hands out for one URL multiplexes over
    the SAME channel object, whose counters see all their requests."""
    with BridgeEnvironment() as env:
        c1 = env.directory.connect(URLS["slurm"], TOKENS["slurm"])
        c2 = env.directory.connect(URLS["slurm"], TOKENS["slurm"])
        other = env.directory.connect(URLS["lsf"], TOKENS["lsf"])
        assert c1.channel is c2.channel
        assert other.channel is not c1.channel
        before = c1.channel.requests
        c1.get("/slurm/v0.0.37/ping")
        c2.get("/slurm/v0.0.37/ping")
        assert c1.channel.requests == before + 2
        assert env.directory.channels()[URLS["slurm"]] is c1.channel


def test_channel_memo_amortizes_and_refreshes():
    """channel.memo: one compute per max_age window however many callers;
    a stale entry is recomputed exactly once."""
    cluster, srv, client = _cluster_and_client()
    ch = client.channel
    calls = []

    def compute():
        calls.append(1)
        return len(calls)

    assert ch.memo("k", 10.0, compute) == 1
    assert ch.memo("k", 10.0, compute) == 1  # cached
    assert len(calls) == 1
    assert ch.memo("k", 0.0, compute) == 2   # max_age 0: always stale
    cluster.shutdown()


def test_channel_memo_single_flight_under_contention():
    """Regression (single-flight): N threads probing the SAME stale key must
    cause exactly ONE upstream compute — the rest block on the per-key gate
    and read the value the winner cached.  This is what keeps the events-
    version probe O(endpoints) however many CR chains fire at once."""
    cluster, srv, client = _cluster_and_client()
    ch = client.channel
    n = 16
    barrier = threading.Barrier(n)
    computes = []
    compute_mu = threading.Lock()
    results = []

    def compute():
        with compute_mu:
            computes.append(1)
        time.sleep(0.05)  # hold the gate so every prober piles up behind it
        return "value"

    def probe():
        barrier.wait()
        results.append(ch.memo("hot", 10.0, compute))

    threads = [threading.Thread(target=probe) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert results == ["value"] * n
    assert len(computes) == 1
    cluster.shutdown()


def test_server_per_route_stats():
    cluster, srv, client = _cluster_and_client()
    client.get("/slurm/v0.0.37/ping")
    client.get("/slurm/v0.0.37/ping")
    client.get("/slurm/v0.0.37/job/does-not-exist")
    stats = srv.stats
    assert stats["GET /slurm/v0.0.37/ping"] == {"requests": 2, "errors": 0}
    assert stats["GET /slurm/v0.0.37/job/{id}"]["errors"] == 1
    cluster.shutdown()


# ---------------------------------------------------------------------------
# integrated protocol behaviour
# ---------------------------------------------------------------------------


def _proto_of(env, handle):
    pod = env.operator.pods[handle.job().uid]
    return pod._proto


@pytest.mark.parametrize("cadence", ["adaptive", "watch", "wakeup"])
def test_event_modes_converge_like_fixed(cadence):
    """Lifecycle parity: an array CR runs to DONE with per-index states
    under both event-driven cadences, exactly as under fixed."""
    with BridgeEnvironment(default_duration=0.1,
                           operator_kwargs={"cadence": cadence}) as env:
        h = env.bridge.submit("ev", env.make_spec(
            "slurm", script="s", updateinterval=0.03,
            array=ArraySpec(count=4)))
        assert h.wait(timeout=30).status.state == DONE
        assert h.job().status.index_states == {str(i): DONE
                                               for i in range(4)}


def test_watch_mode_skips_status_requests():
    """The watch fast path must actually skip status polls during a quiet
    RUNNING plateau — and still observe the terminal transition."""
    with BridgeEnvironment(default_duration=0.6,
                           operator_kwargs={"cadence": "watch"}) as env:
        h = env.bridge.submit("w", env.make_spec(
            "slurm", script="s", updateinterval=0.05,
            jobproperties={"WallSeconds": "0.6"}))
        assert _wait(lambda: h.status().state == RUNNING, timeout=10)
        proto = _proto_of(env, h)
        assert h.wait(timeout=30).status.state == DONE
        assert proto.watch_skips > 0


def test_poke_overrides_backed_off_deadline_multiplexed():
    """Satellite-spec: a spec patch must take effect NOW even when the
    adaptive cadence has backed the chain's deadline off — the poke entry
    supersedes the heap entry and resets the cadence."""
    with BridgeEnvironment(slots=8, default_duration=600,
                           operator_kwargs={"mode": "multiplexed",
                                            "cadence": "adaptive"}) as env:
        h = env.bridge.submit("el", env.make_spec(
            "slurm", script="s", updateinterval=0.5,
            jobproperties={"WallSeconds": "600"},
            array=ArraySpec(count=2)))
        assert _wait(lambda: h.status().state == RUNNING, timeout=15)
        # let the quiet RUNNING plateau back the cadence off past 2x base
        time.sleep(3.0)
        t0 = time.time()
        h.scale(4)
        assert _wait(
            lambda: len([s for s in h.status().job_id.split(",") if s]) == 4,
            timeout=10)
        # far sooner than the backed-off deadline (>= 2*base = 1s away on
        # average, up to 4s); generous bound for slow CI
        assert time.time() - t0 < 2.5


# ---------------------------------------------------------------------------
# capability gating: dialects without WATCH/BATCH_STATUS never see the verbs
# ---------------------------------------------------------------------------


class _SpyQuantumAdapter(QuantumAdapter):
    forbidden_calls = []

    def status_batch(self, job_ids):
        type(self).forbidden_calls.append(("status_batch", job_ids))
        raise AssertionError("status_batch called without BATCH_STATUS")

    def watch_events(self, since=-1, ids=None, wait=0.0):
        type(self).forbidden_calls.append(("watch_events", since))
        raise AssertionError("watch_events called without WATCH")

    def watch_events_ids(self, since=-1, ids=None, wait=0.0):
        type(self).forbidden_calls.append(("watch_events_ids", since))
        raise AssertionError("watch_events_ids called without WATCH")


class _SpyRayAdapter(RayAdapter):
    forbidden_calls = []

    def status_batch(self, job_ids):
        type(self).forbidden_calls.append(("status_batch", job_ids))
        raise AssertionError("status_batch called without BATCH_STATUS")

    def watch_events(self, since=-1, ids=None, wait=0.0):
        type(self).forbidden_calls.append(("watch_events", since))
        raise AssertionError("watch_events called without WATCH")

    def watch_events_ids(self, since=-1, ids=None, wait=0.0):
        type(self).forbidden_calls.append(("watch_events_ids", since))
        raise AssertionError("watch_events_ids called without WATCH")


@pytest.mark.parametrize("kind,spy", [("quantum", _SpyQuantumAdapter),
                                      ("ray", _SpyRayAdapter)])
@pytest.mark.parametrize("cadence", ["fixed", "watch", "wakeup"])
def test_unwatchable_dialects_never_see_batch_or_watch_verbs(kind, spy,
                                                             cadence):
    """Regression: quantum/ray declare neither BATCH_STATUS nor WATCH, so an
    array CR on them must converge through per-id status polls alone — even
    when the operator runs in watch or wakeup mode (transparent fallback:
    no watch probe, no id-filtered event fetch, no watcher subscription)."""
    assert Capability.WATCH not in spy.capabilities
    assert Capability.BATCH_STATUS not in spy.capabilities
    spy.forbidden_calls = []
    with BridgeEnvironment(default_duration=0.05,
                           operator_kwargs={"mode": "multiplexed",
                                            "cadence": cadence}) as env:
        env.operator.adapters[spy.image] = spy
        h = env.bridge.submit("nb", env.make_spec(
            kind, script="s", updateinterval=0.03, array=ArraySpec(count=3)))
        assert h.wait(timeout=30).status.state == DONE
        assert h.job().status.index_states == {str(i): DONE for i in range(3)}
        assert spy.forbidden_calls == []
        if cadence in ("watch", "wakeup"):
            assert _proto_of(env, h).watch_skips == 0
        if cadence == "wakeup":
            # an unwatchable dialect never registers for watcher pokes
            stats = env.operator.runtime.stats()
            assert stats["watcher_threads"] == 0
            assert stats["subscribed_ids"] == 0


# ---------------------------------------------------------------------------
# wakeup cadence: watcher pokes, id-filtered polling, coalescing, chaos
# ---------------------------------------------------------------------------


def test_wakeup_mode_merges_events_and_polls_only_terminal():
    """The wakeup tentpole, end to end on one CR: the RUNNING transition is
    learned from the watcher's event payload with ZERO status requests, and
    the whole lifecycle costs exactly one terminal status poll."""
    with BridgeEnvironment(default_duration=0.8,
                           operator_kwargs={"mode": "multiplexed",
                                            "cadence": "wakeup"}) as env:
        status_route = "GET /slurm/v0.0.37/job/{id}"
        batch_route = "GET /slurm/v0.0.37/jobs"

        def status_requests():
            stats = env.servers["slurm"].stats
            return (stats.get(status_route, {}).get("requests", 0)
                    + stats.get(batch_route, {}).get("requests", 0))

        h = env.bridge.submit("wk", env.make_spec(
            "slurm", script="s", updateinterval=0.05,
            jobproperties={"WallSeconds": "0.8"}))
        assert _wait(lambda: h.status().state == RUNNING, timeout=10)
        # RUNNING was learned by merging the event payload, not by polling
        assert status_requests() == 0
        proto = _proto_of(env, h)
        assert h.wait(timeout=30).status.state == DONE
        assert proto.watch_skips > 0
        # the terminal transition is the one (id-filtered) status request
        assert status_requests() <= 2
        stats = env.operator.runtime.stats()
        assert stats["watcher_threads"] == 1
        assert stats["pokes_delivered"] > 0
        assert stats["wakeup_samples"] > 0
        for key in ("heap_depth", "stale_drops", "pokes_coalesced",
                    "wakeup_latency_p50_s", "wakeup_latency_p99_s",
                    "subscribed_ids"):
            assert key in stats


def test_poke_storm_coalesces_to_bounded_evaluations():
    """Satellite-spec: M rapid pokes on one chain inside a tick window must
    run at most a couple of extra evaluations — never M — and never multiply
    live heap entries (superseded tokens are dropped on pop)."""
    with BridgeEnvironment(slots=4, default_duration=600,
                           operator_kwargs={"mode": "multiplexed",
                                            "cadence": "wakeup"}) as env:
        h = env.bridge.submit("storm", env.make_spec(
            "slurm", script="s", updateinterval=0.5,
            jobproperties={"WallSeconds": "600"}))
        assert _wait(lambda: h.status().state == RUNNING, timeout=15)
        task = env.operator.pods[h.job().uid]
        proto = task._proto
        time.sleep(0.3)  # let submission-wave steps and pokes settle
        before = env.operator.runtime.stats()
        ticks = []
        orig_tick = proto.tick
        proto.tick = lambda chain=None: (ticks.append(1), orig_tick(chain))[1]
        try:
            for _ in range(50):
                task.poke_chain(0)
            assert _wait(lambda: len(ticks) >= 1, timeout=5)
            time.sleep(0.3)  # absorb any follow-up scheduling
        finally:
            proto.tick = orig_tick
        after = env.operator.runtime.stats()
        delivered = after["pokes_delivered"] - before["pokes_delivered"]
        coalesced = after["pokes_coalesced"] - before["pokes_coalesced"]
        assert delivered >= 50
        assert coalesced >= 40   # the storm collapsed into a few wake-ups
        # a storm of 50 pokes costs a handful of evaluations, not 50
        assert len(ticks) <= 4
        # and the heap holds one live entry per chain, not one per poke
        assert after["heap_depth"] <= 4


def test_watcher_blackout_falls_back_to_deadline_polls():
    """Chaos: a hard endpoint outage (the watcher's long-polls AND the
    deadline polls all fail) must degrade to deadline polling once the
    outage lifts — the terminal transition that happened DURING the blackout
    lands exactly once, never skipped, and the watcher reconnects."""
    fp = FaultProfile()
    with BridgeEnvironment(default_duration=1.0,
                           fault_profiles={"slurm": fp},
                           operator_kwargs={"mode": "multiplexed",
                                            "cadence": "wakeup"}) as env:
        h = env.bridge.submit("bo", env.make_spec(
            "slurm", script="s", updateinterval=0.1,
            jobproperties={"WallSeconds": "1.0"}))
        assert _wait(lambda: h.status().state == RUNNING, timeout=10)
        fp.begin_outage()
        time.sleep(1.5)    # the job finishes DURING the blackout
        fp.end_outage()
        assert h.wait(timeout=30).status.state == DONE
        assert env.operator.runtime.stats()["watcher_threads"] == 1


def test_wakeup_mode_survives_operator_pod_kill():
    """Chaos: killing the monitor task mid-RUN in wakeup mode must restart
    cleanly — the replacement re-attaches to the same remote job (no double
    submission), re-seeds its info cache through a plain poll, re-subscribes,
    and still observes the terminal transition."""
    with BridgeEnvironment(default_duration=1.2,
                           operator_kwargs={"mode": "multiplexed",
                                            "cadence": "wakeup"}) as env:
        h = env.bridge.submit("pk", env.make_spec(
            "slurm", script="s", updateinterval=0.05,
            jobproperties={"WallSeconds": "1.2"}))
        assert _wait(lambda: h.status().state == RUNNING, timeout=10)
        env.operator.pods[h.job().uid].kill_pod()
        assert h.wait(timeout=30).status.state == DONE
        assert len(env.clusters["slurm"].jobs) == 1  # re-attached, not resubmitted
