"""The paper's §6 workflow integration: the three-step pipeline
(createop -> invokeop -> cleanop) used as a sub-workflow of a larger
pipeline, staging input from S3 and uploading outputs back.

  PYTHONPATH=src python examples/hpc_workflow.py
"""
from repro.core import BridgeEnvironment, IMAGES, URLS
from repro.workflows import Pipeline, PipelineOp, bridge_pipeline


def main() -> None:
    with BridgeEnvironment(default_duration=0.2) as env:
        env.s3.put("inputs", "genome.fasta", b">chr1\nACGT...\n")

        hpc_step = bridge_pipeline(
            env, "align-job",
            resourceURL=URLS["lsf"], resourcesecret="lsf-secret",
            script="bsub -n 8 ./align genome.fasta", scriptlocation="inline",
            docker=IMAGES["lsf"],
            additionaldata="inputs:genome.fasta",
            jobproperties={"OutputFileName": "align.out"},
            s3uploadfiles="align.out", s3uploadbucket="results",
            updateinterval=0.05,
        )

        outer = Pipeline("science-workflow")
        prep = outer.add(PipelineOp(
            "prepare-data", lambda ctx: env.s3.list("inputs")))
        hpc = outer.add_subpipeline(hpc_step, after=["prepare-data"])
        outer.add(PipelineOp(
            "postprocess",
            lambda ctx: env.s3.list("results"),
            after=[hpc.name]))

        results = outer.run()
        print("pipeline results:")
        for name, val in results.items():
            print(f"  {name}: {val if not isinstance(val, dict) else val.get('invokeop', val)}")
        assert any(k.endswith("align.out") for k in results["postprocess"])
        print("output uploaded to s3://results/ — workflow complete")


if __name__ == "__main__":
    main()
