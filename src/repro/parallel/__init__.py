from repro.parallel.ep import ep_mesh, moe_ep_shard_map
from repro.parallel.pipeline import pipeline_apply, stack_stage_params
