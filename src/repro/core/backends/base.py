"""Simulated external-cluster machinery shared by all resource managers.

A ``SimulatedCluster`` is the black box behind each REST facade: a queue of
jobs, a bounded set of execution slots, and a scheduler thread that advances
job states.  Specific managers (slurm/lsf/quantum/ray) expose their own REST
dialect over this substrate; ``jaxlocal`` replaces the sleep payload with a
REAL distributed JAX training loop.

Canonical internal states (each dialect maps to its own vocabulary):
    QUEUED -> RUNNING -> {COMPLETED, FAILED, CANCELLED}
"""
from __future__ import annotations

import enum
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, FrozenSet, List, Mapping, Optional,
                    Tuple, Type)

QUEUED = "QUEUED"
RUNNING = "RUNNING"
COMPLETED = "COMPLETED"
FAILED = "FAILED"
CANCELLED = "CANCELLED"
TERMINAL = (COMPLETED, FAILED, CANCELLED)


@dataclass
class ClusterJob:
    id: str
    script: str
    properties: Dict[str, str] = field(default_factory=dict)
    params: Dict[str, str] = field(default_factory=dict)
    state: str = QUEUED
    submit_time: float = field(default_factory=time.time)
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    exit_code: Optional[int] = None
    reason: str = ""
    # cluster events version at this job's last state transition (watch/
    # long-poll support: lets a watcher ask "did THESE ids change since v?")
    events_stamp: int = 0
    # files produced by the job, downloadable via the manager's API
    outputs: Dict[str, bytes] = field(default_factory=dict)
    # serve-mode jobs (long-lived replicas): the payload installs a request
    # handler once it can take traffic — health answers 200 iff the job is
    # RUNNING with a handler installed and not flagged unhealthy
    handler: Optional[Callable[[Any], Any]] = field(default=None, repr=False)
    unhealthy: threading.Event = field(default_factory=threading.Event,
                                       repr=False)
    invocations: int = 0
    _cancel: threading.Event = field(default_factory=threading.Event, repr=False)

    def snapshot(self) -> Dict[str, Any]:
        return {
            "id": self.id, "state": self.state, "submit_time": self.submit_time,
            "start_time": self.start_time, "end_time": self.end_time,
            "exit_code": self.exit_code, "reason": self.reason,
        }


# A payload executes the job body.  It runs on a worker thread and must poll
# ``job._cancel`` to honour kills.  Returns an exit code.
Payload = Callable[[ClusterJob, "SimulatedCluster"], int]


def serve_loop(job: ClusterJob, cluster: "SimulatedCluster") -> int:
    """Long-lived serve-mode replica: install an echo handler and run until
    cancelled.  Serve jobs NEVER auto-complete — walltime expiry must not be
    mistaken for success on a replica whose whole point is staying up.

    Chaos knobs (properties): ``CrashAfter`` fails the replica after N
    seconds (handler removed first, so health goes 503 before FAILED);
    ``ServeLatency`` adds per-request artificial service time.
    """
    latency = float(job.properties.get("ServeLatency", "0") or 0)

    def handler(body: Any) -> Any:
        if latency:
            time.sleep(latency)
        return {"echo": body, "served_by": job.id}

    crash_after = float(job.properties.get("CrashAfter", "0") or 0)
    deadline = time.time() + crash_after if crash_after > 0 else None
    job.handler = handler
    try:
        while not job._cancel.is_set():
            if deadline is not None and time.time() >= deadline:
                job.handler = None
                job.reason = "replica crashed (CrashAfter)"
                return 1
            time.sleep(0.005)
        return -1
    finally:
        job.handler = None


def sleep_payload(job: ClusterJob, cluster: "SimulatedCluster") -> int:
    """Default black-box job: run for WallSeconds, optionally fail, write outputs."""
    if job.properties.get("Serve", "") == "true":
        return serve_loop(job, cluster)
    dur = float(job.properties.get("WallSeconds", cluster.default_duration))
    deadline = time.time() + dur
    while time.time() < deadline:
        if job._cancel.is_set():
            return -1
        time.sleep(min(0.005, max(deadline - time.time(), 0)))
    # FailMe as a property fails the whole submission; as a param it fails
    # one array index (params are the per-index channel)
    if (job.properties.get("FailMe", "") == "true"
            or job.params.get("FailMe", "") == "true"):
        job.reason = "job script exited non-zero (FailMe)"
        return 1
    out_name = job.properties.get("OutputFileName", "job.out")
    job.outputs[out_name] = (
        f"job {job.id} ok\nscript_bytes={len(job.script)}\n"
        f"params={sorted(job.params)}\n").encode()
    err_name = job.properties.get("ErrorFileName", "")
    if err_name:
        job.outputs[err_name] = b""
    return 0


# max ids per BATCH_STATUS request (squeue -j takes a bounded id list; real
# REST dialects cap URL length) — callers chunk, so a 256-index array costs
# ceil(256/64)=4 requests per poll tick instead of 256
BATCH_STATUS_CHUNK = 64


class Capability(enum.Enum):
    """Typed adapter capabilities: what a backend's API genuinely offers.

    Consumers (operator, controller pod, scheduler, ``Bridge`` facade) consult
    ``adapter.capabilities`` instead of try/except-probing optional verbs.
    Every adapter declares honestly — a missing capability means the remote
    API has no such endpoint, not that we didn't wire it.
    """
    CANCEL = "cancel"                # can cancel a running job
    CANCEL_QUEUED = "cancel_queued"  # can cancel a job still in the queue
    UPLOAD = "upload"                # can stage files onto the resource
    DOWNLOAD = "download"            # can fetch arbitrary output files
    LOGS = "logs"                    # can fetch per-job logs (ray idiom)
    QUEUE_LOAD = "queue_load"        # exposes queue depth/slots for scheduling
    NATIVE_ARRAYS = "native_arrays"  # one submission fans out N indices
    BATCH_STATUS = "batch_status"    # one request polls many ids (squeue -j)
    WATCH = "watch"                  # events-version long-poll (skip idle polls)
    SERVE = "serve"                  # health-probe + invoke long-lived jobs


class ResourceAdapter:
    """The contract every controller-pod implementation obeys (paper §5.1:
    "to support a new resource type, the only thing that is required is the
    implementation of the corresponding controller, based on very simple
    rules imposed by the operator").

    An adapter owns a ``RestClient`` and translates the bridge verbs into the
    manager's REST dialect.  Status is reported in the CANONICAL vocabulary
    above; the adapter maps dialect states back to it.  ``capabilities``
    advertises which optional verbs the dialect really has; callers must not
    invoke a verb the adapter does not declare.
    """

    #: docker-image prefix this adapter serves ("slurmpod", "lsfpod", ...)
    image: str = ""
    #: honest declaration of what the remote API supports
    capabilities: FrozenSet[Capability] = frozenset({Capability.CANCEL})

    def __init__(self, client) -> None:
        self.client = client

    @classmethod
    def supports(cls, cap: Capability) -> bool:
        return cap in cls.capabilities

    # every verb may raise TransportError (network) — callers must handle it
    def submit(self, script: str, properties: Dict[str, str],
               params: Dict[str, str]) -> str:
        """Returns the remote job id, or raises SubmitError."""
        raise NotImplementedError

    def submit_array(self, script: str, properties: Dict[str, str],
                     params_by_index: List[Dict[str, str]],
                     start_index: int = 0) -> List[str]:
        """Native array fan-out: ONE submission call -> one id per index.
        ``params_by_index[i]`` serves GLOBAL array index ``start_index + i``
        — a placement slice submits its contiguous range in one call and the
        dialect stamps the global index marker.  Only valid when
        ``Capability.NATIVE_ARRAYS`` is declared; callers without it fan out
        via repeated ``submit()``."""
        raise NotImplementedError(
            f"{type(self).__name__} does not declare NATIVE_ARRAYS")

    def resubmit_index(self, script: str, properties: Dict[str, str],
                       params: Dict[str, str], index: int) -> str:
        """Resubmit ONE array index (the retry path).  Dialects with native
        arrays override this to restamp their own index marker so a retried
        index sees the same params as the original run."""
        return self.submit(script, properties, params)

    def status(self, job_id: str) -> Dict[str, Any]:
        """Returns {'state': CANONICAL, 'start_time', 'end_time', 'reason'}."""
        raise NotImplementedError

    def status_batch(self, job_ids: List[str]) -> List[Dict[str, Any]]:
        """ONE request answering ``status()`` for many ids, results aligned
        with ``job_ids`` (an id the manager no longer knows still yields an
        entry, with the dialect's job-vanished semantics).  Only valid when
        ``Capability.BATCH_STATUS`` is declared; callers without it poll
        per-id.  Callers chunk to ``BATCH_STATUS_CHUNK`` ids per request."""
        raise NotImplementedError(
            f"{type(self).__name__} does not declare BATCH_STATUS")

    def cancel(self, job_id: str) -> None:
        raise NotImplementedError

    def upload(self, name: str, data: bytes) -> bool:
        """Stage a file onto the resource (requires Capability.UPLOAD)."""
        return False

    def download(self, name: str) -> Optional[bytes]:
        """Fetch an output file (requires Capability.DOWNLOAD)."""
        return None

    def download_logs(self, job_id: str) -> Optional[bytes]:
        """Fetch per-job logs (requires Capability.LOGS)."""
        return None

    def queue_load(self) -> Optional[Dict[str, int]]:
        """Queue depth/slots (requires Capability.QUEUE_LOAD)."""
        return None

    def watch_events(self, since: int = -1,
                     ids: Optional[List[str]] = None,
                     wait: float = 0.0) -> Optional[int]:
        """Events-version probe/long-poll (requires Capability.WATCH).

        Returns the manager's current global events version when anything
        relevant changed after ``since`` (``ids=None`` means ANY change; an
        id the manager no longer knows counts as changed), or None when
        nothing did within ``wait`` seconds (the server answers 204).  The
        server additionally caps ``wait`` to the client's timeout."""
        raise NotImplementedError(
            f"{type(self).__name__} does not declare WATCH")

    def watch_events_ids(self, since: int = -1,
                         ids: Optional[List[str]] = None,
                         wait: float = 0.0
                         ) -> Optional[Tuple[int, Optional[List[Tuple[str, str]]]]]:
        """Payload-carrying variant of ``watch_events`` (requires
        Capability.WATCH).

        Returns None when nothing relevant changed within ``wait`` (204),
        else ``(version, events)`` where ``events`` lists ``(job_id,
        canonical_state)`` for every relevant transition in
        ``(since, version]`` — at most one entry per id, latest state wins —
        or ``events is None`` when the manager could not enumerate the
        range (its bounded event ring no longer covers ``since``): the
        caller must fall back to a status poll."""
        raise NotImplementedError(
            f"{type(self).__name__} does not declare WATCH")

    def probe_health(self, job_id: str) -> bool:
        """True iff the serve-mode job answers its health route 200
        (requires Capability.SERVE).  A 4xx/5xx answer is False; transport
        failures raise, so callers can tell replica-dead from
        manager-unreachable."""
        raise NotImplementedError(
            f"{type(self).__name__} does not declare SERVE")

    def invoke(self, job_id: str, payload: Any) -> Any:
        """POST one request to a serve-mode job and return its response body
        (requires Capability.SERVE).  Raises ``InvokeError`` on a non-2xx
        answer and ``TransportError`` when the manager is unreachable."""
        raise NotImplementedError(
            f"{type(self).__name__} does not declare SERVE")

    def events_version_cached(self, max_age: float) -> int:
        """Global events version, amortized across every CR on the endpoint
        via the shared channel's memo cache: at most one probe request per
        ``max_age`` window however many slices consult it (requires
        Capability.WATCH)."""
        fetch = lambda: self.watch_events(since=-1)  # since=-1: always 200
        channel = getattr(self.client, "channel", None)
        if channel is None:
            return fetch()
        return channel.memo("events_version", max_age, fetch)

    def watch_push_healthy(self, window: float) -> bool:
        """True iff the endpoint's dedicated watcher (wakeup cadence) proved
        itself alive within the last ``window`` seconds — it stamps the
        shared channel's heartbeat on every successful long-poll cycle.
        False (no shared channel, no watcher yet, stale heartbeat) means
        push delivery cannot be relied on and the caller must fetch events
        itself."""
        channel = getattr(self.client, "channel", None)
        if channel is None:
            return False
        return time.time() - getattr(channel, "watch_heartbeat", 0.0) <= window


def normalized_queue_load(q: Optional[Dict[str, int]]) -> Optional[float]:
    """The one definition of 'how loaded is this resource': (queued +
    running) / slots from a ``queue_load()`` answer, or None when the
    answer is absent or useless.  Scheduler ranking, slice planning, and
    the controller's rebalancing target all score through here."""
    if not q or not q.get("slots"):
        return None
    return (q["queued"] + q["running"]) / q["slots"]


def resolve_adapter(adapters: Mapping[str, Type[ResourceAdapter]],
                    image: str) -> Type[ResourceAdapter]:
    """Adapter lookup by controller image ("slurmpod:0.1" -> SlurmAdapter).

    The single place the image-tag convention lives; every consumer
    (controller pod, scheduler, Bridge facade) resolves through here and gets
    the same error for an unknown image.
    """
    base_image = image.split(":")[0]
    try:
        return adapters[base_image]
    except KeyError:
        raise KeyError(
            f"no controller implementation for image {image!r}") from None


class SubmitError(RuntimeError):
    """Submission rejected by the resource manager (4xx/5xx, quota, ...)."""


class InvokeError(RuntimeError):
    """A serve-mode request reached the manager but was refused or failed
    (replica unready, handler crash, job gone).  Distinct from
    ``TransportError`` — the HTTP round-trip itself succeeded.  Carries the
    HTTP status so routers can tell "unready, retry elsewhere" (503) from
    "handler bug" (500)."""

    def __init__(self, status: int, detail: str = ""):
        super().__init__(f"invoke failed ({status}): {detail}")
        self.status = status
        self.detail = detail


class SimulatedCluster:
    """Bounded-slot job executor with a scheduler thread."""

    def __init__(self, name: str, slots: int = 4, default_duration: float = 0.05,
                 payload: Optional[Payload] = None, id_prefix: str = "",
                 start_numbering: int = 1000):
        self.name = name
        self.slots = slots
        self.default_duration = default_duration
        self.payload = payload or sleep_payload
        self.id_prefix = id_prefix
        self.jobs: Dict[str, ClusterJob] = {}
        # power_off(): the whole resource died — live jobs fail, nothing
        # schedules, queue_load reports zero capacity
        self.powered_off = False
        # staged files visible to jobs (upload/download area; LSF-style)
        self.files: Dict[str, bytes] = {}
        self._next_id = start_numbering
        self._lock = threading.RLock()
        # monotonically increasing events version: bumped (under the lock)
        # on EVERY job state transition; watchers long-poll it via the
        # condition so a ``GET /jobs/events?since=`` wakes on the change
        self._events_version = 0
        self._events_cv = threading.Condition(self._lock)
        # bounded event ring: (version, job_id, canonical_state) per bump,
        # job_id None for job-less bumps (shutdown).  Lets a watcher ask
        # "WHAT changed since v", not just "did anything change"; when the
        # ring no longer covers ``since`` the payload answer degrades to
        # "unknown" and consumers fall back to a status poll
        self._events_ring: "deque[Tuple[int, Optional[str], str]]" = deque(
            maxlen=4096)
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self._sched = threading.Thread(target=self._schedule_loop, daemon=True,
                                       name=f"{name}-sched")
        self._sched.start()

    # -- events version (watch/long-poll substrate) -------------------------

    def _bump_events(self, job: Optional[ClusterJob] = None) -> None:
        """Publish one state transition to watchers.  Caller holds _lock."""
        self._events_version += 1
        if job is not None:
            job.events_stamp = self._events_version
        self._events_ring.append((self._events_version,
                                  job.id if job is not None else None,
                                  job.state if job is not None else ""))
        self._events_cv.notify_all()

    def events_version(self) -> int:
        with self._lock:
            return self._events_version

    def wait_events(self, since: int, timeout: float = 0.0,
                    ids: Optional[List[str]] = None) -> "tuple[int, bool]":
        """Long-poll primitive: block until an event relevant to ``ids``
        (any event when ``ids`` is None; a vanished id counts as changed)
        is newer than ``since``, or ``timeout`` elapses.  Returns
        (current global version, relevant_change_seen)."""
        def relevant() -> bool:
            if ids is None:
                return self._events_version > since
            return any(j is None or j.events_stamp > since
                       for j in (self.jobs.get(i) for i in ids))

        deadline = time.time() + max(timeout, 0.0)
        with self._events_cv:
            changed = relevant()
            while not changed:
                remaining = deadline - time.time()
                if remaining <= 0:
                    break
                self._events_cv.wait(remaining)
                changed = relevant()
            return self._events_version, changed

    def wait_events_payload(self, since: int, timeout: float = 0.0,
                            ids: Optional[List[str]] = None
                            ) -> "tuple[int, bool, Optional[List[Tuple[str, str]]]]":
        """``wait_events`` plus the WHAT: returns (version, changed, events)
        where ``events`` lists ``(job_id, state)`` for every relevant
        transition in ``(since, version]`` — deduplicated, latest state per
        id — or None when the bounded ring no longer covers that range (or a
        job-less wildcard bump falls inside it), meaning the caller must
        re-poll statuses instead of trusting the enumeration."""
        version, changed = self.wait_events(since, timeout, ids)
        if not changed:
            return version, False, []
        with self._lock:
            return self._events_version, True, self._events_payload(since, ids)

    def _events_payload(self, since: int,
                        ids: Optional[List[str]]) -> Optional[List[Tuple[str, str]]]:
        """Enumerate ring events newer than ``since`` (caller holds _lock).
        None == coverage unknown."""
        ring = self._events_ring
        if not ring or ring[0][0] > max(since, 0) + 1:
            # the ring starts after ``since``: overwritten entries may hide
            # transitions we can no longer enumerate
            return None
        latest: Dict[str, str] = {}
        for version, jid, state in ring:
            if version <= since:
                continue
            if jid is None:
                return None  # wildcard bump: scope unknown
            latest[jid] = state
        if ids is not None:
            want = set(ids)
            return [(j, s) for j, s in latest.items() if j in want]
        return list(latest.items())

    # -- control surface (what REST facades call) ---------------------------

    def submit(self, script: str, properties: Dict[str, str],
               params: Dict[str, str]) -> ClusterJob:
        with self._lock:
            jid = f"{self.id_prefix}{self._next_id}"
            self._next_id += 1
            job = ClusterJob(id=jid, script=script, properties=dict(properties or {}),
                             params=dict(params or {}))
            self.jobs[jid] = job
            self._bump_events(job)
            return job

    def get(self, job_id: str) -> Optional[ClusterJob]:
        with self._lock:
            return self.jobs.get(job_id)

    def cancel(self, job_id: str) -> bool:
        return self.cancel_if_live(job_id) != "absent"

    def cancel_if_live(self, job_id: str) -> str:
        """Cancel with the state race resolved ATOMICALLY under the lock:
        returns "absent", "terminal" (the job finished before the cancel
        landed — REST facades answer 409 Conflict, not 500), or "cancelled".
        """
        with self._lock:
            job = self.jobs.get(job_id)
            if job is None:
                return "absent"
            if job.state in TERMINAL:
                return "terminal"
            if job.state == QUEUED:
                job.state = CANCELLED
                job.end_time = time.time()
                self._bump_events(job)
                return "cancelled"
        job._cancel.set()
        return "cancelled"

    # -- serve-mode surface (health + invoke, shared by the REST dialects) --

    def serve_health(self, job_id: str) -> "tuple[int, Dict[str, Any]]":
        """(http_status, body) for a replica health probe: 200 iff the job is
        RUNNING with its handler installed and not flagged unhealthy."""
        job = self.get(job_id)
        if job is None:
            return 404, {"error": f"job {job_id} not found"}
        if (job.state != RUNNING or job.handler is None
                or job.unhealthy.is_set()):
            return 503, {"status": "unready", "state": job.state}
        return 200, {"status": "ok", "state": job.state}

    def serve_invoke(self, job_id: str, body: Any) -> "tuple[int, Any]":
        """(http_status, response_body) for one request to a replica.  The
        handler runs OUTSIDE the cluster lock — requests are the data plane
        and must not serialize against the scheduler."""
        job = self.get(job_id)
        if job is None:
            return 404, {"error": f"job {job_id} not found"}
        handler = job.handler
        if job.state != RUNNING or handler is None or job.unhealthy.is_set():
            return 503, {"error": "replica unready", "state": job.state}
        with self._lock:
            job.invocations += 1
        try:
            return 200, handler(body)
        except Exception as e:
            return 500, {"error": f"{type(e).__name__}: {e}"}

    def queue_load(self) -> Dict[str, int]:
        with self._lock:
            if self.powered_off:
                # a dead resource has no schedulable capacity: slots=0 makes
                # normalized_queue_load() return None, so planners skip it
                return {"queued": 0, "running": 0, "slots": 0}
            q = sum(1 for j in self.jobs.values() if j.state == QUEUED)
            r = sum(1 for j in self.jobs.values() if j.state == RUNNING)
        return {"queued": q, "running": r, "slots": self.slots}

    def power_off(self, reason: str = "resource powered off") -> None:
        """Hard-kill the whole resource: every live job fails NOW (their
        worker threads observe _cancel, but the terminal state is already
        set and _run_job must not overwrite it) and nothing schedules until
        ``power_on()``.  Chaos tests combine this with a FaultProfile
        blackout on the REST facade to simulate a dead endpoint whose work
        is really gone."""
        with self._lock:
            self.powered_off = True
            for job in self.jobs.values():
                if job.state not in TERMINAL:
                    job.state = FAILED
                    job.end_time = time.time()
                    job.reason = reason
                    job._cancel.set()
                    self._bump_events(job)

    def power_on(self) -> None:
        with self._lock:
            self.powered_off = False

    def upload(self, name: str, data: bytes) -> None:
        with self._lock:
            self.files[name] = bytes(data)

    def download(self, name: str) -> Optional[bytes]:
        with self._lock:
            return self.files.get(name)

    def shutdown(self) -> None:
        self._stop.set()
        for j in list(self.jobs.values()):
            j._cancel.set()
        with self._lock:
            self._bump_events()  # release any in-flight long-poll waiters
        self._sched.join(timeout=2)

    # -- scheduler --------------------------------------------------------

    def _schedule_loop(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                # reap finished workers — the list must not grow with job count
                self._threads = [t for t in self._threads if t.is_alive()]
                running = sum(1 for j in self.jobs.values() if j.state == RUNNING)
                free = 0 if self.powered_off else self.slots - running
                to_start = [j for j in sorted(self.jobs.values(),
                                              key=lambda j: j.submit_time)
                            if j.state == QUEUED][:max(free, 0)]
                for job in to_start:
                    job.state = RUNNING
                    job.start_time = time.time()
                    self._bump_events(job)
                    t = threading.Thread(target=self._run_job, args=(job,),
                                         daemon=True, name=f"{self.name}-{job.id}")
                    self._threads.append(t)
                    t.start()
            time.sleep(0.005)

    def _run_job(self, job: ClusterJob) -> None:
        try:
            code = self.payload(job, self)
        except Exception as e:  # payload crash == job failure
            job.reason = f"{type(e).__name__}: {e}"
            code = 1
        with self._lock:
            if job.state in TERMINAL:
                # power_off() (or another out-of-band kill) already decided
                # this job's fate while the payload was unwinding — a late
                # COMPLETED must not resurrect a job the bridge saw FAILED
                return
            job.exit_code = code
            job.end_time = time.time()
            if job._cancel.is_set() or code == -1:
                job.state = CANCELLED
            elif code == 0:
                job.state = COMPLETED
            else:
                job.state = FAILED
            self._bump_events(job)
