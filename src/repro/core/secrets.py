"""Kubernetes-Secret analogue.

Paper §4: "Credentials to access the external resources as well as object
storage are accessible as Kubernetes secrets mounted in a volume by the pod."

Secrets live in the store under a name; a controller pod *mounts* a secret,
receiving a read-only dict.  Secret values never appear in BridgeJob specs or
config maps (only the secret *name* does), matching the paper's separation.
"""
from __future__ import annotations

import threading
from typing import Dict, Mapping, Optional
from types import MappingProxyType


class SecretNotFound(KeyError):
    pass


class SecretStore:
    def __init__(self) -> None:
        self._secrets: Dict[str, Dict[str, str]] = {}
        self._lock = threading.RLock()

    def create(self, name: str, data: Dict[str, str]) -> None:
        with self._lock:
            self._secrets[name] = dict(data)

    def mount(self, name: str) -> Mapping[str, str]:
        """Read-only view, as a mounted volume would provide."""
        with self._lock:
            if name not in self._secrets:
                raise SecretNotFound(name)
            return MappingProxyType(dict(self._secrets[name]))

    def delete(self, name: str) -> None:
        with self._lock:
            self._secrets.pop(name, None)

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._secrets
