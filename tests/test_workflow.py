"""Workflow integration (paper §6, Fig. 4): three-step bridge pipeline."""
import json

import pytest

from repro.core import BridgeEnvironment, IMAGES, URLS
from repro.workflows.pipeline import (Pipeline, PipelineError, PipelineOp,
                                      bridge_pipeline)


@pytest.fixture()
def env():
    with BridgeEnvironment(default_duration=0.05) as e:
        yield e


def test_three_step_pipeline_slurm(env):
    env.s3.put("mys3bucket", "slurmbatch.sh", b"#!/bin/bash\nsrun job\n")
    pipe = bridge_pipeline(
        env, "wfjob",
        resourceURL=URLS["slurm"], resourcesecret="slurm-secret",
        script="mys3bucket:slurmbatch.sh", scriptlocation="s3",
        docker=IMAGES["slurm"],
        jobproperties={"NodesNumber": "1", "Queue": "V100",
                       "OutputFileName": "slurmjob.out"},
    )
    results = pipe.run()
    assert results["invokeop"]["jobStatus"] == "DONE"
    assert results["cleanop"] == "cleaned"
    # config map cleaned up
    assert not env.statestore.exists("default/wfjob-bridge-cm")


def test_three_step_pipeline_lsf_output_upload(env):
    """LSF supports file download: outputs land in S3 via the pipeline."""
    pipe = bridge_pipeline(
        env, "wfjob-lsf",
        resourceURL=URLS["lsf"], resourcesecret="lsf-secret",
        script="bsub payload", scriptlocation="inline", docker=IMAGES["lsf"],
        jobproperties={"OutputFileName": "lsfjob.out"},
        s3uploadfiles="lsfjob.out", s3uploadbucket="outputs",
    )
    results = pipe.run()
    assert results["invokeop"]["jobStatus"] == "DONE"
    assert any(k.endswith("lsfjob.out") for k in env.s3.list("outputs"))


def test_pipeline_is_backend_agnostic(env):
    """Same pipeline code, different docker parameter (paper: 'can be used
    with any of the Bridge operator pods')."""
    for kind in ("lsf", "ray", "quantum"):
        pipe = bridge_pipeline(
            env, f"wf-{kind}", resourceURL=URLS[kind],
            resourcesecret=f"{kind}-secret", script=f"payload-{kind}",
            scriptlocation="inline", docker=IMAGES[kind])
        results = pipe.run()
        assert results["invokeop"]["jobStatus"] == "DONE", kind


def test_pipeline_as_subworkflow(env):
    """A bridge pipeline composes as a sub-workflow of a bigger pipeline."""
    inner = bridge_pipeline(env, "inner", resourceURL=URLS["slurm"],
                            resourcesecret="slurm-secret", script="w",
                            scriptlocation="inline", docker=IMAGES["slurm"])
    outer = Pipeline("outer")
    pre = outer.add(PipelineOp("prepare", lambda ctx: "prepared"))
    sub = outer.add_subpipeline(inner, after=["prepare"])
    post = outer.add(PipelineOp(
        "report", lambda ctx: ctx["results"][sub.name]["invokeop"]["jobStatus"]))
    post.after_op(sub)
    results = outer.run()
    assert results["report"] == "DONE"


def test_pipeline_cycle_detection():
    p = Pipeline("cyclic")
    a = p.add(PipelineOp("a", lambda ctx: 1))
    b = p.add(PipelineOp("b", lambda ctx: 2))
    a.after.append("b")
    b.after.append("a")
    with pytest.raises(PipelineError, match="cycle"):
        p.run()


def test_pipeline_retries(env):
    calls = {"n": 0}

    def flaky(ctx):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    p = Pipeline("retry")
    p.add(PipelineOp("flaky", flaky, retries=3))
    assert p.run()["flaky"] == "ok"
    assert calls["n"] == 3


def test_pipeline_caching():
    calls = {"n": 0}

    def op(ctx):
        calls["n"] += 1
        return calls["n"]

    p = Pipeline("cached")
    p.add(PipelineOp("op", op, max_cache_staleness="P30D"))
    assert p.run()["op"] == 1
    assert p.run()["op"] == 1  # cached
    p2 = Pipeline("uncached")
    p2.add(PipelineOp("op", op, max_cache_staleness="P0D"))
    assert p2.run()["op"] == 2
    assert p2.run()["op"] == 3
