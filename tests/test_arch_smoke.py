"""Per-architecture smoke tests: reduced same-family configs, one forward/
train step + prefill/decode on CPU; output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ARCH_IDS, SHAPES, ShapeConfig, cells,
                                get_config, get_smoke_config)
from repro.models import decoding as DEC
from repro.models import transformer as TF
from repro.steps import init_model, make_synthetic_batch

TRAIN = ShapeConfig("smoke_train", 32, 2, "train")
DECODE = ShapeConfig("smoke_dec", 32, 2, "decode")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    assert cfg.family == get_config(arch).family, "smoke must match family"
    defs, params = init_model(cfg, max_seq=64)
    batch = make_synthetic_batch(cfg, TRAIN)
    loss, metrics = TF.forward_train(params, cfg, batch, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    # gradients flow and are finite
    g = jax.grad(lambda p: TF.forward_train(p, cfg, batch, remat=False)[0])(
        params)
    leaves = jax.tree_util.tree_leaves(g)
    assert leaves and all(bool(jnp.all(jnp.isfinite(l))) for l in leaves), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistent(arch):
    """Greedy decode after prefill == teacher-forced argmax on the same
    prefix (cache correctness), for every family."""
    cfg = get_smoke_config(arch)
    _, params = init_model(cfg, max_seq=64)
    batch = make_synthetic_batch(cfg, TRAIN)
    pre = {k: v for k, v in batch.items() if k not in ("targets", "mask")}
    logits, cache = DEC.prefill(params, cfg, pre, max_len=48)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # one decode step
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = DEC.decode_step(params, cfg, cache, nxt)
    assert logits2.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2))), arch
    assert int(cache2["pos"][0]) == int(cache["pos"][0]) + 1


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "hymba-1.5b",
                                  "moonshot-v1-16b-a3b", "xlstm-125m"])
def test_decode_matches_teacher_forcing(arch):
    """Token-level check: running the full sequence through forward equals
    prefill(prefix) + decode(token) logits at the boundary."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity-based dropping differs between 9- and 8-token dispatch;
        # give enough capacity that no token drops (exactness requires it)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    _, params = init_model(cfg, max_seq=64)
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 9), 0, cfg.vocab,
                              jnp.int32)
    # full prefill of 9 tokens
    full_logits, _ = DEC.prefill(params, cfg, {"tokens": toks}, max_len=32)
    # prefill 8 + decode the 9th
    pre_logits, cache = DEC.prefill(params, cfg, {"tokens": toks[:, :8]},
                                    max_len=32)
    step_logits, _ = DEC.decode_step(params, cfg, cache, toks[:, 8:9])
    np.testing.assert_allclose(np.asarray(full_logits[:, -1], np.float32),
                               np.asarray(step_logits[:, -1], np.float32),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_decode_hymba():
    """Circular KV buffer: decode far past the window stays finite and
    position advances correctly."""
    cfg = get_smoke_config("hymba-1.5b")
    window = cfg.long_window  # 16
    _, params = init_model(cfg, max_seq=64)
    cache = DEC.init_cache(cfg, 1, max_len=64, window=window)
    tok = jnp.zeros((1, 1), jnp.int32)
    for i in range(window + 5):  # wrap the circular buffer
        logits, cache = DEC.decode_step(params, cfg, cache, tok, window=window)
    assert cache["k"].shape[2] == window
    assert int(cache["pos"][0]) == window + 5
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_cells_matrix():
    """The dry-run matrix: 40 total cells; long_500k runs only for
    sub-quadratic archs (2), is skipped for the other 8."""
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40
    runnable = [c for c in all_cells if c[2] == "run"]
    skipped = [c for c in all_cells if c[2].startswith("skip")]
    assert len(runnable) == 32
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s, _ in skipped)
    long_ok = {a for a, s, st in runnable if s == "long_500k"}
    assert long_ok == {"hymba-1.5b", "xlstm-125m"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_brief(arch):
    """Exact assigned values from the task brief."""
    brief = {
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }
    L, d, h, kv, ff, v = brief[arch]
    cfg = get_config(arch)
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch
    if arch == "moonshot-v1-16b-a3b":
        assert cfg.moe.n_experts == 64 and cfg.moe.top_k == 6
    if arch == "granite-moe-3b-a800m":
        assert cfg.moe.n_experts == 40 and cfg.moe.top_k == 8
    if arch == "hymba-1.5b":
        assert cfg.ssm.d_state == 16 and cfg.hybrid_parallel
    if arch == "gemma-2b":
        assert cfg.resolved_head_dim == 256
    if arch == "nemotron-4-340b":
        assert cfg.activation == "relu2"
    if arch == "whisper-large-v3":
        assert cfg.n_enc_layers == 32
