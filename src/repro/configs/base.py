"""Config system: architecture + shape definitions.

Every assigned architecture is a ``ModelConfig`` produced by a module in
``repro.configs``.  Shapes (the benchmark cells) are ``ShapeConfig``s; the
cross-product, with documented skips, forms the dry-run / roofline matrix.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # "dense" computes every expert for every token (tiny smoke configs only);
    # "dropping" is the GShard-style capacity-based dispatch (EP-shardable).
    routing_impl: str = "dropping"
    # pad expert WEIGHTS to this count (0 = no padding) so the expert axis
    # divides the mesh; padded experts are never routed to (§Perf: granite's
    # 40 experts pad to 48 for 16-way EP).
    n_experts_padded: int = 0

    @property
    def e_pad(self) -> int:
        return max(self.n_experts, self.n_experts_padded)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # "assoc": one associative scan over S — materializes (B,S,di,N); the
    # naive baseline.  "chunked": stream (B,chunk,di,N) tiles with a carried
    # state (the XLA mirror of kernels/ssm_scan.py) — §Perf optimization.
    scan_impl: str = "assoc"
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclass(frozen=True)
class XLSTMConfig:
    # every `slstm_every`-th block is an sLSTM block (xLSTM[m:s] ratio);
    # 0 disables sLSTM entirely.
    slstm_every: int = 4
    proj_factor: float = 2.0  # mLSTM up-projection factor


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    activation: str = "swiglu"  # swiglu | geglu | relu2 | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scaling
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid (hymba): every block runs attention and mamba mixers in parallel
    hybrid_parallel: bool = False
    # encoder config (whisper): decoder uses the fields above
    n_enc_layers: int = 0
    enc_frames: int = 1500  # stub audio frontend: frames fed to the encoder
    # vlm stub frontend: number of image-embedding tokens prepended
    n_img_tokens: int = 0
    # sliding window (tokens) used in `long` shapes by hybrid archs; 0 = full
    long_window: int = 0
    # layer iteration: "scan" (homogeneous stacks) or "unroll"
    layer_impl: str = "scan"
    # attention implementation: xla | blockwise | pallas | pallas_interpret
    # (blockwise = q-chunked XLA flash — the dry-run-able stand-in for the
    #  Pallas kernel; bounds the S^2 working set)
    attention_impl: str = "xla"
    # q-chunk size for attention_impl="blockwise"
    attention_block_q: int = 512
    # "auto": XLA decides activations; "seq": constrain attention q/scores
    # to be sequence-sharded over "model" (the §Perf fix for MQA archs whose
    # few heads cannot use a 16-way TP axis)
    attention_partitioning: str = "auto"
    # shard the decode KV cache on the SEQUENCE dim over "model"
    # (flash-decode style; the §Perf fix for GQA kv_heads < mesh model axis)
    decode_seq_shard: bool = False
    dtype: str = "bfloat16"
    # notes recorded in DESIGN/EXPERIMENTS
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """Archs eligible for the long_500k shape (SSM / hybrid / linear-attn)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    def n_params(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        d, h = self.d_model, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        attn = d * (nq * h) + 2 * d * (nkv * h) + (nq * h) * d

        def mlp_params(dff: int) -> int:
            if self.activation in ("swiglu", "geglu"):
                return 3 * self.d_model * dff
            return 2 * self.d_model * dff

        per_layer = attn
        if self.family == "moe":
            assert self.moe is not None
            per_layer += self.moe.n_experts * mlp_params(self.moe.d_ff_expert)
            per_layer += self.moe.n_shared_experts * mlp_params(self.moe.d_ff_expert)
            per_layer += d * self.moe.n_experts  # router
        elif self.family == "ssm":
            per_layer = 0  # xlstm: no standard attention
            assert self.xlstm is not None
            dp = int(self.xlstm.proj_factor * d)
            per_layer += 2 * d * dp + dp * d + 3 * dp * h  # mlstm proj + qkv-ish
        else:
            per_layer += mlp_params(self.d_ff) if self.d_ff else 0
        if self.hybrid_parallel and self.ssm is not None:
            di = self.ssm.d_inner(d)
            per_layer += 2 * d * di + di * d + di * (self.ssm.d_conv + 2 * self.ssm.d_state + 2)
        total = self.n_layers * per_layer
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.n_enc_layers:
            enc_attn = 4 * d * d
            total += self.n_enc_layers * (enc_attn + mlp_params(self.d_ff))
            total += self.n_layers * 4 * d * d  # decoder cross-attention
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.n_params()
        assert self.moe is not None
        full = self.n_params()

        def mlp_params(dff: int) -> int:
            if self.activation in ("swiglu", "geglu"):
                return 3 * self.d_model * dff
            return 2 * self.d_model * dff

        all_exp = self.n_layers * self.moe.n_experts * mlp_params(self.moe.d_ff_expert)
        act_exp = self.n_layers * (self.moe.top_k + self.moe.n_shared_experts) * mlp_params(
            self.moe.d_ff_expert
        )
        return full - all_exp + act_exp


# ---------------------------------------------------------------------------
# Shapes (benchmark cells)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS: List[str] = [
    "phi-3-vision-4.2b",
    "hymba-1.5b",
    "moonshot-v1-16b-a3b",
    "granite-moe-3b-a800m",
    "phi3-mini-3.8b",
    "nemotron-4-340b",
    "granite-3-8b",
    "gemma-2b",
    "whisper-large-v3",
    "xlstm-125m",
]

_MODULE_FOR_ARCH = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str, **overrides: Any) -> ModelConfig:
    if arch not in _MODULE_FOR_ARCH:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULE_FOR_ARCH[arch])
    cfg: ModelConfig = mod.CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(arch: str, **overrides: Any) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(_MODULE_FOR_ARCH[arch])
    cfg: ModelConfig = mod.SMOKE
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def cells(include_skipped: bool = False) -> List[Tuple[str, str, str]]:
    """All (arch, shape, status) dry-run cells.

    status: "run" or "skip:<reason>".  long_500k is skipped for pure
    full-attention archs (see DESIGN.md §Arch-applicability).
    """
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            status = "run"
            if shape.name == "long_500k" and not cfg.is_subquadratic:
                status = "skip:full-attention arch, 524k dense KV is quadratic-regime"
            if status == "run" or include_skipped:
                out.append((arch, shape.name, status))
    return out
