"""Simulated slurmrestd (REST dialect per Slurm's v0.0.37-era API).

Dialect notes (paper §5.2): numeric job ids; sacct-style states; the Slurm
REST API tested in the paper (21.08) does NOT support file upload/download —
the adapter honestly returns unsupported for both, which exercises the
bridge's "stage via S3 + remote path" alternative.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core.backends import base as B
from repro.core.rest import FaultProfile, HttpResponse, RestServer

_STATE_TO_SLURM = {
    B.QUEUED: "PENDING",
    B.RUNNING: "RUNNING",
    B.COMPLETED: "COMPLETED",
    B.FAILED: "FAILED",
    B.CANCELLED: "CANCELLED",
}
_SLURM_TO_STATE = {v: k for k, v in _STATE_TO_SLURM.items()}


def make_server(cluster: B.SimulatedCluster, token: str = "",
                fault: FaultProfile = None) -> RestServer:
    srv = RestServer(token=token, fault=fault)

    def submit(_groups, body) -> HttpResponse:
        body = body or {}
        if "script" not in body:
            return HttpResponse(400, {"error": "no script"})
        # sbatch --array analogue: one request fans out N tasks, each a full
        # job with SLURM_ARRAY_TASK_ID and optional per-index params;
        # array_start offsets the task ids (sbatch --array=lo-hi), which is
        # how a placement slice submits its global index range in one call
        n = int(body.get("array_size", 0) or 0)
        if n > 1:
            per_index = body.get("params_by_index") or []
            base = int(body.get("array_start", 0) or 0)
            task_ids = []
            for i in range(n):
                params = dict(body.get("params", {}))
                if i < len(per_index):
                    params.update(per_index[i])
                params.setdefault("SLURM_ARRAY_TASK_ID", str(base + i))
                job = cluster.submit(body["script"], body.get("job", {}),
                                     params)
                task_ids.append(int(job.id))
            return HttpResponse(200, {"job_id": task_ids[0],
                                      "task_ids": task_ids})
        job = cluster.submit(body["script"], body.get("job", {}),
                             body.get("params", {}))
        return HttpResponse(200, {"job_id": int(job.id)})

    def _job_record(job: B.ClusterJob) -> dict:
        s = job.snapshot()
        return {
            "job_id": int(job.id),
            "job_state": _STATE_TO_SLURM[job.state],
            "start_time": s["start_time"], "end_time": s["end_time"],
            "exit_code": s["exit_code"], "state_reason": s["reason"],
        }

    def get_job(groups, _body) -> HttpResponse:
        job = cluster.get(groups["id"])
        if job is None:
            return HttpResponse(404, {"error": "job not found"})
        return HttpResponse(200, {"jobs": [_job_record(job)]})

    def get_jobs(groups, _body) -> HttpResponse:
        # squeue -j id1,id2 analogue: one request answers many ids; an id
        # slurmctld no longer knows yields a record with job_state=null
        ids = [s for s in groups.get("ids", "").split(",") if s]
        if not ids:
            return HttpResponse(400, {"error": "ids query param required"})
        records = []
        for jid in ids:
            job = cluster.get(jid)
            records.append(_job_record(job) if job is not None
                           else {"job_id": jid, "job_state": None})
        return HttpResponse(200, {"jobs": records})

    def cancel(groups, _body) -> HttpResponse:
        # scancel of an already-finished job: 409 Conflict (the cancel lost
        # the race against the terminal transition), never a 500
        outcome = cluster.cancel_if_live(groups["id"])
        if outcome == "absent":
            return HttpResponse(404, {"error": "job not found"})
        if outcome == "terminal":
            return HttpResponse(409, {"error": "job already terminal"})
        return HttpResponse(200, {})

    def events(groups, _body, budget) -> HttpResponse:
        # long-poll watch: answer as soon as an event relevant to ``ids``
        # (any event without ids) is newer than ``since``; 204 when nothing
        # changed within min(wait, client timeout) — "no content" is the
        # cheap steady-state answer that lets a watcher skip its status poll
        since = int(groups.get("since", "-1") or -1)
        ids = [s for s in groups.get("ids", "").split(",") if s] or None
        wait = min(float(groups.get("wait", "0") or 0), budget)
        version, changed, payload = cluster.wait_events_payload(
            since, timeout=wait, ids=ids)
        if not changed:
            return HttpResponse(204)
        body: Dict[str, Any] = {"version": version}
        if payload is not None:
            # WHICH jobs changed, in dialect vocabulary; omitted when the
            # cluster's bounded event ring no longer covers ``since`` (the
            # client must re-poll statuses instead)
            body["events"] = [{"job_id": int(jid),
                               "job_state": _STATE_TO_SLURM[state]}
                              for jid, state in payload]
        return HttpResponse(200, body)

    def health(groups, _body) -> HttpResponse:
        status, payload = cluster.serve_health(groups["id"])
        return HttpResponse(status, payload)

    def invoke(groups, body) -> HttpResponse:
        status, payload = cluster.serve_invoke(groups["id"], body)
        return HttpResponse(status, payload)

    def ping(_groups, _body) -> HttpResponse:
        return HttpResponse(200, {"pings": [{"ping": "UP"}]})

    def partitions(_groups, _body) -> HttpResponse:
        load = cluster.queue_load()
        return HttpResponse(200, {"partitions": [dict(name="batch", **load)]})

    srv.route("POST", "/slurm/v0.0.37/job/submit", submit)
    srv.route("GET", "/slurm/v0.0.37/jobs/events", events, kind="watch")
    srv.route("GET", "/slurm/v0.0.37/jobs", get_jobs)
    srv.route("GET", "/slurm/v0.0.37/job/{id}/health", health)
    srv.route("POST", "/slurm/v0.0.37/job/{id}/invoke", invoke)
    srv.route("GET", "/slurm/v0.0.37/job/{id}", get_job)
    srv.route("DELETE", "/slurm/v0.0.37/job/{id}", cancel)
    srv.route("GET", "/slurm/v0.0.37/ping", ping)
    srv.route("GET", "/slurm/v0.0.37/partitions", partitions)
    return srv


class SlurmAdapter(B.ResourceAdapter):
    image = "slurmpod"
    # Slurm REST 21.08: no file staging (paper §5.2), but sbatch arrays,
    # scancel-of-pending, squeue-style multi-id status, and an events-
    # version long-poll are native
    capabilities = frozenset({
        B.Capability.CANCEL, B.Capability.CANCEL_QUEUED,
        B.Capability.QUEUE_LOAD, B.Capability.NATIVE_ARRAYS,
        B.Capability.BATCH_STATUS, B.Capability.WATCH,
        B.Capability.SERVE,
    })

    def submit(self, script, properties, params) -> str:
        r = self.client.post("/slurm/v0.0.37/job/submit",
                             {"script": script, "job": properties, "params": params})
        if not r.ok:
            raise B.SubmitError(f"slurm submit: HTTP {r.status} {r.json}")
        return str(r.json["job_id"])

    def submit_array(self, script, properties, params_by_index,
                     start_index=0) -> list:
        r = self.client.post("/slurm/v0.0.37/job/submit",
                             {"script": script, "job": properties,
                              "array_size": len(params_by_index),
                              "array_start": start_index,
                              "params_by_index": params_by_index})
        if not r.ok:
            raise B.SubmitError(f"slurm array submit: HTTP {r.status} {r.json}")
        return [str(t) for t in r.json["task_ids"]]

    def resubmit_index(self, script, properties, params, index) -> str:
        # keep the retried index indistinguishable from its original run
        params = dict(params)
        params.setdefault("SLURM_ARRAY_TASK_ID", str(index))
        return self.submit(script, properties, params)

    @staticmethod
    def _record_to_info(j: Dict[str, Any]) -> Dict[str, Any]:
        if j.get("job_state") is None:
            return {"state": B.FAILED, "reason": "job vanished from slurmctld"}
        return {
            "state": _SLURM_TO_STATE.get(j["job_state"], B.FAILED),
            "start_time": j.get("start_time"), "end_time": j.get("end_time"),
            "reason": j.get("state_reason", ""),
        }

    def status(self, job_id: str) -> Dict[str, Any]:
        r = self.client.get(f"/slurm/v0.0.37/job/{job_id}")
        if r.status == 404:
            return {"state": B.FAILED, "reason": "job vanished from slurmctld"}
        if not r.ok:
            raise B.SubmitError(f"slurm status: HTTP {r.status}")
        return self._record_to_info(r.json["jobs"][0])

    def status_batch(self, job_ids) -> list:
        r = self.client.get("/slurm/v0.0.37/jobs?ids=" + ",".join(job_ids))
        if not r.ok:
            raise B.SubmitError(f"slurm batch status: HTTP {r.status}")
        by_id = {str(j["job_id"]): j for j in r.json["jobs"]}
        # align with the request order; an id the server skipped == vanished
        return [self._record_to_info(by_id.get(str(jid), {}))
                for jid in job_ids]

    def cancel(self, job_id: str) -> None:
        self.client.delete(f"/slurm/v0.0.37/job/{job_id}")

    def probe_health(self, job_id: str) -> bool:
        return self.client.get(f"/slurm/v0.0.37/job/{job_id}/health").ok

    def invoke(self, job_id: str, payload: Any) -> Any:
        r = self.client.post(f"/slurm/v0.0.37/job/{job_id}/invoke", payload)
        if not r.ok:
            detail = r.json.get("error", "") if isinstance(r.json, dict) else ""
            raise B.InvokeError(r.status, detail)
        return r.json

    def watch_events(self, since=-1, ids=None, wait=0.0):
        q = f"since={since}"
        if ids:
            q += "&ids=" + ",".join(ids)
        if wait:
            q += f"&wait={wait}"
        r = self.client.get("/slurm/v0.0.37/jobs/events?" + q)
        if r.status == 204:
            return None
        if not r.ok:
            raise B.SubmitError(f"slurm events: HTTP {r.status}")
        return int(r.json["version"])

    def watch_events_ids(self, since=-1, ids=None, wait=0.0):
        q = f"since={since}"
        if ids:
            q += "&ids=" + ",".join(ids)
        if wait:
            q += f"&wait={wait}"
        r = self.client.get("/slurm/v0.0.37/jobs/events?" + q)
        if r.status == 204:
            return None
        if not r.ok:
            raise B.SubmitError(f"slurm events: HTTP {r.status}")
        events = r.json.get("events")
        if events is not None:
            events = [(str(e["job_id"]),
                       _SLURM_TO_STATE.get(e["job_state"], B.FAILED))
                      for e in events]
        return int(r.json["version"]), events

    def queue_load(self) -> Optional[Dict[str, int]]:
        r = self.client.get("/slurm/v0.0.37/partitions")
        if not r.ok:
            return None
        p = r.json["partitions"][0]
        return {"queued": p["queued"], "running": p["running"], "slots": p["slots"]}
