"""whisper-large-v3 [audio]: encoder-decoder, conv frontend STUB.

[arXiv:2212.04356; unverified]  32L (decoder) d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866; 32 encoder layers; input_specs() supplies precomputed
mel-frame embeddings (1500 x d_model) per the brief (frontend is a stub).
rope_theta=0 -> learned absolute position embeddings (whisper style).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51_866,
    activation="gelu",
    norm="layernorm",
    rope_theta=0.0,
    n_enc_layers=32,
    enc_frames=1500,
    source="arXiv:2212.04356",
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    activation="gelu",
    norm="layernorm",
    rope_theta=0.0,
    n_enc_layers=2,
    enc_frames=16,
    dtype="float32",
)
