import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST precede every other import (jax locks the device
# count at first init).  This module is the multi-pod dry-run launcher:
# for every (architecture x input-shape) cell it lowers + compiles the
# pjit step on the production mesh and records memory / cost / collective
# analysis for EXPERIMENTS.md (§Dry-run, §Roofline).
"""
Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.compat import cost_analysis_dict, jit_sharded, use_mesh
from repro.configs.base import ARCH_IDS, SHAPES, cells, get_config
from repro.launch.analysis import (collective_stats, memory_stats_dict,
                                   model_flops, roofline_terms)
from repro.launch.mesh import HW, make_production_mesh
from repro.steps import make_step

# per-arch strategy: 2-D weight sharding where TP-only cannot fit HBM
TRAIN_STRATEGY = {
    "nemotron-4-340b": "fsdp_tp",
}
# perf-config overrides installed by the §Perf hillclimbs (see EXPERIMENTS.md
# §Perf for the hypothesis->change->measure log).  Keyed by (arch, shape);
# reproduce with tools/perf_iter.py or --perf here.
def _perf_overrides() -> Dict[Any, Dict[str, Any]]:
    import dataclasses as _dc

    from repro.configs.base import get_config as _gc

    gm = _gc("granite-moe-3b-a800m")
    hy = _gc("hymba-1.5b")
    return {
        ("granite-moe-3b-a800m", "train_4k"): {
            "moe": _dc.replace(gm.moe, routing_impl="ep_gather",
                               n_experts_padded=48),
            "attention_impl": "blockwise",  # deploy; probe with blockwise_u
            "attention_partitioning": "seq",
        },
        ("hymba-1.5b", "prefill_32k"): {
            "attention_partitioning": "seq",
            "attention_impl": "blockwise",
            "ssm": _dc.replace(hy.ssm, scan_impl="chunked", chunk=1024),
        },
        ("gemma-2b", "train_4k"): {
            "attention_partitioning": "seq",
        },
    }

# Accounting mode per arch.  "probe": compile the FULL config scanned (the
# compile-succeeds proof + memory analysis), then unrolled L=1/L=2 probes
# whose per-layer deltas extrapolate exact flops/bytes/collectives — XLA's
# cost analysis visits while-loop bodies ONCE, so a scanned module
# undercounts by ~L; unrolling the full stack is exact but compiles for
# minutes-to-hours on the big archs.  "direct": full unroll (xlstm's 12
# heterogeneous layers are unrolled by definition).
ACCOUNTING = {"xlstm-125m": "direct"}


def default_strategy(arch: str, shape_name: str) -> str:
    if SHAPES[shape_name].kind == "train":
        return TRAIN_STRATEGY.get(arch, "tp")
    return "tp"


def _compile_once(cfg, shape, mesh, strategy):
    t0 = time.time()
    bundle = make_step(cfg, mesh, shape, strategy=strategy)
    with use_mesh(mesh):
        jf = jit_sharded(bundle.fn, mesh,
                         in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnames=bundle.donate_argnames)
        lowered = jf.lower(*bundle.input_specs.values())
        compiled = lowered.compile()
    t = time.time() - t0
    cost = cost_analysis_dict(compiled)
    return {
        "compile_s": t,
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_stats(compiled.as_text()),
        "mem": memory_stats_dict(compiled.memory_analysis()),
    }


def _extrapolate(base: Dict, per_layer: Dict, n_extra: int) -> Dict[str, Any]:
    """base (L=1 probe) + n_extra * per-layer delta, per metric."""
    out = {"flops": base["flops"] + n_extra * per_layer["flops"],
           "bytes": base["bytes"] + n_extra * per_layer["bytes"]}
    operand, wire, counts = {}, {}, {}
    keys = set(base["coll"].operand_bytes) | set(per_layer["coll_operand"])
    for k in keys:
        operand[k] = int(base["coll"].operand_bytes.get(k, 0)
                         + n_extra * per_layer["coll_operand"].get(k, 0))
        wire[k] = int(base["coll"].wire_bytes.get(k, 0)
                      + n_extra * per_layer["coll_wire"].get(k, 0))
        counts[k] = int(base["coll"].counts.get(k, 0)
                        + n_extra * per_layer["coll_counts"].get(k, 0))
    out["collectives"] = {"counts": counts, "operand_bytes": operand,
                          "wire_bytes": wire,
                          "total_operand": sum(operand.values()),
                          "total_wire": sum(wire.values())}
    return out


def _layer_delta(p1: Dict, p2: Dict) -> Dict[str, Any]:
    d = {"flops": max(p2["flops"] - p1["flops"], 0.0),
         "bytes": max(p2["bytes"] - p1["bytes"], 0.0),
         "coll_operand": {}, "coll_wire": {}, "coll_counts": {}}
    keys = set(p1["coll"].operand_bytes) | set(p2["coll"].operand_bytes)
    for k in keys:
        d["coll_operand"][k] = max(p2["coll"].operand_bytes.get(k, 0)
                                   - p1["coll"].operand_bytes.get(k, 0), 0)
        d["coll_wire"][k] = max(p2["coll"].wire_bytes.get(k, 0)
                                - p1["coll"].wire_bytes.get(k, 0), 0)
        d["coll_counts"][k] = max(p2["coll"].counts.get(k, 0)
                                  - p1["coll"].counts.get(k, 0), 0)
    return d


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             strategy: Optional[str] = None, overrides: Optional[Dict] = None,
             verbose: bool = True, mode: Optional[str] = None) -> Dict[str, Any]:
    overrides = dict(overrides or {})
    cfg = get_config(arch, **overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    strategy = strategy or default_strategy(arch, shape_name)
    mode = mode or ACCOUNTING.get(arch, "probe")

    # 1) the production compile: FULL config exactly as deployed
    full = _compile_once(cfg, shape, mesh, strategy)

    # 2) accounting
    import dataclasses as _dc

    if mode == "direct":
        acc_cfg = _dc.replace(cfg, layer_impl="unroll") \
            if cfg.layer_impl != "unroll" else cfg
        direct = _compile_once(acc_cfg, shape, mesh, strategy) \
            if cfg.layer_impl != "unroll" else full
        acct = {"flops": direct["flops"], "bytes": direct["bytes"],
                "collectives": direct["coll"].to_dict()}
        probe_info = {"mode": "direct"}
    else:
        p1 = _compile_once(_dc.replace(cfg, layer_impl="unroll", n_layers=1),
                           shape, mesh, strategy)
        p2 = _compile_once(_dc.replace(cfg, layer_impl="unroll", n_layers=2),
                           shape, mesh, strategy)
        delta = _layer_delta(p1, p2)
        acct = _extrapolate(p1, delta, cfg.n_layers - 1)
        # (encdec note: probes replace only n_layers; the unrolled encoder
        #  stack stays full-size inside both probes, so its cost is exact.)
        probe_info = {"mode": "probe", "probe_flops": [p1["flops"], p2["flops"]],
                      "layer_flops": delta["flops"],
                      "probe_compile_s": [round(p1["compile_s"], 2),
                                          round(p2["compile_s"], 2)]}

    coll = acct["collectives"] if isinstance(acct["collectives"], dict) \
        else acct["collectives"]

    class _C:  # adapt dict back into the roofline interface
        total_operand = coll["total_operand"]
        total_wire = coll["total_wire"]

    terms = roofline_terms(acct["flops"], acct["bytes"], _C)
    n_chips = mesh.devices.size
    mf = model_flops(cfg, shape)
    mem = full["mem"]
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "strategy": strategy, "kind": shape.kind,
        "n_chips": n_chips,
        "compile_s": round(full["compile_s"], 2),
        "accounting": probe_info,
        "hlo_flops_per_dev": acct["flops"],
        "hlo_bytes_per_dev": acct["bytes"],
        "scanned_flops_per_dev": full["flops"],
        "collectives": coll,
        "collectives_scanned": full["coll"].to_dict(),
        "memory": mem,
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_dev": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips / acct["flops"])
        if acct["flops"] else None,
        "hbm_fit": (mem.get("peak_bytes_per_device", 0) <= HW["hbm_bytes"])
        if mem else None,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
    }
    if verbose:
        print(f"[dryrun] {arch:24s} {shape_name:12s} "
              f"{record['mesh']:8s} {strategy:8s} "
              f"compile={full['compile_s']:6.1f}s "
              f"flops/dev={acct['flops']:.3e} bytes/dev={acct['bytes']:.3e} "
              f"coll={coll['total_operand']:.3e}B "
              f"peakmem={mem.get('peak_bytes_per_device', 0)/2**30:.2f}GiB "
              f"dominant={terms['dominant']} "
              f"useful={record['useful_flops_ratio'] or 0:.2f}")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={acct['flops']:.4e} "
              f"bytes={acct['bytes']:.4e}")
    return record


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=ARCH_IDS)
    p.add_argument("--shape", choices=list(SHAPES))
    p.add_argument("--all", action="store_true", help="every runnable cell")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--strategy", choices=["tp", "fsdp_tp"])
    p.add_argument("--out", default="artifacts/dryrun")
    p.add_argument("--skip-existing", action="store_true")
    p.add_argument("--perf", action="store_true",
                   help="apply the §Perf hillclimb overrides where defined")
    args = p.parse_args()
    perf_map = _perf_overrides() if args.perf else {}

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    todo = []
    if args.all:
        for arch, shape_name, status in cells():
            todo.append((arch, shape_name))
    else:
        if not (args.arch and args.shape):
            p.error("--arch and --shape (or --all) required")
        todo.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi in meshes:
        mesh_tag = "multi" if multi else "single"
        os.makedirs(os.path.join(args.out, mesh_tag), exist_ok=True)
        for arch, shape_name in todo:
            path = os.path.join(args.out, mesh_tag,
                                f"{arch}__{shape_name}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] skip existing {path}")
                continue
            try:
                rec = run_cell(arch, shape_name, multi_pod=multi,
                               strategy=args.strategy,
                               overrides=perf_map.get((arch, shape_name)))
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception as e:
                traceback.print_exc()
                failures.append((mesh_tag, arch, shape_name, f"{type(e).__name__}: {e}"))
    if failures:
        print("\nFAILURES:")
        for f_ in failures:
            print(" ", f_)
        raise SystemExit(1)
    print("\nALL DRY-RUN CELLS OK")


if __name__ == "__main__":
    main()
