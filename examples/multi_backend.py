"""One generic programming model, four external resource managers — plus the
paper's §7 future work (load-aware placement) actually implemented.

  PYTHONPATH=src python examples/multi_backend.py
"""
from repro.core import (BridgeEnvironment, Candidate, IMAGES,
                        LoadAwareScheduler, URLS)


def main() -> None:
    with BridgeEnvironment(default_duration=0.2) as env:
        # the SAME payload dispatched to all four managers
        for kind in ("slurm", "lsf", "quantum", "ray"):
            spec = env.make_spec(kind, script=f"echo payload-for-{kind}",
                                 updateinterval=0.05)
            env.submit(f"job-{kind}", spec)
        for kind in ("slurm", "lsf", "quantum", "ray"):
            job = env.operator.wait_for(f"job-{kind}", timeout=30)
            print(f"{kind:8s} -> {job.status.state} "
                  f"(remote id {job.status.job_id})")

        # load-aware placement: saturate slurm, scheduler picks elsewhere
        for _ in range(10):
            env.clusters["slurm"].submit("hog", {"WallSeconds": "10"}, {})
        sched = LoadAwareScheduler(
            env.bridge,
            [Candidate(URLS[k], IMAGES[k], f"{k}-secret")
             for k in ("slurm", "lsf", "ray")])
        print("\nqueue loads:")
        for load, cand in sched.rank():
            print(f"  {cand.resourceURL:40s} load={load:.2f}")
        spec = env.make_spec("slurm", script="important job",
                             updateinterval=0.05)
        placed = sched.place(spec)
        print(f"placed on: {placed.resourceURL} (was {spec.resourceURL})")
        env.submit("placed-job", placed)
        job = env.operator.wait_for("placed-job", timeout=30)
        print(f"placed-job -> {job.status.state}")


if __name__ == "__main__":
    main()
