"""Runtime capability probes: which kernel path can this process run?

Three tiers, best first:
  * pallas-TPU       — a TPU is attached; ``pallas_call`` lowers via Mosaic;
  * pallas-interpret — no TPU, but Pallas imports; kernel bodies run in
    Python on CPU (bit-accurate correctness path for tests/containers);
  * xla              — Pallas itself is unavailable; callers fall back to
    the pure-jnp reference implementations.

``interpret=None`` in the kernel wrappers means "pick for me":
:func:`resolve_interpret` maps it to ``not has_tpu()`` so the same call
site compiles on a pod and interprets in a CPU container.  Set
``REPRO_PALLAS_INTERPRET=0/1`` to force either mode.
"""
from __future__ import annotations

import functools
import os
from typing import Optional


@functools.lru_cache(maxsize=None)
def has_tpu() -> bool:
    import jax

    try:
        return len(jax.devices("tpu")) > 0
    except RuntimeError:
        return False


@functools.lru_cache(maxsize=None)
def pallas_available() -> bool:
    try:
        from jax.experimental import pallas  # noqa: F401
        from jax.experimental.pallas import tpu  # noqa: F401
    except ImportError:
        return False
    return True


def pallas_interpret_default() -> bool:
    """True when Pallas kernels should run in interpret mode by default."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False", "")
    return not has_tpu()


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Map the tri-state kernel arg (None = auto) to a concrete bool."""
    if interpret is None:
        return pallas_interpret_default()
    return bool(interpret)


def best_kernel_path() -> str:
    """'pallas_tpu' | 'pallas_interpret' | 'xla' for this process."""
    if not pallas_available():
        return "xla"
    return "pallas_tpu" if has_tpu() else "pallas_interpret"
