"""xLSTM blocks: mLSTM (matrix memory, parallel train form + O(1) decode) and
sLSTM (scalar memory, sequential recurrence with exponential gating).

Follows arXiv:2405.04517.  The mLSTM training form is the stabilized
quadratic formulation; decode carries (C, n, m).  sLSTM blocks are strictly
sequential (lax.scan over time) with block-diagonal recurrent weights per head
and a small post-FFN.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.models.layers import adtype, apply_norm, norm_defs

Params = Dict[str, Any]


def _logsigmoid(x):
    return -jax.nn.softplus(-x)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def mlstm_defs(cfg) -> Params:
    d = cfg.d_model
    dp = int(cfg.xlstm.proj_factor * d)
    h = cfg.n_heads
    dh = dp // h
    dt = adtype(cfg)
    return {
        "norm": norm_defs(cfg),
        "w_up": ParamDef((d, dp), ("embed", "inner"), dtype=dt),
        "w_gate": ParamDef((d, dp), ("embed", "inner"), dtype=dt),
        "conv_w": ParamDef((4, dp), (None, "inner"), init="scaled", scale=0.5, dtype=dt),
        "conv_b": ParamDef((dp,), ("inner",), init="zeros", dtype=dt),
        "w_q": ParamDef((dp, h, dh), ("inner", "heads", "head_dim"), dtype=dt),
        "w_k": ParamDef((dp, h, dh), ("inner", "heads", "head_dim"), dtype=dt),
        "w_v": ParamDef((dp, h, dh), ("inner", "heads", "head_dim"), dtype=dt),
        "w_i": ParamDef((d, h), ("embed", "heads"), dtype=jnp.float32),
        "b_i": ParamDef((h,), ("heads",), init="zeros", dtype=jnp.float32),
        "w_f": ParamDef((d, h), ("embed", "heads"), dtype=jnp.float32),
        "b_f": ParamDef((h,), ("heads",), init="ones", dtype=jnp.float32),
        "w_down": ParamDef((dp, d), ("inner", "embed"), dtype=dt),
    }


def _mlstm_qkvgates(p: Params, x: jax.Array, cfg, conv_state=None):
    xn = apply_norm(p["norm"], x, cfg.norm)
    u = xn @ p["w_up"]
    z = xn @ p["w_gate"]
    from repro.models.ssm import _causal_conv

    c, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    c = jax.nn.silu(c)
    q = jnp.einsum("bsd,dhk->bshk", c, p["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", c, p["w_k"]) / jnp.sqrt(q.shape[-1]).astype(c.dtype)
    v = jnp.einsum("bsd,dhk->bshk", u, p["w_v"])
    ig = (xn.astype(jnp.float32) @ p["w_i"] + p["b_i"])  # (B,S,H) log-space input gate
    fg = (xn.astype(jnp.float32) @ p["w_f"] + p["b_f"])
    return q, k, v, ig, fg, z, conv_state


def mlstm_forward(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Parallel (training/prefill) form.  x: (B,S,d) -> (y, final decode state)."""
    q, k, v, ig, fg, z, conv_state = _mlstm_qkvgates(p, x, cfg)
    b, s, h, dh = q.shape
    logf = _logsigmoid(fg)  # (B,S,H)
    fcum = jnp.cumsum(logf, axis=1)
    # log-decay matrix: D[i,j] = fcum_i - fcum_j + ig_j  (j <= i)
    dmat = fcum[:, :, None, :] - fcum[:, None, :, :] + ig[:, None, :, :]  # (B,Si,Sj,H)
    ii = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    causal = (jj <= ii)[None, :, :, None]
    dmat = jnp.where(causal, dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)  # (B,S,1,H)
    m = jnp.maximum(m, -1e30)  # guard all -inf rows
    dprime = jnp.exp(dmat - m)  # (B,Si,Sj,H)
    scores = jnp.einsum("bihk,bjhk->bijh", q.astype(jnp.float32), k.astype(jnp.float32))
    w = scores * dprime
    norm = jnp.maximum(jnp.abs(w.sum(axis=2)), jnp.exp(-m[:, :, 0]))  # (B,S,H)
    y = jnp.einsum("bijh,bjhk->bihk", w, v.astype(jnp.float32)) / norm[..., None]
    y = (y.astype(x.dtype) * jax.nn.silu(z).reshape(b, s, h, dh)).reshape(b, s, h * dh)
    # final recurrent state for decode handoff
    state = _mlstm_state_from_seq(q, k, v, ig, fg, conv_state)
    return y @ p["w_down"], state


def _mlstm_state_from_seq(q, k, v, ig, fg, conv_state) -> Dict[str, jax.Array]:
    """Fold the whole sequence into (C, n, m) so decode can continue."""
    b, s, h, dh = k.shape
    logf = _logsigmoid(fg)
    fcum = jnp.cumsum(logf, axis=1)
    total = fcum[:, -1:, :]  # (B,1,H)
    # weight of step j in final state: exp(total - fcum_j + ig_j)
    logw = (total - fcum + ig)  # (B,S,H)
    m = jnp.max(logw, axis=1)  # (B,H)
    wgt = jnp.exp(logw - m[:, None, :])  # (B,S,H)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = jnp.einsum("bsh,bshd,bshe->bhde", wgt, kf, vf)
    n = jnp.einsum("bsh,bshd->bhd", wgt, kf)
    return {"C": C, "n": n, "m": m, "conv": conv_state}


def mlstm_decode(p: Params, x: jax.Array, state: Dict[str, jax.Array], cfg
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """O(1) recurrent step.  x: (B,1,d)."""
    q, k, v, ig, fg, z, conv_state = _mlstm_qkvgates(p, x, cfg, state["conv"])
    b, _, h, dh = q.shape
    qf, kf, vf = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    ig1, fg1 = ig[:, 0], fg[:, 0]  # (B,H)
    logf = _logsigmoid(fg1)
    m_new = jnp.maximum(logf + state["m"], ig1)
    fprime = jnp.exp(logf + state["m"] - m_new)[..., None]
    iprime = jnp.exp(ig1 - m_new)[..., None]
    C = state["C"] * fprime[..., None] + iprime[..., None] * jnp.einsum("bhd,bhe->bhde", kf, vf)
    n = state["n"] * fprime + iprime * kf
    num = jnp.einsum("bhde,bhd->bhe", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf)), jnp.exp(-m_new))
    y = (num / den[..., None]).astype(x.dtype)  # (B,H,Dh)
    y = (y.reshape(b, 1, h * dh) * jax.nn.silu(z))
    return y @ p["w_down"], {"C": C, "n": n, "m": m_new, "conv": conv_state}


def init_mlstm_state(cfg, batch: int) -> Dict[str, jax.Array]:
    d = cfg.d_model
    dp = int(cfg.xlstm.proj_factor * d)
    h = cfg.n_heads
    dh = dp // h
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, dp), adtype(cfg)),
    }


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def slstm_defs(cfg) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    dt = adtype(cfg)
    dffn = int(2 * d)
    return {
        "norm": norm_defs(cfg),
        # gate input projections: z, i, f, o
        "w_x": ParamDef((d, 4, h, dh), ("embed", None, "heads", "head_dim"), dtype=jnp.float32),
        # block-diagonal recurrent weights per head
        "r_h": ParamDef((4, h, dh, dh), (None, "heads", "head_dim", None),
                        init="normal", dtype=jnp.float32),
        "b": ParamDef((4, h, dh), (None, "heads", "head_dim"), init="zeros", dtype=jnp.float32),
        "ffn_norm": norm_defs(cfg),
        "ffn_w1": ParamDef((d, dffn), ("embed", "mlp"), dtype=dt),
        "ffn_w2": ParamDef((dffn, d), ("mlp", "embed"), dtype=dt),
    }


def _slstm_cell(p: Params, xt: jax.Array, state: Dict[str, jax.Array]):
    """xt: (B,4,H,Dh) pre-projected gate inputs."""
    h_prev = state["h"]  # (B,H,Dh)
    rec = jnp.einsum("bhd,ghde->bghe", h_prev, p["r_h"])  # (B,4,H,Dh)
    g = xt + rec + p["b"]
    zt = jnp.tanh(g[:, 0])
    it = g[:, 1]  # log-space
    ft = _logsigmoid(g[:, 2])
    ot = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(ft + state["m"], it)
    iprime = jnp.exp(it - m_new)
    fprime = jnp.exp(ft + state["m"] - m_new)
    c = fprime * state["c"] + iprime * zt
    n = fprime * state["n"] + iprime
    h = ot * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_forward(p: Params, x: jax.Array, cfg, state: Dict[str, jax.Array] = None
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Sequential over time.  x: (B,S,d)."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, d // cfg.n_heads
    xn = apply_norm(p["norm"], x, cfg.norm)
    xg = jnp.einsum("bsd,dghe->bsghe", xn.astype(jnp.float32), p["w_x"])  # (B,S,4,H,Dh)
    if state is None:
        state = init_slstm_state(cfg, b)

    def step(st, xt):
        st = _slstm_cell(p, xt, st)
        return st, st["h"]

    state, hs = jax.lax.scan(step, state, jnp.swapaxes(xg, 0, 1))
    y = jnp.swapaxes(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = x + y  # residual around the cell
    yn = apply_norm(p["ffn_norm"], y, cfg.norm)
    y = y + (jax.nn.gelu(yn @ p["ffn_w1"]) @ p["ffn_w2"])
    return y, state


def slstm_decode(p: Params, x: jax.Array, state: Dict[str, jax.Array], cfg
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    y, state = slstm_forward(p, x, cfg, state)
    return y, state


def init_slstm_state(cfg, batch: int) -> Dict[str, jax.Array]:
    h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, h, dh), -1e30, jnp.float32)}
