"""HTTP/HTTPS transport simulation.

The paper's only assumption on an external system is that it "exposes a
HTTP/HTTPS API for its control/management".  We preserve that boundary: the
controller pods talk to backends EXCLUSIVELY through ``RestClient.request``
(method, path, json) and never call backend internals.  The transport injects
the unreliable-network character (latency, fault windows, auth failures) that
the bridge's retry/UNKNOWN logic exists to survive.

Two event-driven extensions live here:

  * ``watch`` routes — a route kind whose handler may BLOCK until a
    state-version advances or its wait budget expires (returning 204).  The
    budget honors ``RestClient.timeout``: the server never holds a request
    longer than the client is willing to wait.
  * ``Channel`` — one keep-alive connection per endpoint.  Every client a
    monitor holds for the same endpoint multiplexes its requests over the
    shared channel (``ResourceManagerDirectory`` hands out one per URL), so
    request/error counters — and the channel's memo cache, which amortizes
    events-version probes across all CRs on the endpoint — are measured
    where a real connection pool would sit.
"""
from __future__ import annotations

import math
import random
import re
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple
from urllib.parse import parse_qsl


class TransportError(ConnectionError):
    """Network-level failure (timeout / connection refused)."""


@dataclass
class HttpResponse:
    status: int
    json: Any = None

    @property
    def ok(self) -> bool:
        return 200 <= self.status < 300


@dataclass
class FaultProfile:
    """Deterministic (seeded) fault injection for the simulated network.

    Composable fault modes, all usable at once:

      * ``drop_rate``/``latency``/``seed`` — steady-state packet loss and RTT;
      * ``begin_outage()``/``end_outage()`` — a hard blackout, every request
        fails until lifted;
      * ``schedule_blackout(start_in, duration)`` — a timed blackout window
        (``duration=None`` = until further notice), checked lazily against
        the wall clock so chaos tests can pre-program a kill;
      * ``schedule_flaps(...)`` — N short blackout windows on a fixed period
        (a flapping endpoint), built from timed windows;
      * ``fail_next(n)`` — exactly the next ``n`` requests fail, for
        deterministic single-blip tests;
      * ``begin_partition()``/``end_partition()`` — the request EXECUTES on
        the server but the reply is lost (classic network partition): this
        is the mode that exercises at-most-once handling, because the client
        cannot tell a lost reply from a lost request.
    """
    drop_rate: float = 0.0        # probability a request raises TransportError
    latency: float = 0.0          # fixed per-request latency (seconds)
    seed: int = 0
    # hard outage window: every request fails while ``outage`` is set
    _outage: threading.Event = field(default_factory=threading.Event, repr=False)
    # reply-lost partition: handlers run, responses vanish
    _partition: threading.Event = field(default_factory=threading.Event,
                                        repr=False)
    _rng: random.Random = field(default=None, repr=False)
    # one shared seeded Random serves every concurrent caller; the lock keeps
    # each check() consuming exactly one draw so drop injection stays
    # deterministic however many pods/workers hit the server at once
    _rng_lock: threading.Lock = field(default_factory=threading.Lock,
                                      repr=False)
    # timed blackout windows [(start, end-or-None), ...], absolute times
    _windows: List[Tuple[float, Optional[float]]] = field(
        default_factory=list, repr=False)
    _fail_next: int = field(default=0, repr=False)

    def __post_init__(self):
        self._rng = random.Random(self.seed)

    def begin_outage(self) -> None:
        self._outage.set()

    def end_outage(self) -> None:
        self._outage.clear()

    def begin_partition(self) -> None:
        self._partition.set()

    def end_partition(self) -> None:
        self._partition.clear()

    def reply_lost(self) -> bool:
        """Consulted by the server AFTER the handler ran: True = drop the
        response on the floor (the partition fault mode)."""
        return self._partition.is_set()

    def schedule_blackout(self, start_in: float = 0.0,
                          duration: Optional[float] = None) -> None:
        """Blackout every request in the window ``[now+start_in, now+
        start_in+duration)``; ``duration=None`` never ends."""
        start = time.time() + start_in
        end = None if duration is None else start + duration
        with self._rng_lock:
            self._windows.append((start, end))

    def schedule_flaps(self, start_in: float, count: int, down_for: float,
                       up_for: float) -> None:
        """A flapping endpoint: ``count`` blackouts of ``down_for`` seconds,
        one every ``down_for + up_for`` seconds, starting at ``start_in``."""
        for i in range(count):
            self.schedule_blackout(start_in + i * (down_for + up_for),
                                   down_for)

    def fail_next(self, n: int = 1) -> None:
        """Fail exactly the next ``n`` requests (deterministic blip)."""
        with self._rng_lock:
            self._fail_next += n

    def _in_blackout_window(self, now: float) -> bool:
        with self._rng_lock:
            for start, end in self._windows:
                if start <= now and (end is None or now < end):
                    return True
        return False

    def check(self) -> None:
        if self.latency:
            time.sleep(self.latency)
        if self._outage.is_set():
            raise TransportError("simulated network outage")
        if self._windows and self._in_blackout_window(time.time()):
            raise TransportError("simulated network outage (scheduled)")
        with self._rng_lock:
            if self._fail_next > 0:
                self._fail_next -= 1
                raise TransportError("simulated transient blip")
        if self.drop_rate:
            with self._rng_lock:
                drop = self._rng.random() < self.drop_rate
            if drop:
                raise TransportError("simulated packet loss")


Handler = Callable[[Dict[str, str], Any], HttpResponse]
# watch handlers additionally receive the wait budget (seconds) the server
# grants them: min(what the query asked for, what the client will wait)
WatchHandler = Callable[[Dict[str, str], Any, float], HttpResponse]


class RestServer:
    """Route table + bearer-token auth for one simulated resource manager."""

    def __init__(self, token: str = "", fault: Optional[FaultProfile] = None):
        self._routes: List[Tuple[str, re.Pattern, Handler, str, str]] = []
        self._token = token
        self.fault = fault or FaultProfile()
        self.request_count = 0
        self._lock = threading.Lock()
        # per-route request/error counters, keyed "METHOD /pattern"
        self._stats: Dict[str, Dict[str, int]] = {}

    def route(self, method: str, pattern: str, handler: Handler,
              kind: str = "plain") -> None:
        """pattern: '/jobs/{id}' -> named groups.  ``kind="watch"`` marks a
        long-poll route: its handler gets a third argument (the wait budget
        in seconds) and may block until a state-version advances or the
        budget runs out (answering 204)."""
        if kind not in ("plain", "watch"):
            raise ValueError(f"unknown route kind {kind!r}")
        rx = re.compile("^" + re.sub(r"\{(\w+)\}", r"(?P<\1>[^/]+)", pattern) + "$")
        self._routes.append((method.upper(), rx, handler, kind, pattern))

    @property
    def stats(self) -> Dict[str, Dict[str, int]]:
        """Per-route {"requests", "errors"} counters (copy)."""
        with self._lock:
            return {k: dict(v) for k, v in self._stats.items()}

    def _count(self, key: str, error: bool) -> None:
        with self._lock:
            ent = self._stats.setdefault(key, {"requests": 0, "errors": 0})
            ent["requests"] += 1
            if error:
                ent["errors"] += 1

    def handle(self, method: str, path: str, json_body: Any = None,
               headers: Optional[Dict[str, str]] = None,
               timeout: Optional[float] = None) -> HttpResponse:
        # the client gives up before a too-slow response can arrive — this is
        # where RestClient.timeout actually bites (watch routes additionally
        # cap their blocking wait to the same budget below)
        if timeout is not None and self.fault.latency > timeout:
            time.sleep(timeout)
            raise TransportError(f"client timed out after {timeout}s")
        self.fault.check()
        with self._lock:
            self.request_count += 1
        headers = headers or {}
        if self._token:
            auth = headers.get("Authorization", "")
            if auth != f"Bearer {self._token}":
                self._count("(unauthorized)", error=True)
                return HttpResponse(401, {"error": "unauthorized"})
        # query string: merged into the handler's groups dict (path groups
        # win on collision), so 'GET /jobs?ids=a,b' routes like 'GET /jobs'
        path, _, query = path.partition("?")
        params = dict(parse_qsl(query)) if query else {}
        for m, rx, handler, kind, pattern in self._routes:
            if m != method.upper():
                continue
            match = rx.match(path)
            if match:
                key = f"{m} {pattern}"
                try:
                    if kind == "watch":
                        budget = math.inf if timeout is None else timeout
                        resp = handler({**params, **match.groupdict()},
                                       json_body, budget)
                    else:
                        resp = handler({**params, **match.groupdict()},
                                       json_body)
                except Exception as e:  # backend bug -> 500, not a crash
                    resp = HttpResponse(500,
                                        {"error": f"{type(e).__name__}: {e}"})
                self._count(key, error=resp.status >= 400)
                # partition: the handler RAN (side effects happened) but the
                # reply never reaches the client — at-most-once territory
                if self.fault.reply_lost():
                    raise TransportError("simulated partition: reply lost")
                return resp
        self._count("(unmatched)", error=True)
        return HttpResponse(404, {"error": f"no route {method} {path}"})


class Channel:
    """One keep-alive connection to ONE endpoint.

    All of a monitor's requests to that endpoint flow through the shared
    channel object (``ResourceManagerDirectory.connect`` hands every client
    for a URL the same channel), which is where request/error counters and
    the cross-client memo cache live.
    """

    # bounded retry for idempotent reads: a GET that dies in transport is
    # retried in-call with exponential backoff + seeded jitter, so ONE
    # transient blip costs one in-tick retry instead of a failed poll (and a
    # bump of the slice's UNKNOWN counter).  Writes are never retried here —
    # submit/cancel idempotency is owned by the protocol layer.
    GET_RETRIES = 2
    RETRY_BACKOFF = 0.005

    def __init__(self, server: RestServer, url: str = ""):
        self._server = server
        self.url = url
        self.requests = 0
        self.errors = 0
        self.retries = 0
        self._lock = threading.Lock()
        self._retry_rng = random.Random(hash(url) & 0xFFFF)
        self._memo: Dict[str, Tuple[Any, float]] = {}
        self._memo_gates: Dict[str, threading.Lock] = {}
        # optional dedicated watcher: ONE long-poll loop per endpoint (the
        # wakeup cadence's push path) — never one per CR
        self._watcher: Optional[threading.Thread] = None
        self._watcher_stop: Optional[threading.Event] = None
        # stamped by the watcher after every successful long-poll cycle;
        # 0.0 (never) or stale means push delivery cannot be trusted and
        # safety-net ticks must fall back to fetching events themselves
        self.watch_heartbeat = 0.0

    def request(self, method: str, path: str, json: Any = None,
                headers: Optional[Dict[str, str]] = None,
                timeout: Optional[float] = None) -> HttpResponse:
        attempts = 1 + (self.GET_RETRIES if method.upper() == "GET" else 0)
        for attempt in range(attempts):
            try:
                resp = self._server.handle(method, path, json, headers,
                                           timeout=timeout)
            except TransportError:
                with self._lock:
                    self.requests += 1
                    self.errors += 1
                    if attempt + 1 < attempts:
                        self.retries += 1
                        backoff = (self.RETRY_BACKOFF * (2 ** attempt)
                                   * (1.0 + self._retry_rng.random()))
                    else:
                        backoff = None
                if backoff is None:
                    raise
                time.sleep(backoff)
                continue
            except Exception:
                with self._lock:
                    self.requests += 1
                    self.errors += 1
                raise
            with self._lock:
                self.requests += 1
                if resp.status >= 400:
                    self.errors += 1
            return resp

    def memo(self, key: str, max_age: float, compute: Callable[[], Any]) -> Any:
        """Endpoint-wide response cache with single-flight refresh: however
        many clients share the channel, at most one re-computes a stale
        entry (the rest read the cached value) — this is what keeps e.g.
        events-version probes O(endpoints), not O(CRs)."""
        now = time.time()
        with self._lock:
            ent = self._memo.get(key)
            if ent is not None and now - ent[1] <= max_age:
                return ent[0]
            gate = self._memo_gates.setdefault(key, threading.Lock())
        with gate:
            with self._lock:
                ent = self._memo.get(key)
                if ent is not None and time.time() - ent[1] <= max_age:
                    return ent[0]
            value = compute()  # outside self._lock: it is a live request
            with self._lock:
                self._memo[key] = (value, time.time())
            return value

    # -- dedicated watcher (wakeup cadence) ---------------------------------

    def ensure_watcher(self, run: Callable[[threading.Event], None],
                       name: str = "") -> bool:
        """Start the endpoint's dedicated watcher if none is running: a
        daemon thread executing ``run(stop_event)`` (a long-poll loop that
        pokes subscribed chains).  At most ONE watcher exists per channel —
        however many CRs subscribe, the endpoint pays one in-flight
        long-poll.  Returns True iff a new watcher was started."""
        with self._lock:
            if self._watcher is not None and self._watcher.is_alive():
                return False
            stop = threading.Event()
            t = threading.Thread(
                target=run, args=(stop,), daemon=True,
                name=name or f"bridge-monitor-watch:{self.url}")
            self._watcher, self._watcher_stop = t, stop
            t.start()
        return True

    def stop_watcher(self, timeout: float = 1.0) -> None:
        with self._lock:
            t, stop = self._watcher, self._watcher_stop
            self._watcher = self._watcher_stop = None
        if stop is not None:
            stop.set()
        if t is not None:
            t.join(timeout=timeout)

    @property
    def watcher_alive(self) -> bool:
        t = self._watcher
        return t is not None and t.is_alive()


class RestClient:
    """What a controller pod holds: endpoint + credentials, nothing else.
    Requests ride the endpoint's (possibly shared) ``Channel``."""

    def __init__(self, server, token: str = "", timeout: float = 5.0):
        self.channel = server if isinstance(server, Channel) \
            else Channel(server)
        self._token = token
        self.timeout = timeout

    def request(self, method: str, path: str, json: Any = None) -> HttpResponse:
        headers = {"Authorization": f"Bearer {self._token}"} if self._token else {}
        return self.channel.request(method, path, json, headers,
                                    timeout=self.timeout)

    def get(self, path: str) -> HttpResponse:
        return self.request("GET", path)

    def post(self, path: str, json: Any = None) -> HttpResponse:
        return self.request("POST", path, json)

    def delete(self, path: str) -> HttpResponse:
        return self.request("DELETE", path)

    def put(self, path: str, json: Any = None) -> HttpResponse:
        return self.request("PUT", path, json)


class ResourceManagerDirectory:
    """Maps resourceURL -> RestServer (DNS + ingress analogue).  Keeps ONE
    ``Channel`` per URL: every client connected through the directory to the
    same endpoint shares it."""

    def __init__(self) -> None:
        self._servers: Dict[str, RestServer] = {}
        self._channels: Dict[str, Channel] = {}
        self._lock = threading.Lock()

    def register(self, url: str, server: RestServer) -> None:
        self._servers[url] = server

    def channel(self, url: str) -> Channel:
        if url not in self._servers:
            raise TransportError(f"cannot resolve {url!r}")
        with self._lock:
            ch = self._channels.get(url)
            if ch is None:
                ch = self._channels[url] = Channel(self._servers[url], url)
            return ch

    def channels(self) -> Dict[str, Channel]:
        """Live per-endpoint channels (for stats/observability)."""
        with self._lock:
            return dict(self._channels)

    def connect(self, url: str, token: str = "") -> RestClient:
        return RestClient(self.channel(url), token)

    def urls(self) -> List[str]:
        return sorted(self._servers)
