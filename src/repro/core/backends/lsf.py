"""Simulated IBM Spectrum LSF Application Center REST API.

Dialect notes (paper §5.2): bsub-style submission options; states
PEND/RUN/DONE/EXIT; the Application Center API DOES support file upload and
download to/from the cluster, plus queue queries.
"""
from __future__ import annotations

import base64
import re
from typing import Any, Dict, Optional

from repro.core.backends import base as B
from repro.core.rest import FaultProfile, HttpResponse, RestServer

_STATE_TO_LSF = {
    B.QUEUED: "PEND",
    B.RUNNING: "RUN",
    B.COMPLETED: "DONE",
    B.FAILED: "EXIT",
    B.CANCELLED: "EXIT",  # LSF kills show as EXIT; reason distinguishes
}


def _lsf_to_state(s: str, reason: str) -> str:
    if s == "PEND":
        return B.QUEUED
    if s == "RUN":
        return B.RUNNING
    if s == "DONE":
        return B.COMPLETED
    if "TERM_OWNER" in reason or "killed" in reason.lower():
        return B.CANCELLED
    return B.FAILED


def make_server(cluster: B.SimulatedCluster, token: str = "",
                fault: FaultProfile = None) -> RestServer:
    srv = RestServer(token=token, fault=fault)

    _ARRAY_RE = re.compile(r"^[^\[\]]+\[(\d+)-(\d+)\]$")

    def submit(_groups, body) -> HttpResponse:
        body = body or {}
        if not body.get("COMMANDTORUN"):
            return HttpResponse(400, {"error": "COMMANDTORUN required"})
        props = {k: v for k, v in body.items()
                 if k not in ("COMMANDTORUN", "JOB_ARRAY", "PARAMS_BY_INDEX")}
        # bsub -J "name[lo-hi]" analogue: ONE submission call fans out the
        # whole array, each element stamped with its 1-based LSB_JOBINDEX
        if body.get("JOB_ARRAY"):
            m = _ARRAY_RE.match(body["JOB_ARRAY"])
            if not m:
                return HttpResponse(400, {"error":
                                          'JOB_ARRAY must be "name[lo-hi]"'})
            lo, hi = int(m.group(1)), int(m.group(2))
            if not 0 < lo <= hi:
                return HttpResponse(400, {"error": "bad JOB_ARRAY bounds"})
            per_index = body.get("PARAMS_BY_INDEX") or []
            element_ids = []
            for i, jobindex in enumerate(range(lo, hi + 1)):
                params = dict(body.get("PARAMS", {}))
                if i < len(per_index):
                    params.update(per_index[i])
                params.setdefault("LSB_JOBINDEX", str(jobindex))
                job = cluster.submit(body["COMMANDTORUN"], props, params)
                element_ids.append(job.id)
            return HttpResponse(200, {
                "jobId": element_ids[0], "elementJobIds": element_ids,
                "message": f"Job <{element_ids[0]}> is submitted to queue."})
        job = cluster.submit(body["COMMANDTORUN"], props, body.get("PARAMS", {}))
        return HttpResponse(200, {"jobId": job.id,
                                  "message": f"Job <{job.id}> is submitted to queue."})

    def _job_record(job: B.ClusterJob) -> dict:
        reason = job.reason or ("TERM_OWNER: killed by owner"
                                if job.state == B.CANCELLED else "")
        return {
            "jobId": job.id, "status": _STATE_TO_LSF[job.state],
            "startTime": job.start_time, "endTime": job.end_time,
            "exitReason": reason,
        }

    def jobinfo(groups, _body) -> HttpResponse:
        job = cluster.get(groups["id"])
        if job is None:
            return HttpResponse(404, {"error": "Job not found"})
        return HttpResponse(200, _job_record(job))

    def jobsinfo(groups, _body) -> HttpResponse:
        # bjobs id1 id2 ... analogue: one request answers many ids; an id
        # mbatchd no longer knows yields a record with status=null
        ids = [s for s in groups.get("ids", "").split(",") if s]
        if not ids:
            return HttpResponse(400, {"error": "ids query param required"})
        records = []
        for jid in ids:
            job = cluster.get(jid)
            records.append(_job_record(job) if job is not None
                           else {"jobId": jid, "status": None})
        return HttpResponse(200, {"jobs": records})

    def kill(groups, _body) -> HttpResponse:
        # bkill of an already-finished job: 409 Conflict (the kill lost the
        # race against the terminal transition), never a 500
        outcome = cluster.cancel_if_live(groups["id"])
        if outcome == "absent":
            return HttpResponse(404, {"error": "Job not found"})
        if outcome == "terminal":
            return HttpResponse(409, {"error": "Job already finished"})
        return HttpResponse(200, {})

    def upload(groups, body) -> HttpResponse:
        cluster.upload(groups["name"], base64.b64decode(body["data"]))
        return HttpResponse(200, {})

    def download(groups, _body) -> HttpResponse:
        name = groups["name"]
        # job outputs take priority over the shared staging area
        for job in cluster.jobs.values():
            if name in job.outputs:
                return HttpResponse(200, {"data": base64.b64encode(
                    job.outputs[name]).decode()})
        data = cluster.download(name)
        if data is None:
            return HttpResponse(404, {"error": "no such file"})
        return HttpResponse(200, {"data": base64.b64encode(data).decode()})

    def health(groups, _body) -> HttpResponse:
        status, payload = cluster.serve_health(groups["id"])
        return HttpResponse(status, payload)

    def invoke(groups, body) -> HttpResponse:
        status, payload = cluster.serve_invoke(groups["id"], body)
        return HttpResponse(status, payload)

    def queues(_groups, _body) -> HttpResponse:
        load = cluster.queue_load()
        return HttpResponse(200, {"queues": [dict(name="normal", **load)]})

    def events(groups, _body, budget) -> HttpResponse:
        # long-poll watch (see the slurm dialect): 200 {"version"} when an
        # event relevant to ``ids`` is newer than ``since``, 204 otherwise
        since = int(groups.get("since", "-1") or -1)
        ids = [s for s in groups.get("ids", "").split(",") if s] or None
        wait = min(float(groups.get("wait", "0") or 0), budget)
        version, changed, payload = cluster.wait_events_payload(
            since, timeout=wait, ids=ids)
        if not changed:
            return HttpResponse(204)
        body: Dict[str, Any] = {"version": version}
        if payload is not None:
            # WHICH jobs changed, in LSF vocabulary; CANCELLED carries the
            # TERM_OWNER reason so clients can round-trip EXIT correctly.
            # Omitted when the bounded event ring no longer covers ``since``
            body["events"] = [
                {"jobId": jid, "status": _STATE_TO_LSF[state],
                 "exitReason": ("TERM_OWNER: killed by owner"
                                if state == B.CANCELLED else "")}
                for jid, state in payload]
        return HttpResponse(200, body)

    srv.route("POST", "/platform/ws/jobs/submit", submit)
    srv.route("GET", "/platform/ws/jobs", jobsinfo)
    # registered BEFORE the {id} route: "events" must not match as an id
    srv.route("GET", "/platform/ws/jobs/events", events, kind="watch")
    srv.route("GET", "/platform/ws/jobs/{id}", jobinfo)
    srv.route("POST", "/platform/ws/jobs/{id}/kill", kill)
    srv.route("GET", "/platform/ws/jobs/{id}/health", health)
    srv.route("POST", "/platform/ws/jobs/{id}/invoke", invoke)
    srv.route("PUT", "/platform/ws/files/{name}", upload)
    srv.route("GET", "/platform/ws/files/{name}", download)
    srv.route("GET", "/platform/ws/queues", queues)
    return srv


class LSFAdapter(B.ResourceAdapter):
    image = "lsfpod"
    # Application Center API: full file staging, bjobs-style multi-id
    # status, and bsub -J "name[1-N]"-style native job arrays (one
    # submission call fans out every element, stamped with LSB_JOBINDEX)
    capabilities = frozenset({
        B.Capability.CANCEL, B.Capability.CANCEL_QUEUED,
        B.Capability.UPLOAD, B.Capability.DOWNLOAD, B.Capability.QUEUE_LOAD,
        B.Capability.BATCH_STATUS, B.Capability.NATIVE_ARRAYS,
        B.Capability.WATCH, B.Capability.SERVE,
    })

    def submit(self, script, properties, params) -> str:
        body = dict(properties or {})
        body["COMMANDTORUN"] = script
        body["PARAMS"] = dict(params or {})
        r = self.client.post("/platform/ws/jobs/submit", body)
        if not r.ok:
            raise B.SubmitError(f"lsf submit: HTTP {r.status} {r.json}")
        return str(r.json["jobId"])

    def submit_array(self, script, properties, params_by_index,
                     start_index=0) -> list:
        # bsub -J "bridge[lo-hi]": LSB_JOBINDEX is 1-based, global array
        # index start_index + i maps to element index start_index + i + 1
        lo, hi = start_index + 1, start_index + len(params_by_index)
        body = dict(properties or {})
        body["COMMANDTORUN"] = script
        body["JOB_ARRAY"] = f"bridge[{lo}-{hi}]"
        body["PARAMS_BY_INDEX"] = [dict(p or {}) for p in params_by_index]
        r = self.client.post("/platform/ws/jobs/submit", body)
        if not r.ok:
            raise B.SubmitError(f"lsf array submit: HTTP {r.status} {r.json}")
        return [str(j) for j in r.json["elementJobIds"]]

    def resubmit_index(self, script, properties, params, index) -> str:
        # keep the retried element indistinguishable from its original run
        params = dict(params)
        params.setdefault("LSB_JOBINDEX", str(index + 1))
        return self.submit(script, properties, params)

    @staticmethod
    def _record_to_info(j: Dict[str, Any]) -> Dict[str, Any]:
        if j.get("status") is None:
            return {"state": B.FAILED, "reason": "job not found in mbatchd"}
        return {"state": _lsf_to_state(j["status"], j.get("exitReason", "")),
                "start_time": j.get("startTime"), "end_time": j.get("endTime"),
                "reason": j.get("exitReason", "")}

    def status(self, job_id: str) -> Dict[str, Any]:
        r = self.client.get(f"/platform/ws/jobs/{job_id}")
        if r.status == 404:
            return {"state": B.FAILED, "reason": "job not found in mbatchd"}
        if not r.ok:
            raise B.SubmitError(f"lsf status: HTTP {r.status}")
        return self._record_to_info(r.json)

    def status_batch(self, job_ids) -> list:
        r = self.client.get("/platform/ws/jobs?ids=" + ",".join(job_ids))
        if not r.ok:
            raise B.SubmitError(f"lsf batch status: HTTP {r.status}")
        by_id = {str(j["jobId"]): j for j in r.json["jobs"]}
        return [self._record_to_info(by_id.get(str(jid), {}))
                for jid in job_ids]

    def cancel(self, job_id: str) -> None:
        self.client.post(f"/platform/ws/jobs/{job_id}/kill")

    def probe_health(self, job_id: str) -> bool:
        return self.client.get(f"/platform/ws/jobs/{job_id}/health").ok

    def invoke(self, job_id: str, payload: Any) -> Any:
        r = self.client.post(f"/platform/ws/jobs/{job_id}/invoke", payload)
        if not r.ok:
            detail = r.json.get("error", "") if isinstance(r.json, dict) else ""
            raise B.InvokeError(r.status, detail)
        return r.json

    def watch_events(self, since=-1, ids=None, wait=0.0):
        q = f"since={since}"
        if ids:
            q += "&ids=" + ",".join(ids)
        if wait:
            q += f"&wait={wait}"
        r = self.client.get("/platform/ws/jobs/events?" + q)
        if r.status == 204:
            return None
        if not r.ok:
            raise B.SubmitError(f"lsf events: HTTP {r.status}")
        return int(r.json["version"])

    def watch_events_ids(self, since=-1, ids=None, wait=0.0):
        q = f"since={since}"
        if ids:
            q += "&ids=" + ",".join(ids)
        if wait:
            q += f"&wait={wait}"
        r = self.client.get("/platform/ws/jobs/events?" + q)
        if r.status == 204:
            return None
        if not r.ok:
            raise B.SubmitError(f"lsf events: HTTP {r.status}")
        events = r.json.get("events")
        if events is not None:
            events = [(str(e["jobId"]),
                       _lsf_to_state(e["status"], e.get("exitReason", "")))
                      for e in events]
        return int(r.json["version"]), events

    def upload(self, name: str, data: bytes) -> bool:
        r = self.client.put(f"/platform/ws/files/{name}",
                            {"data": base64.b64encode(data).decode()})
        return r.ok

    def download(self, name: str) -> Optional[bytes]:
        r = self.client.get(f"/platform/ws/files/{name}")
        if not r.ok:
            return None
        return base64.b64decode(r.json["data"])

    def queue_load(self) -> Optional[Dict[str, int]]:
        r = self.client.get("/platform/ws/queues")
        if not r.ok:
            return None
        q = r.json["queues"][0]
        return {"queued": q["queued"], "running": q["running"], "slots": q["slots"]}
