"""Workflow integration (paper §6, Fig. 4).

A minimal pipeline engine with KFP-like semantics (ops, ``.after()``
dependencies, cache-staleness knobs) and the paper's canonical three-step
bridge pipeline:

    createop  — create the per-job config map from the pipeline parameters,
    invokeop  — run the bridge controller pod to completion,
    cleanop   — delete the config map.

The bridge pipeline runs the pod DIRECTLY (as Kubeflow would run the
container), not via the operator — matching the paper, where the pipeline is
an alternative, self-contained consumer of the same pod images.  Pipelines
compose: a bridge pipeline is usable "as a sub workflow for more complex
implementations" (§6) via ``Pipeline.add_subpipeline``.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.controller import ControllerPod


class PipelineError(RuntimeError):
    pass


@dataclass
class PipelineOp:
    name: str
    fn: Callable[[Dict[str, Any]], Any]
    after: List[str] = field(default_factory=list)
    # KFP: execution_options.caching_strategy.max_cache_staleness ("P0D" = never)
    max_cache_staleness: str = "P0D"
    retries: int = 0

    def after_op(self, *ops: "PipelineOp") -> "PipelineOp":
        self.after.extend(o.name for o in ops)
        return self


class Pipeline:
    def __init__(self, name: str):
        self.name = name
        self.ops: Dict[str, PipelineOp] = {}
        self._cache: Dict[str, Any] = {}

    def add(self, op: PipelineOp) -> PipelineOp:
        if op.name in self.ops:
            raise PipelineError(f"duplicate op {op.name!r}")
        self.ops[op.name] = op
        return op

    def add_subpipeline(self, sub: "Pipeline", after: Optional[List[str]] = None
                        ) -> PipelineOp:
        """Compose: run ``sub`` as a single op of this pipeline."""
        return self.add(PipelineOp(
            name=f"sub:{sub.name}",
            fn=lambda ctx, _s=sub: _s.run(dict(ctx)),
            after=list(after or [])))

    def _toposort(self) -> List[PipelineOp]:
        order, seen, visiting = [], set(), set()

        def visit(name: str) -> None:
            if name in seen:
                return
            if name in visiting:
                raise PipelineError(f"dependency cycle at {name!r}")
            visiting.add(name)
            for dep in self.ops[name].after:
                if dep not in self.ops:
                    raise PipelineError(f"{name!r} depends on unknown {dep!r}")
                visit(dep)
            visiting.discard(name)
            seen.add(name)
            order.append(self.ops[name])

        for name in self.ops:
            visit(name)
        return order

    def run(self, context: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Execute ops topologically; each op sees the shared context and its
        result is stored under ``results[name]``."""
        ctx = dict(context or {})
        results: Dict[str, Any] = {}
        ctx["results"] = results
        for op in self._toposort():
            use_cache = op.max_cache_staleness != "P0D"
            if use_cache and op.name in self._cache:
                results[op.name] = self._cache[op.name]
                continue
            attempt = 0
            while True:
                try:
                    results[op.name] = op.fn(ctx)
                    break
                except Exception:
                    attempt += 1
                    if attempt > op.retries:
                        raise
            if use_cache:
                self._cache[op.name] = results[op.name]
        return results


# ---------------------------------------------------------------------------
# The paper's three-step bridge pipeline (Fig. 4)
# ---------------------------------------------------------------------------


def bridge_pipeline(bridge, jobname: str, *, resourceURL: str, resourcesecret: str,
                    script: str, scriptlocation: str, docker: str,
                    additionaldata: str = "", jobproperties: Optional[Dict] = None,
                    jobparams: Optional[Dict] = None, s3uploadfiles: str = "",
                    s3uploadbucket: str = "", updateinterval: float = 0.02,
                    namespace: str = "default", pod_retries: int = 2) -> Pipeline:
    """Build the createop -> invokeop -> cleanop pipeline against a ``Bridge``
    facade (same parameter list as the paper's ``bridgepipeline`` python
    function, modulo s3 endpoint bundling).  A ``BridgeEnvironment`` is also
    accepted; its facade is used."""
    env = bridge
    bridge = getattr(env, "bridge", env)  # BridgeEnvironment -> its facade
    pipe = Pipeline(f"bridge-{jobname}")
    cm_name = f"{namespace}/{jobname}-bridge-cm"

    def createop(ctx):
        data = {
            "resourceURL": resourceURL, "image": docker,
            "resourcesecret": resourcesecret,
            "updateinterval": str(updateinterval),
            "jobscript": script, "scriptlocation": scriptlocation,
            "additionaldata": additionaldata,
            "jobproperties": json.dumps(jobproperties or {}),
            "jobparams": json.dumps(jobparams or {}),
            "unknown_after": "5", "id": "", "jobStatus": "PENDING",
            "kill": "false", "message": "",
            "s3uploadfiles": s3uploadfiles, "s3uploadbucket": s3uploadbucket,
        }
        bridge.statestore.get_or_create(cm_name, data)
        return cm_name

    def invokeop(ctx):
        cm = bridge.statestore.get(cm_name)
        pod = ControllerPod(
            name=f"{namespace}/{jobname}-pod", configmap=cm,
            secrets=bridge.secrets, objectstore=bridge.s3,
            directory=bridge.directory, adapters=bridge.adapters,
            min_sleep=0.002)
        pod.start()
        pod.join(timeout=60)
        status = cm.data.get("jobStatus", "")
        if pod.exit_code != 0:
            raise PipelineError(
                f"bridge pod exited {pod.exit_code} (job {status})")
        return {"jobStatus": status, "id": cm.data.get("id", ""),
                "outputs": cm.data.get("outputs", "")}

    def cleanop(ctx):
        bridge.statestore.delete(cm_name)
        return "cleaned"

    create = pipe.add(PipelineOp("createop", createop))
    invoke = pipe.add(PipelineOp("invokeop", invokeop, retries=pod_retries))
    invoke.after_op(create)
    clean = pipe.add(PipelineOp("cleanop", cleanop))
    clean.after_op(invoke)
    return pipe
