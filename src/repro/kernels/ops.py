"""Jit-able wrappers around the Pallas kernels (padding + layout glue).

Layout contract with the model code (repro.models.layers): activations are
(B, S, H, D) / caches are (B, M, Hkv, D); the kernels want head-major
(B, H, S, D).  Wrappers transpose, pad sequences to block multiples, call
the kernel, and slice back.

Substrate dispatch (via repro.compat): ``interpret=None`` (the default)
auto-selects — Mosaic lowering on TPU, Python interpret mode on CPU; and
when Pallas itself cannot be imported on the installed JAX, each wrapper
degrades to the pure-XLA reference implementation in ``repro.kernels.ref``
so the model code never sees the substrate change.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import pallas_available, resolve_interpret
from repro.kernels import ref as _ref

if pallas_available():
    from repro.kernels.decode_attention import decode_attention_bhd
    from repro.kernels.flash_attention import flash_attention_bhsd
    from repro.kernels.ssm_scan import ssm_scan_chunked


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,S,Hq,D); k,v: (B,S,Hkv,D) -> (B,S,Hq,D).  Causal only (key
    padding is masked by causality)."""
    # resolve interpret=None OUTSIDE jit so the cache is keyed on the
    # concrete mode and env/backend changes can't hit a stale executable
    return _flash_attention(q, k, v, causal=causal, block_q=block_q,
                            block_k=block_k,
                            interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _flash_attention(q, k, v, *, causal, block_q, block_k, interpret):
    if not causal:
        raise NotImplementedError("pallas path is causal-only; xla handles "
                                  "bidirectional encoders")
    if not pallas_available():
        out = _ref.flash_attention_ref(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), causal=True)
        return jnp.swapaxes(out, 1, 2)
    s = q.shape[1]
    qt = _pad_to(jnp.swapaxes(q, 1, 2), 2, block_q)
    kt = _pad_to(jnp.swapaxes(k, 1, 2), 2, block_k)
    vt = _pad_to(jnp.swapaxes(v, 1, 2), 2, block_k)
    out = flash_attention_bhsd(qt, kt, vt, causal=True, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return jnp.swapaxes(out[:, :, :s], 1, 2)


def decode_attention(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                     lengths: jax.Array, *, block_m: int = 512,
                     interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,1,Hq,D); cache_{k,v}: (B,M,Hkv,D); lengths (B,) -> (B,1,Hq,D).
    Cache padding beyond ``lengths`` is masked inside the kernel."""
    return _decode_attention(q, cache_k, cache_v, lengths, block_m=block_m,
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def _decode_attention(q, cache_k, cache_v, lengths, *, block_m, interpret):
    if not pallas_available():
        return _ref.decode_attention_ref(
            q[:, 0], jnp.swapaxes(cache_k, 1, 2),
            jnp.swapaxes(cache_v, 1, 2), lengths.astype(jnp.int32))[:, None]
    qb = q[:, 0]  # (B,Hq,D)
    kt = _pad_to(jnp.swapaxes(cache_k, 1, 2), 2, block_m)
    vt = _pad_to(jnp.swapaxes(cache_v, 1, 2), 2, block_m)
    out = decode_attention_bhd(qb, kt, vt, lengths.astype(jnp.int32),
                               block_m=block_m, interpret=interpret)
    return out[:, None]


def ssm_scan(dA: jax.Array, dBx: jax.Array, C: jax.Array, *, chunk: int = 16,
             interpret: Optional[bool] = None):
    """Chunked linear recurrence + output contraction (see ssm_scan.py).
    Pads S to a chunk multiple; padded steps have dA=0, dBx=0 so h_last is
    exact... padded dA must be 1 to keep h; handled here."""
    return _ssm_scan(dA, dBx, C, chunk=chunk,
                     interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssm_scan(dA, dBx, C, *, chunk, interpret):
    if not pallas_available():
        return _ref.ssm_scan_ref(dA, dBx, C)
    s = dA.shape[1]
    pad = (-s) % chunk
    if pad:
        # identity steps: h_t = 1*h_{t-1} + 0 ; C=0 so y_pad = garbage-free
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dBx = jnp.pad(dBx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, h_last = ssm_scan_chunked(dA, dBx, C, chunk=chunk, interpret=interpret)
    return y[:, :s], h_last


def ssm_scan_fused(delta: jax.Array, B: jax.Array, C: jax.Array,
                   x: jax.Array, A: jax.Array, *, chunk: int = 16,
                   interpret: Optional[bool] = None):
    """Fused-discretization selective scan (see ssm_scan.py): dA/dBx never
    touch HBM.  Pads S to a chunk multiple (identity steps)."""
    return _ssm_scan_fused(delta, B, C, x, A, chunk=chunk,
                           interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssm_scan_fused(delta, B, C, x, A, *, chunk, interpret):
    if not pallas_available():
        dA, dBx = _ref.ssm_discretize(delta, B, x, A)
        return _ref.ssm_scan_ref(dA, dBx, C)
    from repro.kernels.ssm_scan import ssm_scan_fused as _fused

    s = delta.shape[1]
    pad = (-s) % chunk
    if pad:
        # delta=0 => dA=1 (identity), dBx=0: state is preserved exactly
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    y, h_last = _fused(delta, B, C, x, A, chunk=chunk, interpret=interpret)
    return y[:, :s], h_last
