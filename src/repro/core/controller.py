"""ControllerPod — the paper's "workhorse" (Figs. 2-3).

One pod per remote job.  The pod:
  1. reads execution data from the associated config map,
  2. mounts secrets, connects to the remote resource manager over the
     HTTP/HTTPS API (the ONLY channel to the external system),
  3. fetches the job script (inline / s3 / remote) and stages extra data,
  4. submits IF AND ONLY IF the config map holds no job id — a restarted pod
     finds the id and resumes monitoring instead of resubmitting (paper §5.1),
  5. runs the monitor loop: poll status, mirror it into the config map,
     honour the kill flag, tolerate transient network failures (UNKNOWN
     after ``unknown_after`` consecutive failures — never invent a terminal
     state),
  6. on completion downloads outputs and uploads them to S3, then exits
     0 (COMPLETED) / 1 (FAILED or CANCELLED), exactly like Fig. 3.

Pod death is simulated by ``kill_pod()``: the thread aborts at the next
action boundary WITHOUT flushing anything — only config-map state survives,
which is precisely the failure mode the paper's design addresses.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, Mapping, Optional, Type

from repro.core.backends import base as B
from repro.core.objectstore import NoSuchKey, ObjectStore
from repro.core.resource import (DONE, FAILED, KILLED, RUNNING, SUBMITTED,
                                 UNKNOWN)
from repro.core.rest import ResourceManagerDirectory, TransportError
from repro.core.secrets import SecretStore
from repro.core.statestore import ConfigMap, StateStore

# backend canonical -> bridge state
_CANON_TO_BRIDGE = {
    B.QUEUED: SUBMITTED,
    B.RUNNING: RUNNING,
    B.COMPLETED: DONE,
    B.FAILED: FAILED,
    B.CANCELLED: KILLED,
}


class PodKilled(BaseException):
    """Out-of-band pod termination (node failure / eviction)."""


class ControllerPod:
    # pod phases (Kubernetes-like)
    PENDING = "Pending"
    RUNNING_PHASE = "Running"
    SUCCEEDED = "Succeeded"
    FAILED_PHASE = "Failed"
    KILLED_PHASE = "Killed"   # external kill (node loss) — operator restarts

    def __init__(self, name: str, configmap: ConfigMap, secrets: SecretStore,
                 objectstore: ObjectStore, directory: ResourceManagerDirectory,
                 adapters: Mapping[str, Type[B.ResourceAdapter]],
                 min_sleep: float = 0.005):
        self.name = name
        self.cm = configmap
        self.secrets = secrets
        self.s3 = objectstore
        self.directory = directory
        self.adapters = dict(adapters)
        self.min_sleep = min_sleep
        self.phase = self.PENDING
        self.exit_code: Optional[int] = None
        self.error: str = ""
        self._killed = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"pod-{name}")

    # -- lifecycle --------------------------------------------------------

    def start(self) -> None:
        self._thread.start()

    def kill_pod(self) -> None:
        """Simulate pod/node failure: abort without flushing state."""
        self._killed.set()

    def alive(self) -> bool:
        return self._thread.is_alive()

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)

    # -- internals ----------------------------------------------------------

    def _checkpoint(self) -> None:
        """Action boundary: a killed pod dies here, state unflushed."""
        if self._killed.is_set():
            raise PodKilled(self.name)

    def _sleep(self, seconds: float) -> None:
        deadline = time.time() + seconds
        while time.time() < deadline:
            self._checkpoint()
            time.sleep(min(self.min_sleep, max(deadline - time.time(), 0)))

    def _adapter_for(self, image: str, client) -> B.ResourceAdapter:
        base_image = image.split(":")[0]
        if base_image not in self.adapters:
            raise KeyError(f"no controller implementation for image {image!r}")
        return self.adapters[base_image](client)

    # -- paper Fig. 2: main --------------------------------------------------

    def _run(self) -> None:
        self.phase = self.RUNNING_PHASE
        try:
            self._main()
        except PodKilled:
            self.phase = self.KILLED_PHASE
        except Exception as e:  # pod crash (bug/unhandled) — operator restarts
            self.error = f"{type(e).__name__}: {e}"
            self.phase = self.KILLED_PHASE

    def _main(self) -> None:
        cm_data = self.cm.data
        url = cm_data["resourceURL"]
        image = cm_data["image"]
        poll = float(cm_data.get("updateinterval", "20"))

        # credentials from the mounted secret (never from the spec/config map)
        secret = self.secrets.mount(cm_data["resourcesecret"])
        token = secret.get("token", "")
        client = self.directory.connect(url, token)
        adapter = self._adapter_for(image, client)

        job_id = cm_data.get("id", "")
        if not job_id:
            job_id = self._submit(adapter, cm_data)
            if not job_id:
                return  # FAILED already recorded; Fig. 2 klog.Exit path
        else:
            # paper: "Job has ID in ConfigMap. Handling state."
            pass
        self._monitor(adapter, job_id, poll, cm_data)

    def _submit(self, adapter: B.ResourceAdapter, cm_data: Dict[str, str]) -> str:
        self._checkpoint()
        try:
            script = self._fetch_script(cm_data)
            self._stage_additional_data(adapter, cm_data)
            properties = json.loads(cm_data.get("jobproperties", "{}"))
            params = json.loads(cm_data.get("jobparams", "{}"))
            job_id = adapter.submit(script, properties, params)
        except (B.SubmitError, TransportError, NoSuchKey, KeyError, ValueError) as e:
            self.cm.update({"jobStatus": FAILED,
                            "message": f"Failed to submit a job to HPC resource: {e}"})
            self._exit(1)
            return ""
        self.cm.update({"id": job_id, "jobStatus": SUBMITTED,
                        "submit_time": str(time.time()), "message": ""})
        return job_id

    def _fetch_script(self, cm_data: Dict[str, str]) -> str:
        loc = cm_data.get("scriptlocation", "inline")
        script = cm_data.get("jobscript", "")
        if loc == "inline":
            return script
        if loc == "s3":
            bucket, key = ObjectStore.parse_ref(script)
            return self.s3.get_text(bucket, key)
        if loc == "remote":
            return script  # path already on the resource; submit by reference
        raise ValueError(f"scriptlocation {loc!r}")

    def _stage_additional_data(self, adapter: B.ResourceAdapter,
                               cm_data: Dict[str, str]) -> None:
        """Upload extra input files (s3 -> resource) where the API allows."""
        refs = [r for r in cm_data.get("additionaldata", "").split(",") if r]
        for ref in refs:
            bucket, key = ObjectStore.parse_ref(ref)
            data = self.s3.get(bucket, key)
            name = key.split("/")[-1]
            if not adapter.upload(name, data):
                # API without upload (e.g. slurmrestd): the job script must
                # fetch from S3 itself; record for observability.
                self.cm.update({"staging": f"unsupported:{name}"})

    # -- paper Fig. 3: monitor ------------------------------------------------

    def _monitor(self, adapter: B.ResourceAdapter, job_id: str, poll: float,
                 cm_data: Dict[str, str]) -> None:
        unknown_after = int(cm_data.get("unknown_after", "5"))
        consecutive_failures = 0
        kill_sent = False
        while True:
            self._sleep(poll)
            cm_now = self.cm.data  # Fig. 3: "Get current config map"
            try:
                info = adapter.status(job_id)
                consecutive_failures = 0
            except (TransportError, B.SubmitError) as e:
                consecutive_failures += 1
                if consecutive_failures >= unknown_after:
                    # black-box honesty: unreachable != dead
                    self.cm.update({"jobStatus": UNKNOWN,
                                    "message": f"resource unreachable: {e}"})
                continue

            state = _CANON_TO_BRIDGE[info["state"]]
            updates = {"jobStatus": state, "message": info.get("reason", "") or ""}
            if info.get("start_time"):
                updates["start_time"] = str(info["start_time"])
            if info.get("end_time"):
                updates["end_time"] = str(info["end_time"])
            if info.get("results_location"):
                updates["results_location"] = info["results_location"]
            self.cm.update(updates)

            if cm_now.get("kill", "false") == "true" and not kill_sent:
                try:
                    adapter.cancel(job_id)
                    kill_sent = True
                except TransportError:
                    pass  # retry next poll

            if state == DONE:
                self._finalize_outputs(adapter, job_id, cm_now)
                self._exit(0)
                return
            if state in (FAILED, KILLED):
                self._exit(1)
                return

    def _finalize_outputs(self, adapter: B.ResourceAdapter, job_id: str,
                          cm_data: Dict[str, str]) -> None:
        """Download outputs from the resource; upload to S3 if configured."""
        self._checkpoint()
        props = json.loads(cm_data.get("jobproperties", "{}"))
        bucket = cm_data.get("s3uploadbucket", "")
        names = [n for n in cm_data.get("s3uploadfiles", "").split(",") if n]
        for key in ("OutputFileName", "ErrorFileName"):
            if props.get(key) and props[key] not in names:
                names.append(props[key])
        uploaded = []
        for name in names:
            data = adapter.download(name)
            if data is None and hasattr(adapter, "download_logs"):
                data = adapter.download_logs(job_id)  # ray idiom
            if data is None:
                continue
            if bucket:
                self.s3.put(bucket, f"{self.name}/{name}", data)
                uploaded.append(f"{bucket}:{self.name}/{name}")
        if uploaded:
            self.cm.update({"outputs": ",".join(uploaded)})

    def _exit(self, code: int) -> None:
        self.exit_code = code
        self.phase = self.SUCCEEDED if code == 0 else self.FAILED_PHASE
