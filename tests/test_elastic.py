"""Elastic job arrays: spec-patch reconcile with delta submit/cancel.

The tentpole guarantees under test:

  * scaling a LIVE array submits/cancels exactly the delta — a live index is
    never resubmitted, scale-down cancels the highest indices first, and a
    controller pod killed mid-patch resumes the half-applied patch from the
    config map;
  * `metadata.generation` / `status.observedGeneration` form the standard
    Kubernetes convergence handshake (`wait_reconciled`);
  * the chaos suite drives random (seeded, deterministic) interleavings of
    scale-up / scale-down / kill-pod against the simulated cluster and checks
    the two lifecycle invariants post-hoc from the cluster's own records:
      1. "every index submitted at most once while live" — for any array
         index, the [submit_time, end_time) intervals of its remote jobs
         never overlap;
      2. "final remote job set == final desired set" — once reconciled, the
         live remote jobs are exactly indices 0..desired-1, once each.

Both operator modes run the same protocol object, so everything here is
mode-parametrized.
"""
import json
import random
import time

import pytest

from repro.core import (ArraySpec, BridgeEnvironment, DONE, FaultProfile,
                        IMAGES, PlacementCandidate, PlacementSpec,
                        RetryPolicy, URLS, ValidationError)
from repro.core.backends import base as B
from repro.core.backends.lsf import LSFAdapter
from repro.core.backends.slurm import SlurmAdapter

MODES = ["multiplexed", "pod-per-cr"]
# (mode, cadence) matrix: both runtimes under the default fixed cadence,
# plus the event-driven cadences on the multiplexed runtime.  Every
# assertion below is cadence-agnostic — the lifecycle invariants must hold
# regardless of how tick deadlines are scheduled or whether a status poll
# was watch-elided.
OPERATORS = [(m, "fixed") for m in MODES] + [
    ("multiplexed", "adaptive"), ("multiplexed", "watch"),
    ("multiplexed", "wakeup")]


class FanoutLSFAdapter(LSFAdapter):
    """LSF with NATIVE_ARRAYS withheld: keeps the facade fan-out reconcile
    path under chaos now that the real dialect submits arrays natively."""
    capabilities = LSFAdapter.capabilities - {B.Capability.NATIVE_ARRAYS}


def _wait(predicate, timeout=30, interval=0.005):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


def _ids(handle):
    return [s for s in handle.status().job_id.split(",") if s]


def _index_of(cluster_job):
    """The array index a remote job was submitted for (the native slurm
    marker, the native 1-based LSF marker, or the bridge's own marker)."""
    p = cluster_job.params
    if "SLURM_ARRAY_TASK_ID" in p:
        return int(p["SLURM_ARRAY_TASK_ID"])
    if "BRIDGE_ARRAY_INDEX" in p:
        return int(p["BRIDGE_ARRAY_INDEX"])
    if "LSB_JOBINDEX" in p:
        return int(p["LSB_JOBINDEX"]) - 1
    return None


def _assert_at_most_once_while_live(jobs):
    """Invariant 1: per index, remote-job lifetimes never overlap."""
    by_index = {}
    for j in jobs.values():
        idx = _index_of(j)
        if idx is not None:
            by_index.setdefault(idx, []).append(j)
    for idx, members in by_index.items():
        members.sort(key=lambda j: j.submit_time)
        for prev, nxt in zip(members, members[1:]):
            assert prev.end_time is not None, (
                f"index {idx}: resubmitted while a prior job was still live")
            assert prev.end_time <= nxt.submit_time, (
                f"index {idx}: overlapping lifetimes "
                f"({prev.id} ended {prev.end_time}, "
                f"{nxt.id} submitted {nxt.submit_time})")


def _assert_remote_matches_desired(jobs, desired):
    """Invariant 2: live remote jobs are exactly indices 0..desired-1."""
    live = [j for j in jobs.values() if j.state in (B.QUEUED, B.RUNNING)]
    assert sorted(_index_of(j) for j in live) == list(range(desired)), (
        f"live remote set != desired 0..{desired - 1}: "
        f"{sorted((_index_of(j), j.id) for j in live)}")


# ---------------------------------------------------------------------------
# acceptance: 32 -> 48 -> 8 with exact deltas and a mid-patch pod kill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,cadence", OPERATORS)
def test_scale_32_up_48_down_8_exact_delta_with_midpatch_kill(mode, cadence):
    """Scaling a running 32-index array to 48 then 8 submits exactly 16 new
    jobs and cancels exactly 40 — zero resubmissions of live indices — and a
    controller pod killed mid-patch resumes the half-applied patch."""
    # per-request latency widens the mid-patch window so the kill reliably
    # lands while the 16-index delta fan-out is in flight
    fp = {"slurm": FaultProfile(latency=0.004, seed=42)}
    with BridgeEnvironment(default_duration=120, slots=4, fault_profiles=fp,
                           operator_kwargs={"mode": mode,
                                            "cadence": cadence}) as env:
        h = env.bridge.submit("elastic", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "120"}, array=ArraySpec(count=32)))
        assert _wait(lambda: len(_ids(h)) == 32)

        h.scale(48)
        assert _wait(lambda: len(_ids(h)) >= 33, timeout=20)
        env.operator.pods["default/elastic"].kill_pod()  # mid-patch

        job = h.wait_reconciled(timeout=60)
        assert len(_ids(h)) == 48
        assert job.status.restarts >= 1
        assert len(env.clusters["slurm"].jobs) == 48, (
            "exactly 16 new submissions — the restarted pod must resume the "
            "half-applied patch, not redo it")

        h.scale(8)
        job = h.wait_reconciled(timeout=60)
        assert job.generation == 3 and job.status.observed_generation == 3
        jobs = env.clusters["slurm"].jobs
        assert len(jobs) == 48, "scale-down must not submit anything"
        cancelled = [j for j in jobs.values() if j.state == B.CANCELLED]
        assert len(cancelled) == 40, "exactly the 40 excess indices cancelled"
        assert {_index_of(j) for j in cancelled} == set(range(8, 48)), (
            "the HIGHEST indices are the ones cancelled")
        # with 4 slots most excess indices never started: CANCEL_QUEUED path
        assert any(j.start_time is None for j in cancelled)
        _assert_remote_matches_desired(jobs, 8)
        _assert_at_most_once_while_live(jobs)
        assert sorted(job.status.index_states, key=int) == [
            str(i) for i in range(8)]


# ---------------------------------------------------------------------------
# chaos: random interleavings of scale-up / scale-down / kill-pod
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,kind,seed,cadence", [
    ("multiplexed", "slurm", 101, "fixed"),  # native arrays, batched status
    ("multiplexed", "lsf", 202, "fixed"),    # fan-out (NATIVE_ARRAYS gone)
    ("pod-per-cr", "slurm", 303, "fixed"),
    ("pod-per-cr", "lsf", 404, "fixed"),
    ("multiplexed", "sliced", 505, "fixed"),  # sharded: slurm + lsf slices
    ("pod-per-cr", "sliced", 606, "fixed"),
    # event-driven cadences under the same chaos: back-off must never delay
    # a patch (poke resets the deadline) and watch-elided ticks must never
    # hide a transition from the invariant checks
    ("multiplexed", "slurm", 707, "adaptive"),
    ("multiplexed", "sliced", 808, "adaptive"),
    ("multiplexed", "slurm", 909, "watch"),
    ("multiplexed", "sliced", 1010, "watch"),
    # wakeup: watcher pokes + id-filtered polls under the same chaos — an
    # event payload must never mask a kill/patch, and a poll that fails
    # mid-storm must not advance the event watermark past a terminal
    ("multiplexed", "slurm", 1111, "wakeup"),
    ("multiplexed", "sliced", 1212, "wakeup"),
])
def test_chaos_lifecycle(mode, kind, seed, cadence):
    """Seeded random op interleavings (deterministic op sequence + seeded
    fault injection) must preserve both lifecycle invariants — including on
    a SLICED array, where a kill can land mid-rebalance and the final live
    set is the union of every slice's remote jobs."""
    rng = random.Random(seed)
    kinds = ("slurm", "lsf") if kind == "sliced" else (kind,)
    fp = {k: FaultProfile(drop_rate=0.02, seed=seed + i)
          for i, k in enumerate(kinds)}
    with BridgeEnvironment(default_duration=300, slots=6, fault_profiles=fp,
                           operator_kwargs={"mode": mode,
                                            "cadence": cadence}) as env:
        placement = None
        if kind == "lsf":
            env.operator.adapters[FanoutLSFAdapter.image] = FanoutLSFAdapter
        if kind == "sliced":
            env.clusters["lsf"].slots = 3  # uneven capacity
            placement = PlacementSpec(candidates=[
                PlacementCandidate(URLS[k], IMAGES[k], f"{k}-secret")
                for k in kinds], strategy="spread")
        h = env.bridge.submit("chaos", env.make_spec(
            kinds[0], script="member", updateinterval=0.01,
            jobproperties={"WallSeconds": "300"},
            array=ArraySpec(count=4),
            retry=RetryPolicy(limit=100),  # absorb injected submit drops
            placement=placement))
        assert _wait(lambda: len(_ids(h)) == 4)

        desired = 4
        for _ in range(10):
            op = rng.choice(["up", "down", "kill", "settle"])
            if op == "up":
                desired = min(desired + rng.randint(1, 6), 24)
                h.scale(desired)
            elif op == "down":
                desired = max(desired - rng.randint(1, 6), 1)
                h.scale(desired)
            elif op == "kill":
                pod = env.operator.pods.get("default/chaos")
                if pod is not None:
                    pod.kill_pod()
            time.sleep(rng.uniform(0.0, 0.05))

        job = h.wait_reconciled(timeout=90)
        assert not job.status.terminal(), job.status.message
        jobs = {}
        for k in kinds:
            jobs.update(env.clusters[k].jobs)  # id ranges are disjoint
        _assert_remote_matches_desired(jobs, desired)
        _assert_at_most_once_while_live(jobs)
        assert sorted(job.status.index_states, key=int) == [
            str(i) for i in range(desired)]
        assert len(_ids(h)) == desired
        if kind == "sliced":
            placements = h.placements()
            assert len(placements) == 2, "both slices must stay live"
            union = sorted(i for p in placements for i in p["indices"])
            assert union == list(range(desired)), (
                "union of slices == final desired set")


# ---------------------------------------------------------------------------
# capability-gated scale-down + per-index state GC + promptness
# ---------------------------------------------------------------------------


def test_scale_down_without_cancel_queued_waits_for_running():
    """An adapter without CANCEL_QUEUED cannot kill queued indices: the
    drain must hold the cancel until each condemned index starts RUNNING —
    never cancelling in-queue — and still converge."""
    class NoQueuedCancel(SlurmAdapter):
        capabilities = SlurmAdapter.capabilities - {B.Capability.CANCEL_QUEUED}

    with BridgeEnvironment(default_duration=0.25, slots=2) as env:
        env.operator.adapters[NoQueuedCancel.image] = NoQueuedCancel
        h = env.bridge.submit("nq", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "0.25"}, array=ArraySpec(count=6)))
        assert _wait(lambda: len(_ids(h)) == 6)
        h.scale(2)
        job = h.wait_reconciled(timeout=60)
        jobs = env.clusters["slurm"].jobs
        assert len(jobs) == 6
        for j in jobs.values():
            if j.state == B.CANCELLED:
                assert j.start_time is not None, (
                    f"{j.id} was cancelled while QUEUED despite the adapter "
                    f"not declaring CANCEL_QUEUED")
        assert h.wait(timeout=60).status.state == DONE  # live pair completes


def test_scale_down_prunes_orphaned_per_index_state():
    """Satellite: after a scale-down the config map must drop the per-index
    keys of removed indices (index_states entries, retry budget) so repeated
    resizes never grow the store monotonically."""
    with BridgeEnvironment(default_duration=120, slots=4) as env:
        h = env.bridge.submit("gc", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "120"},
            array=ArraySpec(count=2), retry=RetryPolicy(limit=2)))
        assert _wait(lambda: len(_ids(h)) == 2)
        baseline_keys = None
        for count in (12, 3, 12, 3):
            h.scale(count)
            h.wait_reconciled(timeout=60)
            assert _wait(lambda: len(json.loads(env.statestore.get(
                "default/gc-bridge-cm").get("index_states"))) == count)
            cm = env.statestore.get("default/gc-bridge-cm").data
            states = json.loads(cm["index_states"])
            assert sorted(states, key=int) == [str(i) for i in range(count)]
            attempts = json.loads(cm.get("retry_attempts", "{}"))
            assert all(int(k) < count for k in attempts)
            assert not any(k.startswith("results_location_")
                           and int(k.rsplit("_", 1)[1]) >= count for k in cm)
            if count == 3:
                if baseline_keys is None:
                    baseline_keys = len(cm)
                else:
                    assert len(cm) == baseline_keys, (
                        "config-map key count grew across resize cycles")


def test_stalled_scale_up_surfaces_diagnostic_and_recovers():
    """A scale-up that cannot submit (job script vanished from S3) reports
    the stall in status.message every tick instead of silently spinning, and
    completes once the blocker clears."""
    with BridgeEnvironment(default_duration=120, slots=8) as env:
        env.s3.put("bkt", "script.sh", b"#!/bin/sh\ntrue\n")
        h = env.bridge.submit("stall", env.make_spec(
            "slurm", script="bkt:script.sh", scriptlocation="s3",
            updateinterval=0.02, jobproperties={"WallSeconds": "120"},
            array=ArraySpec(count=2)))
        assert _wait(lambda: len(_ids(h)) == 2)
        env.s3.delete("bkt", "script.sh")
        h.scale(4)
        assert _wait(lambda: "scale-up to 4 stalled at index 2"
                     in h.status().message, timeout=20), h.status().message
        assert len(_ids(h)) == 2, "no index may be submitted while stalled"
        env.s3.put("bkt", "script.sh", b"#!/bin/sh\ntrue\n")
        job = h.wait_reconciled(timeout=60)
        assert len(_ids(h)) == 4
        assert "stalled" not in job.status.message


def test_stalled_scale_up_holds_completion_until_applied():
    """Regression: a CR whose live indices all finish while a scale-up is
    stalled must NOT turn terminal — the accepted patch would be silently
    dropped.  It stays open, keeps retrying, and completes only once the
    full desired count has run."""
    with BridgeEnvironment(default_duration=0.2, slots=8) as env:
        env.s3.put("bkt", "s.sh", b"#!/bin/sh\ntrue\n")
        h = env.bridge.submit("hold", env.make_spec(
            "slurm", script="bkt:s.sh", scriptlocation="s3",
            updateinterval=0.02, jobproperties={"WallSeconds": "0.2"},
            array=ArraySpec(count=2)))
        assert _wait(lambda: len(_ids(h)) == 2)
        env.s3.delete("bkt", "s.sh")
        h.scale(4)
        # the two live indices complete while the scale-up cannot submit
        assert _wait(lambda: all(
            j.state == B.COMPLETED
            for j in env.clusters["slurm"].jobs.values()), timeout=20)
        time.sleep(0.2)  # several ticks with everything live terminal
        assert not h.status().terminal(), (
            "CR went terminal with the accepted scale-up never applied")
        env.s3.put("bkt", "s.sh", b"#!/bin/sh\ntrue\n")
        job = h.wait(timeout=30)
        assert job.status.state == DONE
        assert len(job.status.job_id.split(",")) == 4
        assert job.status.observed_generation == job.generation
        assert len(env.clusters["slurm"].jobs) == 4


def test_multiplexed_resize_applies_without_waiting_a_poll_period():
    """MonitorRuntime reconcile promptness: a spec patch pokes the task, so
    the delta is applied well before the (long) poll interval elapses."""
    with BridgeEnvironment(default_duration=120, slots=4,
                           operator_kwargs={"mode": "multiplexed"}) as env:
        h = env.bridge.submit("poke", env.make_spec(
            "slurm", script="member", updateinterval=5.0,
            jobproperties={"WallSeconds": "120"}, array=ArraySpec(count=2)))
        assert _wait(lambda: len(_ids(h)) == 2, timeout=20)
        t0 = time.time()
        h.scale(5)
        assert _wait(lambda: len(_ids(h)) == 5, timeout=20)
        assert time.time() - t0 < 2.5, (
            "resize waited for the poll deadline instead of being poked")


def test_repeated_patches_do_not_multiply_poll_rate():
    """Regression: every poke() supersedes the task's pending heap entry —
    repeated resizes must leave ONE scheduling chain, not k+1 chains each
    polling every interval (which would multiply REST traffic per patch)."""
    with BridgeEnvironment(default_duration=120, slots=8,
                           operator_kwargs={"mode": "multiplexed"}) as env:
        h = env.bridge.submit("rate", env.make_spec(
            "slurm", script="member", updateinterval=0.05,
            jobproperties={"WallSeconds": "120"}, array=ArraySpec(count=2)))
        assert _wait(lambda: len(_ids(h)) == 2)
        for count in (3, 4, 5, 6, 7):
            h.scale(count)
            h.wait_reconciled(timeout=30)
        srv = env.servers["slurm"]
        req0 = srv.request_count
        time.sleep(0.5)  # ~10 poll ticks at 0.05s, 1 batched request each
        per_tick = (srv.request_count - req0) / (0.5 / 0.05)
        assert per_tick <= 3, (
            f"{per_tick:.1f} requests/tick after 5 patches — duplicate "
            f"scheduling chains are multiplying the poll rate")


# ---------------------------------------------------------------------------
# facade-level patch semantics
# ---------------------------------------------------------------------------


def test_patch_rejects_immutable_fields_and_terminal_jobs():
    import dataclasses

    with BridgeEnvironment(default_duration=0.05) as env:
        h = env.bridge.submit("pv", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "5"}, array=ArraySpec(count=2)))
        assert _wait(lambda: len(_ids(h)) == 2)
        with pytest.raises(ValidationError, match="mutable"):
            h.patch(lambda s: dataclasses.replace(s, image="raypod:0.1"))
        with pytest.raises(ValidationError, match=">= 1"):
            h.scale(0)
        h.cancel()
        assert h.wait(timeout=30).status.terminal()
        with pytest.raises(ValidationError, match="terminal"):
            h.scale(4)


def test_scale_pads_and_truncates_indexed_params():
    """indexed_params (when used) tracks the new count: padded with empty
    overlays on growth, truncated on shrink — and the new indices' params
    reach the remote jobs."""
    with BridgeEnvironment(default_duration=120, slots=8) as env:
        h = env.bridge.submit("ip", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "120"},
            array=ArraySpec(count=2, indexed_params=[{"K": "a"}, {"K": "b"}])))
        assert _wait(lambda: len(_ids(h)) == 2)
        h.scale(4)
        job = h.wait_reconciled(timeout=60)
        assert job.spec.array.indexed_params == [
            {"K": "a"}, {"K": "b"}, {}, {}]
        h.scale(1)
        job = h.wait_reconciled(timeout=60)
        assert job.spec.array.indexed_params == [{"K": "a"}]
        members = {_index_of(j): j
                   for j in env.clusters["slurm"].jobs.values()}
        assert members[0].params["K"] == "a" and members[1].params["K"] == "b"
        assert "K" not in members[2].params


def test_scheduler_scale_placed_reconsults_load():
    """Satellite-spec scheduler hook: scale-up re-consults the load ranking
    and refuses growth onto an unreachable target; scale-down proceeds."""
    from repro.core import Candidate, IMAGES, URLS, LoadAwareScheduler

    with BridgeEnvironment(default_duration=120, slots=8) as env:
        sched = LoadAwareScheduler(env.bridge, [
            Candidate(URLS[k], IMAGES[k], f"{k}-secret")
            for k in ("slurm", "lsf")])
        h = env.bridge.submit("sp", env.make_spec(
            "slurm", script="member", updateinterval=0.02,
            jobproperties={"WallSeconds": "120"}, array=ArraySpec(count=2)))
        assert _wait(lambda: len(_ids(h)) == 2)
        sched.scale_placed("sp", 4)
        assert _wait(lambda: len(_ids(h)) == 4)
        env.servers["slurm"].fault.begin_outage()
        try:
            with pytest.raises(RuntimeError, match="not schedulable"):
                sched.scale_placed("sp", 8)
            sched.scale_placed("sp", 2)  # shrinking needs no capacity check
        finally:
            env.servers["slurm"].fault.end_outage()
        assert _wait(lambda: len(_ids(h)) == 2, timeout=60)
