"""Version-portable sharded ``jax.jit``.

The step bundles (repro.steps) carry *PartitionSpec pytrees* as their
in/out shardings.  Newer JAX accepts raw specs in ``jax.jit`` whenever a
mesh has been made current (``set_mesh``); 0.4.x rejects them with
"jax.jit only supports `Sharding`s being passed to in_shardings".

:func:`resolve_shardings` closes the gap by binding every spec leaf to a
concrete ``NamedSharding`` on the given mesh — valid on every JAX
version — and :func:`jit_sharded` is the drop-in ``jax.jit`` wrapper the
launchers use.  ``None`` subtrees (= let XLA decide) pass through
untouched, as do leaves that are already ``Sharding`` objects.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def resolve_shardings(mesh: Mesh, tree: Any) -> Any:
    """Bind PartitionSpec leaves in ``tree`` to ``NamedSharding(mesh, .)``."""
    def fix(leaf):
        if isinstance(leaf, PartitionSpec):
            return NamedSharding(mesh, leaf)
        return leaf

    return jax.tree_util.tree_map(
        fix, tree, is_leaf=lambda x: isinstance(x, PartitionSpec))


def jit_sharded(fn: Callable[..., Any], mesh: Mesh, *,
                in_shardings: Any = None, out_shardings: Any = None,
                donate_argnames: Optional[Sequence[str]] = None,
                **jit_kwargs: Any) -> Any:
    """``jax.jit`` that accepts PartitionSpec pytrees on every JAX.

    ``donate_argnames`` may be empty/None and is then omitted entirely.
    """
    kwargs = dict(jit_kwargs)
    if donate_argnames:
        kwargs["donate_argnames"] = tuple(donate_argnames)
    return jax.jit(fn,
                   in_shardings=resolve_shardings(mesh, in_shardings),
                   out_shardings=resolve_shardings(mesh, out_shardings),
                   **kwargs)


def cost_analysis_dict(compiled: Any) -> dict:
    """``Compiled.cost_analysis()`` normalized to one flat dict.

    0.4.x returns a list with one per-executable dict; newer JAX returns
    the dict itself (and may return None when analysis is unavailable).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
