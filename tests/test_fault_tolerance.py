"""The paper's key mechanism: config-map state makes pod restarts safe.

"Because the remote job ID is kept in the config map, ... the pod will know
that the remote job is already running and will not try to restart it."
"""
import json
import time

import pytest

from repro.core import (BridgeEnvironment, DONE, KILLED, RUNNING, SUBMITTED,
                        UNKNOWN)


@pytest.fixture()
def env():
    with BridgeEnvironment(default_duration=0.05) as e:
        yield e


def _wait_for_state(env, name, states, timeout=10):
    deadline = time.time() + timeout
    while time.time() < deadline:
        job = env.registry.get(name)
        if job and job.status.state in states:
            return job
        time.sleep(0.005)
    raise TimeoutError(f"{name} never reached {states}: "
                       f"{env.registry.get(name).status.state}")


def test_pod_restart_resumes_without_resubmission(env):
    """Kill the controller pod mid-monitoring; the restarted pod must attach
    to the SAME remote job (no second submission)."""
    spec = env.make_spec("slurm", script="long job", updateinterval=0.02,
                         jobproperties={"WallSeconds": "1.0"})
    env.submit("restartme", spec)
    job = _wait_for_state(env, "restartme", (SUBMITTED, RUNNING))
    first_id = None
    deadline = time.time() + 5
    while time.time() < deadline and not first_id:
        first_id = env.registry.get("restartme").status.job_id
        time.sleep(0.005)
    assert first_id

    # node failure: kill the pod out-of-band
    pod = env.operator.pods["default/restartme"]
    pod.kill_pod()
    job = env.operator.wait_for("restartme", timeout=20)
    assert job.status.state == DONE
    assert job.status.restarts >= 1, "operator must have restarted the pod"
    assert job.status.job_id == first_id, "restarted pod must NOT resubmit"
    # exactly one job exists on the cluster
    assert len(env.clusters["slurm"].jobs) == 1


def test_repeated_pod_kills(env):
    """Multiple successive pod failures still converge to DONE, one job."""
    spec = env.make_spec("slurm", script="x", updateinterval=0.02,
                         jobproperties={"WallSeconds": "1.0"})
    env.submit("flaky", spec)
    _wait_for_state(env, "flaky", (SUBMITTED, RUNNING))
    kills = 0
    deadline = time.time() + 8
    while kills < 3 and time.time() < deadline:
        pod = env.operator.pods.get("default/flaky")
        if pod and pod.alive():
            pod.kill_pod()
            kills += 1
            time.sleep(0.1)
        else:
            time.sleep(0.01)
    job = env.operator.wait_for("flaky", timeout=20)
    assert job.status.state == DONE
    assert kills >= 1
    assert len(env.clusters["slurm"].jobs) == 1


def test_kill_before_submission_no_orphan(env):
    """Pod killed BEFORE it submits: restart submits exactly once."""
    spec = env.make_spec("slurm", script="x", updateinterval=0.02,
                         jobproperties={"WallSeconds": "0.3"})
    # kill the pod the moment it exists (likely pre-submit)
    env.submit("early", spec)
    deadline = time.time() + 5
    while time.time() < deadline:
        pod = env.operator.pods.get("default/early")
        if pod is not None:
            pod.kill_pod()
            break
    job = env.operator.wait_for("early", timeout=20)
    assert job.status.state == DONE
    assert len(env.clusters["slurm"].jobs) == 1, "no orphaned double submit"


def test_transport_flakiness_tolerated():
    """20% packet loss on every request: jobs still complete (monitor loop
    retries; statuses may transiently be stale but never invented)."""
    from repro.core.rest import FaultProfile

    with BridgeEnvironment(
            default_duration=0.05,
            fault_profiles={"slurm": FaultProfile(drop_rate=0.2, seed=42)}) as env:
        spec = env.make_spec("slurm", script="x", updateinterval=0.01,
                             jobproperties={"WallSeconds": "0.2"})
        env.submit("flaky-net", spec)
        job = env.operator.wait_for("flaky-net", timeout=30)
        assert job.status.state == DONE


def test_crash_loop_gives_unknown():
    """A pod that crash-loops past max_restarts surfaces UNKNOWN, not silence."""
    with BridgeEnvironment(default_duration=0.05,
                           operator_kwargs={"max_restarts": 2}) as env:
        spec = env.make_spec("slurm", script="x",
                             jobproperties={"WallSeconds": "30"},
                             updateinterval=0.02)
        env.submit("crashloop", spec)
        _wait_for_state(env, "crashloop", (SUBMITTED, RUNNING))
        # kill pods as fast as they respawn
        deadline = time.time() + 10
        while time.time() < deadline:
            job = env.registry.get("crashloop")
            if job.status.state == UNKNOWN:
                break
            pod = env.operator.pods.get("default/crashloop")
            if pod and pod.alive():
                pod.kill_pod()
            time.sleep(0.01)
        assert env.registry.get("crashloop").status.state == UNKNOWN
        assert "crash-looped" in env.registry.get("crashloop").status.message


def test_statestore_durability(tmp_path):
    """Config maps survive a full control-plane restart (file-backed)."""
    from repro.core.statestore import StateStore

    s1 = StateStore(root=str(tmp_path))
    cm = s1.create("ns/job-cm", {"id": "123", "jobStatus": "RUNNING"})
    cm.update({"jobStatus": "DONE"})
    # "restart" the control plane: brand-new store over the same root
    s2 = StateStore(root=str(tmp_path))
    assert s2.get("ns/job-cm").data == {"id": "123", "jobStatus": "DONE"}
