from repro.data.pipeline import (DataConfig, SyntheticDataset, dataset_for,
                                 with_frontend_stubs)
