"""granite-moe-3b-a800m [moe]: 40 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  32L d_model=1536 24H
(GQA kv=8) d_ff=512 (expert width) vocab=49155, MoE 40e top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49_155,
    activation="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512, n_shared_experts=0),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

SMOKE = ModelConfig(
    name="granite-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=32,
    vocab=256,
    activation="swiglu",
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared_experts=0),
    dtype="float32",
)
