"""Hand-rolled AdamW over pytrees (no optax in this container), with
ZeRO-1-style optimizer-state sharding and standard LR schedules.

Master params policy: params may be bf16; Adam moments are f32; the update is
computed in f32 and cast back to the param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.params import is_paramdef
from repro.sharding import dp_axes, spec_for, _axis_size


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree_util.tree_map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                                  tree), g


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"mu": zeros(params), "nu": zeros(params), "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads: Any, state: Dict[str, Any], params: Any, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if jnp.issubdtype(p.dtype, jnp.floating):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m2, v2

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_m = jax.tree_util.tree_leaves(state["mu"])
    flat_v = jax.tree_util.tree_leaves(state["nu"])
    flat_p = jax.tree_util.tree_leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, metrics


# ---------------------------------------------------------------------------
# Optimizer-state sharding (ZeRO-1)
# ---------------------------------------------------------------------------


def _zero1_spec(shape, base: P, mesh: Mesh) -> P:
    """Add unused data-parallel axes to the first divisible unsharded dim."""
    dp = dp_axes(mesh)
    used = set()
    for e in base:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            used.add(a)
    free_dp = tuple(a for a in dp if a not in used)
    if not free_dp:
        return base
    size = _axis_size(mesh, free_dp)
    entries = list(base) + [None] * (len(shape) - len(base))
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % size == 0:
            entries[i] = free_dp if len(free_dp) > 1 else free_dp[0]
            return P(*entries)
    return base


def opt_pspecs(defs: Any, rules: Dict[str, Any], mesh: Mesh, zero1: bool = True) -> Any:
    """PartitionSpecs for the adamw state tree matching ``adamw_init``."""

    def one(d):
        base = spec_for(d.shape, d.axes, rules, mesh)
        return _zero1_spec(d.shape, base, mesh) if zero1 else base

    mu = jax.tree_util.tree_map(one, defs, is_leaf=is_paramdef)
    return {"mu": mu, "nu": jax.tree_util.tree_map(lambda x: x, mu), "step": P()}
