"""Model serving over the bridge: a BridgeService hosts REAL ServingEngine
replicas on two jaxlocal resource managers, and the request router
load-balances generate calls across them — then one replica is killed
mid-traffic and the service heals without losing a single accepted request.

What this demonstrates end-to-end:

  * ``spec.placement`` (spread) lands the 2 replicas on 2 different
    simulated resource managers;
  * each replica is a long-lived serve-mode remote job hosting a
    continuous-batching ``ServingEngine`` behind ``POST .../invoke``;
  * ``ServiceHandle.router()`` picks the least-loaded READY replica per
    request and retries replica faults on the surviving replica;
  * a killed replica is condemned and resubmitted under the same
    at-most-once bookkeeping job arrays use, and readyReplicas converges
    back to spec.

  PYTHONPATH=src python examples/model_serving.py
"""
import json
import threading
import time

from repro.core import (BridgeEnvironment, HealthProbeSpec, IMAGES,
                        PlacementCandidate, PlacementSpec, TOKENS, URLS)
from repro.core.backends import jaxlocal as JX

MAX_NEW = 4


def main() -> None:
    with BridgeEnvironment(slots=8) as env:
        # a SECOND jaxlocal resource manager: same dialect and token, its
        # own URL and job-id range — the service spreads replicas over both
        url2 = "https://jax.pod1.example.com"
        cluster2 = JX.make_jaxlocal_cluster(env.s3, name="jaxlocal2",
                                            slots=8, start_numbering=8000)
        env.clusters["jaxlocal2"] = cluster2  # env.stop() shuts it down too
        srv2 = JX.make_server(cluster2, token=TOKENS["jaxlocal"])
        env.servers["jaxlocal2"] = srv2
        env.directory.register(url2, srv2)

        script = json.dumps({"mode": "serve", "arch": "gemma-2b",
                             "max_batch": 4, "max_len": 48,
                             "prefill_len": 8, "seed": 0})
        spec = env.make_service_spec(
            "jaxlocal", replicas=2, script=script, updateinterval=0.05,
            # generous startup budget: a replica spends ticks loading weights
            health=HealthProbeSpec(failure_threshold=5,
                                   startup_failure_threshold=2000),
            placement=PlacementSpec(candidates=[
                PlacementCandidate(URLS["jaxlocal"], IMAGES["jaxlocal"],
                                   "jaxlocal-secret"),
                PlacementCandidate(url2, IMAGES["jaxlocal"],
                                   "jaxlocal-secret"),
            ], strategy="spread"))

        handle = env.bridge.submit_service("llm", spec)
        t0 = time.time()
        handle.wait_ready(timeout=120)
        print(f"2 replicas ready in {time.time() - t0:.1f}s:")
        for e in handle.endpoints():
            print(f"  replica {e['replica']}: job {e['job_id']} on "
                  f"{e['resourceURL']}")
        urls = {e["resourceURL"] for e in handle.endpoints()}
        assert len(urls) == 2, "replicas must land on BOTH managers"

        router = handle.router(request_timeout=90)
        stop = threading.Event()
        completed, failures = [], []

        def traffic(tid):
            i = 0
            while not stop.is_set():
                try:
                    out = router.request({"prompt": [1 + tid, 2, 3, i % 50],
                                          "max_new_tokens": MAX_NEW})
                    if len(out["tokens"]) != MAX_NEW:
                        failures.append((tid, i, out))
                    completed.append(out["served_by"])
                except Exception as exc:
                    failures.append((tid, i, repr(exc)))
                i += 1

        threads = [threading.Thread(target=traffic, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        time.sleep(2.0)  # traffic flowing across both replicas

        victim = handle.endpoints()[0]
        vcluster = (env.clusters["jaxlocal"]
                    if victim["resourceURL"] == URLS["jaxlocal"]
                    else cluster2)
        print(f"killing replica {victim['replica']} "
              f"(job {victim['job_id']}) mid-traffic...")
        t_kill = time.time()
        vcluster.cancel_if_live(victim["job_id"])

        deadline = time.time() + 120
        while time.time() < deadline:
            ids = [e["job_id"] for e in handle.endpoints()]
            if (victim["job_id"] not in ids
                    and handle.ready_replicas() == 2):
                break
            time.sleep(0.05)
        recovery = time.time() - t_kill
        assert handle.ready_replicas() == 2, "service never recovered"
        print(f"replaced within {recovery:.1f}s; readyReplicas back to 2")

        time.sleep(1.0)  # traffic over the healed set
        stop.set()
        for t in threads:
            t.join(timeout=120)

        assert not failures, f"lost/failed requests: {failures[:3]}"
        by_replica = {}
        for jid in completed:
            by_replica[jid] = by_replica.get(jid, 0) + 1
        print(f"{len(completed)} requests served, zero lost: {by_replica}")
        assert len(by_replica) >= 2, "router never balanced across replicas"

        stats = router.stats()
        for jid, s in sorted(stats.items()):
            p99 = f"{s['p99_s']:.3f}s" if s["p99_s"] is not None else "n/a"
            print(f"  job {jid}: {s['requests']} reqs, {s['errors']} errors, "
                  f"p99={p99}")

        handle.cancel()
        svc = handle.wait(timeout=60)
        print(f"final: {svc.status.state}")


if __name__ == "__main__":
    main()
