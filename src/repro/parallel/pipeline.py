"""GPipe-style pipeline parallelism over a mesh axis (default: "pod").

Alternative distribution strategy for the multi-pod mesh: instead of pure DP
over the pod axis, split the LAYER STACK across pods and stream microbatches
through with collective_permute between stages.  Provided as a composable
building block (validated at small scale in tests; selectable in the dry-run
via strategy="pp").

Schedule: forward-only GPipe loop with (n_micro + n_stages - 1) ticks.  Each
tick every stage processes one microbatch-slot and the activations rotate by
ppermute.  Works under jit+shard_map and differentiates (backward replays the
permutes in reverse), so it can wrap a train step at small scale.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any, x: jax.Array, mesh: Mesh,
                   axis: str = "pod", n_micro: int = None) -> jax.Array:
    """Run ``x`` through n_stages stages, each living on one ``axis`` shard.

    stage_params: pytree whose leaves have leading dim n_stages (sharded on
    ``axis``).  x: (B, ...) with B divisible by n_micro.  stage_fn is applied
    n_stages times in sequence overall.
    """
    n_stages = mesh.shape[axis]
    n_micro = n_micro or n_stages
    b = x.shape[0]
    if b % n_micro:
        raise ValueError(f"batch {b} % n_micro {n_micro}")

    in_specs = (jax.tree_util.tree_map(lambda _: P(axis), stage_params,
                                       is_leaf=lambda l: hasattr(l, "shape")),
                P())  # x replicated into the pipe; stage 0 selects its slice
    out_specs = P()

    def run(params_l, x_l):
        params_l = jax.tree_util.tree_map(lambda p: p[0], params_l)
        sidx = jax.lax.axis_index(axis)
        micro = x_l.reshape(n_micro, b // n_micro, *x_l.shape[1:])
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(micro[0])
        outs = jnp.zeros_like(micro)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            take = jnp.clip(t, 0, n_micro - 1)
            buf = jnp.where(sidx == 0,
                            jnp.where(t < n_micro, micro[take], buf), buf)
            y = stage_fn(params_l, buf)
            # last stage emits microbatch (t - n_stages + 1)
            emit_idx = t - (n_stages - 1)
            emit = jnp.clip(emit_idx, 0, n_micro - 1)
            outs = jnp.where((sidx == n_stages - 1) & (emit_idx >= 0),
                             outs.at[emit].set(y), outs)
            # rotate activations downstream
            y = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (y, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # only the last stage holds real outputs; share them with everyone
        outs = jax.lax.psum(
            jnp.where(sidx == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape(b, *x_l.shape[1:])

    return shard_map(run, mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)(stage_params, x)


def stack_stage_params(layer_params: Any, n_stages: int) -> Any:
    """Reshape (L, ...) stacked layer params into (n_stages, L/n_stages, ...)."""
    def f(p):
        L = p.shape[0]
        if L % n_stages:
            raise ValueError(f"layers {L} % stages {n_stages}")
        return p.reshape(n_stages, L // n_stages, *p.shape[1:])

    return jax.tree_util.tree_map(f, layer_params)
