"""Mixture-of-Experts layer: top-k router + expert FFNs.

Three routing implementations (``cfg.moe.routing_impl``):
  * ``dense``    — every expert computes every token, combined by router probs.
                   O(E) compute; only for tiny smoke configs / oracles.
  * ``dropping`` — GShard/Switch-style capacity-based one-hot dispatch under
                   pjit.  Auto-shardable (experts on "model" = EP via the SPMD
                   partitioner).  This is the BASELINE for the roofline; its
                   dispatch einsums inflate HLO FLOPs, which the §Perf hillclimb
                   attacks with the shard_map EP path.
  * ``ep_shard_map`` — beyond-paper optimized manual expert parallelism
                   (see repro/parallel/ep.py), selected by the perf config.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.models.layers import adtype, apply_mlp, mlp_defs

Params = Dict[str, Any]


def moe_defs(cfg) -> Params:
    m = cfg.moe
    d, dff, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ep = m.e_pad  # weights padded to a mesh-divisible expert count (§Perf)
    dt = adtype(cfg)
    defs: Params = {
        "router": ParamDef((d, e), ("embed", "expert"), dtype=jnp.float32),
        "w1": ParamDef((ep, d, dff), ("expert", "embed", "mlp"), dtype=dt),
        "w2": ParamDef((ep, dff, d), ("expert", "mlp", "embed"), dtype=dt),
    }
    if cfg.activation in ("swiglu", "geglu"):
        defs["w3"] = ParamDef((ep, d, dff), ("expert", "embed", "mlp"), dtype=dt)
    if m.n_shared_experts:
        defs["shared"] = mlp_defs(cfg, d_ff=m.d_ff_expert * m.n_shared_experts)
    return defs


def _router(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,S,d) -> (probs (B,S,E) f32, gates (B,S,k), idx (B,S,k))."""
    logits = (x.astype(jnp.float32) @ p["router"])  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.moe.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)  # renormalize
    return probs, gates, idx


def aux_load_balance_loss(probs: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (mean_prob * mean_assignment)."""
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    assign = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32).sum(2)  # (B,S,E)
    ce = jnp.mean(assign, axis=(0, 1))
    ce = ce / jnp.maximum(ce.sum(), 1e-9)
    return n_experts * jnp.sum(me * ce)


def _expert_ffn(p: Params, h: jax.Array, activation: str) -> jax.Array:
    """h: (E, C, d) -> (E, C, d), batched over experts."""
    u = jnp.einsum("ecd,edf->ecf", h, p["w1"])
    if activation == "swiglu":
        u = jax.nn.silu(u) * jnp.einsum("ecd,edf->ecf", h, p["w3"])
    elif activation == "geglu":
        u = jax.nn.gelu(u) * jnp.einsum("ecd,edf->ecf", h, p["w3"])
    elif activation == "relu2":
        u = jnp.square(jax.nn.relu(u))
    else:
        u = jax.nn.gelu(u)
    return jnp.einsum("ecf,efd->ecd", u, p["w2"])


def moe_dense(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    probs, gates, idx = _router(p, x, cfg)
    m = cfg.moe
    # all experts on all tokens: (E,B,S,d)
    def one(e):
        sub = {k: p[k][e] for k in ("w1", "w2", *(["w3"] if "w3" in p else []))}
        return apply_mlp(sub, x, cfg.activation)

    all_out = jnp.stack([one(e) for e in range(m.n_experts)], axis=0)
    combine = jnp.zeros(probs.shape, probs.dtype)
    combine = jnp.sum(
        jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32) * gates[..., None], axis=2
    )  # (B,S,E)
    out = jnp.einsum("ebsd,bse->bsd", all_out.astype(jnp.float32), combine).astype(x.dtype)
    return out, aux_load_balance_loss(probs, idx, m.n_experts)


def moe_dropping(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based dispatch (GShard).  Groups = batch dim; capacity per group."""
    b, s, d = x.shape
    m = cfg.moe
    probs, gates, idx = _router(p, x, cfg)
    e = m.e_pad  # one-hot over padded count (router never picks the pads)
    capacity = max(int(s * m.top_k * m.capacity_factor / m.n_experts), 1)
    # pad capacity to a lane-friendly multiple
    capacity = (capacity + 7) // 8 * 8

    # position of each (token, choice) within its expert queue
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (B,S,k,E)
    flat = onehot.reshape(b, s * m.top_k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat  # (B,S*k,E)
    pos = jnp.sum(flat * pos_in_expert, axis=-1).reshape(b, s, m.top_k)
    keep = pos < capacity

    oh_f = onehot.astype(x.dtype)
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=x.dtype)  # (B,S,k,C)
    # dispatch (B,S,E,C): 1 where token s goes to slot c of expert e
    dispatch = jnp.einsum("bske,bskc->bsec", oh_f, pos_oh * keep[..., None].astype(x.dtype))
    combine = jnp.einsum("bsk,bske,bskc->bsec", gates.astype(x.dtype), oh_f,
                         pos_oh * keep[..., None].astype(x.dtype))

    expert_in = jnp.einsum("bsec,bsd->becd", dispatch, x)  # (B,E,C,d)
    out_e = jax.vmap(lambda h: _expert_ffn(p, h, cfg.activation))(expert_in)  # (B,E,C,d)
    out = jnp.einsum("bsec,becd->bsd", combine, out_e)
    aux = aux_load_balance_loss(probs, idx, m.n_experts)
    return out, aux


def apply_moe(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    impl = cfg.moe.routing_impl
    if impl == "dense":
        out, aux = moe_dense(p, x, cfg)
    elif impl == "dropping":
        out, aux = moe_dropping(p, x, cfg)
    elif impl == "ep_shard_map":
        from repro.parallel.ep import moe_ep_shard_map

        out, aux = moe_ep_shard_map(p, x, cfg)
    elif impl == "ep_gather":
        from repro.parallel.ep import moe_ep_gather

        out, aux = moe_ep_gather(p, x, cfg)
    else:
        raise ValueError(impl)
    if cfg.moe.n_shared_experts:
        out = out + apply_mlp(p["shared"], x, cfg.activation)
    return out, aux
