"""Pallas kernel correctness: interpret-mode sweeps vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# -- flash attention -----------------------------------------------------------


@pytest.mark.parametrize("b,sq,hq,hkv,d", [
    (1, 128, 4, 4, 64),      # MHA, one block
    (2, 256, 8, 2, 64),      # GQA 4x, multi-block
    (1, 384, 5, 1, 128),     # MQA, odd heads, 3 blocks
    (2, 96, 4, 2, 32),       # needs padding (96 < 128)
    (1, 320, 2, 2, 64),      # padding to 384
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, sq, hq, hkv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(hash((b, sq, hq)) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, sq, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, sq, hkv, d), jnp.float32).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = R.flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True)
    want = jnp.swapaxes(want, 1, 2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_block_shape_invariance():
    """Different BlockSpec tilings must not change the numerics."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    b = ops.flash_attention(q, k, v, block_q=64, block_k=256, interpret=True)
    c = ops.flash_attention(q, k, v, block_q=256, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5, atol=1e-5)


def test_flash_attention_matches_model_xla_path():
    """The kernel slots into attn_forward and reproduces the xla path."""
    from repro.configs.base import get_smoke_config
    from repro.models import layers as L
    from repro.models.params import init_params

    cfg = get_smoke_config("granite-3-8b", d_model=64, n_heads=4, n_kv_heads=2,
                           head_dim=16)
    p = init_params(jax.random.PRNGKey(0), L.attention_defs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 64), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(48, dtype=jnp.int32), (2, 48))
    out_xla, _ = L.attn_forward(p, x, pos, cfg)
    import dataclasses
    cfg_pl = dataclasses.replace(cfg, attention_impl="pallas_interpret")
    out_pl, _ = L.attn_forward(p, x, pos, cfg_pl)
    np.testing.assert_allclose(np.asarray(out_xla), np.asarray(out_pl),
                               rtol=2e-4, atol=2e-4)


# -- decode attention ------------------------------------------------------------


@pytest.mark.parametrize("b,hq,hkv,m,d,block_m", [
    (2, 4, 4, 512, 64, 512),
    (2, 8, 2, 1024, 64, 256),
    (1, 4, 1, 300, 128, 512),   # padding (300 -> 512)
    (4, 2, 2, 64, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(b, hq, hkv, m, d, block_m, dtype):
    ks = jax.random.split(jax.random.PRNGKey(hash((b, hq, m)) % 2**31), 4)
    q = jax.random.normal(ks[0], (b, 1, hq, d), jnp.float32).astype(dtype)
    ck = jax.random.normal(ks[1], (b, m, hkv, d), jnp.float32).astype(dtype)
    cv = jax.random.normal(ks[2], (b, m, hkv, d), jnp.float32).astype(dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, m + 1, jnp.int32)
    got = ops.decode_attention(q, ck, cv, lengths, block_m=block_m,
                               interpret=True)
    want = R.decode_attention_ref(q[:, 0], jnp.swapaxes(ck, 1, 2),
                                  jnp.swapaxes(cv, 1, 2), lengths)[:, None]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_ignores_stale_cache():
    """Slots beyond ``lengths`` must not influence the output."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 1, 4, 32), jnp.float32)
    ck = jax.random.normal(ks[1], (1, 128, 2, 32), jnp.float32)
    cv = jax.random.normal(ks[2], (1, 128, 2, 32), jnp.float32)
    lengths = jnp.asarray([40], jnp.int32)
    base = ops.decode_attention(q, ck, cv, lengths, block_m=64, interpret=True)
    ck2 = ck.at[:, 40:].set(1e6)  # poison the invalid region
    cv2 = cv.at[:, 40:].set(-1e6)
    poisoned = ops.decode_attention(q, ck2, cv2, lengths, block_m=64,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                               rtol=1e-6, atol=1e-6)


# -- ssm scan ----------------------------------------------------------------


@pytest.mark.parametrize("b,s,di,n,chunk", [
    (2, 64, 32, 8, 16),
    (1, 128, 16, 4, 32),
    (2, 50, 8, 16, 16),    # padding (50 -> 64)
    (1, 16, 64, 16, 16),   # single chunk
])
def test_ssm_scan_matches_ref(b, s, di, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(hash((b, s, di)) % 2**31), 3)
    # decay in (0, 1) like exp(delta * A) with A < 0
    dA = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, di, n)) + 2.0)
    dBx = jax.random.normal(ks[1], (b, s, di, n), jnp.float32) * 0.1
    C = jax.random.normal(ks[2], (b, s, n), jnp.float32)
    y_got, h_got = ops.ssm_scan(dA, dBx, C, chunk=chunk, interpret=True)
    y_want, h_want = R.ssm_scan_ref(dA, dBx, C)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                               rtol=2e-4, atol=2e-5)


def test_ssm_scan_matches_model_mixer():
    """Kernel recurrence == the associative-scan inside ssm_forward."""
    from repro.configs.base import get_smoke_config
    from repro.models import ssm as SSM
    from repro.models.params import init_params

    cfg = get_smoke_config("hymba-1.5b")
    p = init_params(jax.random.PRNGKey(0), SSM.ssm_defs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    # reproduce the discretized inputs exactly as ssm_forward builds them
    xz = x @ p["in_proj"]
    di = xz.shape[-1] // 2
    xs = jax.nn.silu(SSM._causal_conv(xz[..., :di], p["conv_w"], p["conv_b"])[0])
    delta, B, C = SSM._sel_params(p, xs, cfg)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(delta[..., None] * A)
    dBx = delta[..., None] * B[:, :, None, :] * xs.astype(jnp.float32)[..., None]
    y_kernel, h_kernel = ops.ssm_scan(dA, dBx, C, chunk=8, interpret=True)
    y_ref, h_ref = R.ssm_scan_ref(dA, dBx, C)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)


def test_decode_kernel_in_model_decode_path():
    """attn_decode with attention_impl=pallas_interpret == xla path."""
    import dataclasses

    from repro.configs.base import get_smoke_config
    from repro.models import layers as L
    from repro.models.params import init_params

    cfg = get_smoke_config("granite-3-8b", d_model=64, n_heads=4, n_kv_heads=2,
                           head_dim=16)
    p = init_params(jax.random.PRNGKey(0), L.attention_defs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 1, 64), jnp.float32)
    ck = jax.random.normal(jax.random.PRNGKey(2), (3, 32, 2, 16), jnp.float32)
    cv = jax.random.normal(jax.random.PRNGKey(3), (3, 32, 2, 16), jnp.float32)
    pos = jnp.asarray([5, 17, 31], jnp.int32)
    out_xla, (k1, v1) = L.attn_decode(p, x, ck, cv, pos, cfg)
    cfg_pl = dataclasses.replace(cfg, attention_impl="pallas_interpret")
    out_pl, (k2, v2) = L.attn_decode(p, x, ck, cv, pos, cfg_pl)
    np.testing.assert_allclose(np.asarray(out_xla), np.asarray(out_pl),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), rtol=1e-6)


@pytest.mark.parametrize("b,s,di,n,chunk", [
    (2, 64, 32, 8, 16),
    (1, 50, 16, 4, 16),    # padding
    (2, 32, 64, 16, 32),
])
def test_ssm_scan_fused_matches_ref(b, s, di, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(hash((b, s, di, 7)) % 2**31), 5)
    delta = jax.nn.softplus(jax.random.normal(ks[0], (b, s, di)))
    B = jax.random.normal(ks[1], (b, s, n), jnp.float32)
    C = jax.random.normal(ks[2], (b, s, n), jnp.float32)
    x = jax.random.normal(ks[3], (b, s, di), jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[4], (di, n), jnp.float32))
    y_got, h_got = ops.ssm_scan_fused(delta, B, C, x, A, chunk=chunk,
                                      interpret=True)
    dA = jnp.exp(delta[..., None] * A)
    dBx = delta[..., None] * B[:, :, None, :] * x[..., None]
    y_want, h_want = R.ssm_scan_ref(dA, dBx, C)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                               rtol=2e-4, atol=2e-5)
