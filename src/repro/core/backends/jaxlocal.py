"""jaxlocal: the backend whose jobs are REAL distributed JAX training runs.

The paper treats remote jobs as opaque scripts; this backend closes the loop
by making the job a genuine ``repro`` training loop with framework
checkpointing, so bridge-level restart-resume (config-map job id) composes
with step-level checkpoint-resume (CheckpointManager) — the two-level fault
tolerance story of DESIGN.md §6.

Job script = JSON::

    {"arch": "gemma-2b", "steps": 200, "batch": 8, "seq": 64,
     "checkpoint_every": 20, "workdir": "ckpts:runs/demo", "lr": 3e-3,
     "task": "affine", "crash_at_step": 0}

``crash_at_step`` > 0 makes the job fail at that step (fault-injection for
tests): a resubmitted job with the same workdir resumes from the last
checkpoint rather than step 0.

The REST dialect is slurmrestd (this is "our SLURM": same API, real work),
so the generic controller drives it with the plain SlurmAdapter.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, Optional

import numpy as np

from repro.core.backends import base as B
from repro.core.backends.slurm import SlurmAdapter, make_server as make_slurm_server
from repro.core.objectstore import ObjectStore
from repro.core.rest import FaultProfile, RestServer


class JaxLocalAdapter(SlurmAdapter):
    image = "jaxpod"
    # same dialect as slurmrestd, so the same capability set (incl. arrays
    # and squeue-style BATCH_STATUS — the batch route comes with the server)
    capabilities = SlurmAdapter.capabilities


def train_job(spec: Dict[str, Any], store: ObjectStore,
              cancel: Optional[threading.Event] = None,
              log: Optional[list] = None) -> Dict[str, Any]:
    """Run (or resume) one training job.  Returns final metrics.

    Importable directly (examples/tests) or via the cluster payload below.
    """
    import jax
    import jax.numpy as jnp

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs.base import ShapeConfig, get_smoke_config
    from repro.data.pipeline import DataConfig, SyntheticDataset
    from repro.models.transformer import forward_train
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    from repro.steps import init_model

    arch = spec.get("arch", "gemma-2b")
    steps = int(spec.get("steps", 50))
    batch_sz = int(spec.get("batch", 4))
    seq = int(spec.get("seq", 32))
    ckpt_every = int(spec.get("checkpoint_every", 0))
    lr = float(spec.get("lr", 1e-3))
    crash_at = int(spec.get("crash_at_step", 0))
    overrides = dict(spec.get("config_overrides", {}))

    cfg = get_smoke_config(arch, **overrides)
    ds = SyntheticDataset(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                     global_batch=batch_sz,
                                     task=spec.get("task", "affine"),
                                     seed=int(spec.get("seed", 0))))
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=min(20, steps // 4 + 1),
                          total_steps=steps)

    _, params = init_model(cfg, seed=int(spec.get("seed", 0)), max_seq=seq)
    opt_state = adamw_init(params)

    mgr = None
    start_step = 0
    if ckpt_every and spec.get("workdir"):
        bucket, prefix = ObjectStore.parse_ref(spec["workdir"])
        mgr = CheckpointManager(store, bucket, prefix,
                                keep=int(spec.get("keep_checkpoints", 3)))
        resumed = mgr.restore_latest({"params": params, "opt": opt_state})
        if resumed is not None:
            start_step, tree, _extra = resumed
            params, opt_state = tree["params"], tree["opt"]

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            return forward_train(p, cfg, batch, remat=False)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_p, new_o, om = adamw_update(grads, opt_state, params, opt_cfg)
        return new_p, new_o, dict(metrics, **om)

    history = []
    for step in range(start_step, steps):
        if cancel is not None and cancel.is_set():
            if mgr:
                mgr.wait()
            return {"state": "cancelled", "step": step, "history": history}
        if crash_at and step == crash_at and step > start_step:
            # simulated node failure mid-run (AFTER making some progress)
            raise RuntimeError(f"injected crash at step {step}")
        batch = {k: jnp.asarray(v) for k, v in ds.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        history.append(loss)
        if log is not None:
            log.append((step, loss))
        if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.save_async(step + 1, {"params": params, "opt": opt_state},
                           extra={"loss": loss})
    if mgr:
        mgr.wait()
        mgr.save(steps, {"params": params, "opt": opt_state},
                 extra={"loss": history[-1] if history else None})
    return {"state": "done", "step": steps, "history": history,
            "final_loss": history[-1] if history else None,
            "start_step": start_step}


def serve_job(spec: Dict[str, Any], job: B.ClusterJob,
              cluster: B.SimulatedCluster) -> int:
    """Serve-mode replica: host a real ``ServingEngine`` behind the cluster's
    ``POST /.../invoke`` route until cancelled.

    The payload thread is the engine pump (continuous batching over the
    shared KV cache); REST worker threads call ``job.handler`` which enqueues
    a request and parks on a condition variable until the pump moves it to
    ``finished``.  A replica killed mid-request raises out of the handler
    (HTTP 500), which the service router treats as a replica fault and
    retries elsewhere — accepted requests are never silently dropped.
    Serve jobs NEVER auto-complete: only a cancel ends them.
    """
    from repro.configs.base import get_smoke_config
    from repro.serving.engine import ServingEngine
    from repro.steps import init_model

    arch = spec.get("arch", "gemma-2b")
    max_len = int(spec.get("max_len", 64))
    prefill_len = int(spec.get("prefill_len", 16))
    cfg = get_smoke_config(arch, **dict(spec.get("config_overrides", {})))
    _, params = init_model(cfg, seed=int(spec.get("seed", 0)),
                           max_seq=max_len)
    eng = ServingEngine(cfg, params,
                        max_batch=int(spec.get("max_batch", 4)),
                        max_len=max_len, prefill_len=prefill_len)
    cond = threading.Condition()
    results: Dict[int, Any] = {}

    def handler(body: Any) -> Dict[str, Any]:
        body = body or {}
        prompt = [int(t) for t in body.get("prompt", [])]
        with cond:
            if job._cancel.is_set():
                raise RuntimeError("replica shutting down")
            rid = eng.submit(prompt,
                             max_new_tokens=int(body.get("max_new_tokens", 8)),
                             eos_id=body.get("eos_id"))
            cond.notify_all()
            while rid not in results:
                if job._cancel.is_set():
                    raise RuntimeError("replica cancelled mid-request")
                cond.wait(timeout=0.05)
            req = results.pop(rid)
        return {"tokens": req.generated, "served_by": job.id, "arch": arch}

    job.handler = handler
    try:
        while not job._cancel.is_set():
            with cond:
                busy = (bool(eng.pending)
                        or any(s is not None for s in eng.slots))
                if not busy:
                    cond.wait(timeout=0.02)
                    continue
                eng.step()
                if eng.finished:
                    results.update(eng.finished)
                    eng.finished.clear()
                    cond.notify_all()
        return -1
    finally:
        job.handler = None
        with cond:
            cond.notify_all()  # release parked handlers to see the cancel


def jax_train_payload(store: ObjectStore) -> B.Payload:
    def run(job: B.ClusterJob, cluster: B.SimulatedCluster) -> int:
        spec = json.loads(job.script)
        if spec.get("mode") == "serve":
            return serve_job(spec, job, cluster)
        result = train_job(spec, store, cancel=job._cancel)
        job.outputs[job.properties.get("OutputFileName", "train.out")] = (
            json.dumps({k: v for k, v in result.items() if k != "history"})
            .encode())
        if result["state"] == "cancelled":
            return -1
        # publish the loss curve to S3 (output upload per paper §4)
        if spec.get("workdir"):
            bucket, prefix = ObjectStore.parse_ref(spec["workdir"])
            store.put(bucket, f"{prefix}/history_{job.id}.json",
                      json.dumps(result["history"]).encode())
        return 0

    return run


def make_jaxlocal_cluster(store: ObjectStore, name: str = "jaxlocal",
                          slots: int = 2,
                          start_numbering: int = 7000) -> B.SimulatedCluster:
    # start_numbering is per-cluster so a second jaxlocal resource (serving
    # across managers) hands out non-overlapping job ids
    return B.SimulatedCluster(name=name, slots=slots,
                              payload=jax_train_payload(store),
                              start_numbering=start_numbering)


def make_server(cluster: B.SimulatedCluster, token: str = "",
                fault: FaultProfile = None) -> RestServer:
    return make_slurm_server(cluster, token=token, fault=fault)
