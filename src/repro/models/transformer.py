"""Model assembly for all assigned architecture families.

Families:
  dense / vlm   — pre-norm attention + MLP blocks (vlm prepends stub image embeds)
  moe           — attention + MoE FFN (aux load-balance loss accumulated)
  hybrid        — hymba: parallel attention & mamba mixers, then MLP
  ssm           — xlstm: interleaved mLSTM / sLSTM blocks (unrolled)
  encdec        — whisper: bidirectional encoder (stub frame embeds) + causal
                  decoder with cross-attention

Three entry points per model:
  forward_train(params, cfg, batch)            -> (loss, metrics)
  prefill(params, cfg, batch, max_len)         -> (logits_last, cache)
  decode_step(params, cfg, cache, tokens)      -> (logits, cache)

Homogeneous stacks iterate with lax.scan over stacked per-layer params
(compile-time O(1) in depth); heterogeneous stacks (xlstm) unroll.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.params import ParamDef, is_paramdef

Params = Dict[str, Any]


def stack_defs(defs: Any, n: int) -> Any:
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale, d.dtype),
        defs,
        is_leaf=is_paramdef,
    )


# ---------------------------------------------------------------------------
# Per-family block definitions
# ---------------------------------------------------------------------------


def block_defs(cfg: ModelConfig) -> Params:
    d: Params = {"ln_attn": L.norm_defs(cfg), "attn": L.attention_defs(cfg)}
    if cfg.family in ("dense", "vlm"):
        d["ln_mlp"] = L.norm_defs(cfg)
        d["mlp"] = L.mlp_defs(cfg)
    elif cfg.family == "moe":
        d["ln_mlp"] = L.norm_defs(cfg)
        d["moe"] = MOE.moe_defs(cfg)
    elif cfg.family == "hybrid":
        d["ssm"] = SSM.ssm_defs(cfg)
        d["mix_w"] = ParamDef((2,), (None,), init="ones", dtype=jnp.float32)
        d["ln_mlp"] = L.norm_defs(cfg)
        d["mlp"] = L.mlp_defs(cfg)
    else:
        raise ValueError(cfg.family)
    return d


def enc_block_defs(cfg: ModelConfig) -> Params:
    return {
        "ln_attn": L.norm_defs(cfg),
        "attn": L.attention_defs(cfg),
        "ln_mlp": L.norm_defs(cfg),
        "mlp": L.mlp_defs(cfg),
    }


def dec_block_defs(cfg: ModelConfig) -> Params:
    return {
        "ln_attn": L.norm_defs(cfg),
        "attn": L.attention_defs(cfg),
        "ln_cross": L.norm_defs(cfg),
        "cross": L.cross_attention_defs(cfg),
        "ln_mlp": L.norm_defs(cfg),
        "mlp": L.mlp_defs(cfg),
    }


def xlstm_layer_kinds(cfg: ModelConfig):
    ev = cfg.xlstm.slstm_every
    return ["slstm" if (ev and (i + 1) % ev == 0) else "mlstm" for i in range(cfg.n_layers)]


def model_defs(cfg: ModelConfig, max_seq: int = 0) -> Params:
    """Full parameter tree.  ``max_seq`` sizes absolute position tables
    (rope models ignore it)."""
    defs: Params = {"embed": L.embed_defs(cfg), "ln_f": L.norm_defs(cfg)}
    if cfg.family == "ssm":
        blocks = []
        for kind in xlstm_layer_kinds(cfg):
            blocks.append(XL.mlstm_defs(cfg) if kind == "mlstm" else XL.slstm_defs(cfg))
        defs["blocks"] = blocks
    elif cfg.family == "encdec":
        if cfg.layer_impl == "scan":
            defs["enc_blocks"] = stack_defs(enc_block_defs(cfg), cfg.n_enc_layers)
            defs["blocks"] = stack_defs(dec_block_defs(cfg), cfg.n_layers)
        else:
            defs["enc_blocks"] = [enc_block_defs(cfg)
                                  for _ in range(cfg.n_enc_layers)]
            defs["blocks"] = [dec_block_defs(cfg) for _ in range(cfg.n_layers)]
        defs["enc_ln_f"] = L.norm_defs(cfg)
        defs["enc_pos"] = L.posembed_defs(cfg, cfg.enc_frames)
        defs["dec_pos"] = L.posembed_defs(cfg, max(max_seq, 8))
    else:
        if cfg.layer_impl == "scan":
            defs["blocks"] = stack_defs(block_defs(cfg), cfg.n_layers)
        else:
            defs["blocks"] = [block_defs(cfg) for _ in range(cfg.n_layers)]
    if cfg.family == "vlm":
        defs["img_proj"] = {
            "w": ParamDef((cfg.d_model, cfg.d_model), ("embed", "embed_out"), dtype=L.adtype(cfg))
        }
    return defs


# ---------------------------------------------------------------------------
# Block applications (train/prefill produce per-layer cache entries)
# ---------------------------------------------------------------------------


def _apply_block(p: Params, x, positions, cfg, window, want_kv: bool):
    """One decoder block.  Returns (x, (k, v, extra_state, aux))."""
    aux = jnp.zeros((), jnp.float32)
    xn = L.apply_norm(p["ln_attn"], x, cfg.norm)
    attn_out, (k, v) = L.attn_forward(p["attn"], xn, positions, cfg, window=window)
    extra = ()
    if cfg.family == "hybrid":
        ssm_out, ssm_state = SSM.ssm_forward(p["ssm"], xn, cfg)
        w = jax.nn.relu(p["mix_w"])  # learned non-negative mixing
        x = x + (w[0] * attn_out.astype(jnp.float32)
                 + w[1] * ssm_out.astype(jnp.float32)).astype(x.dtype)
        extra = (ssm_state["conv"], ssm_state["ssm"])
    else:
        x = x + attn_out
    xn2 = L.apply_norm(p["ln_mlp"], x, cfg.norm)
    if cfg.family == "moe":
        ffn_out, aux = MOE.apply_moe(p["moe"], xn2, cfg)
    else:
        ffn_out = L.apply_mlp(p["mlp"], xn2, cfg.activation)
    x = x + ffn_out
    if want_kv:
        return x, (k, v, extra, aux)
    return x, ((), (), extra if cfg.family == "hybrid" else (), aux)


def _run_stack(params, x, positions, cfg, window, want_kv, remat: bool):
    """Iterate decoder blocks; returns (x, stacked per-layer outs)."""
    body = functools.partial(_apply_block, positions=positions, cfg=cfg, window=window,
                             want_kv=want_kv)
    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    if cfg.layer_impl == "scan" and not isinstance(params, list):
        x, outs = jax.lax.scan(lambda c, lp: body(lp, c), x, params)
        return x, outs
    outs = []
    for lp in params:
        x, o = body(lp, x)
        outs.append(o)
    return x, outs


# ---------------------------------------------------------------------------
# Embedding frontends
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg, batch) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (x, positions, loss_mask_prefix) handling the vlm stub frontend."""
    tokens = batch["tokens"]
    x = L.embed_tokens(params["embed"], tokens, cfg)
    if cfg.family == "vlm":
        img = batch["img_embeds"].astype(x.dtype) @ params["img_proj"]["w"]
        x = jnp.concatenate([img, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions, tokens


# ---------------------------------------------------------------------------
# Training forward + loss
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, targets: jax.Array, mask: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def forward_train(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                  window: int = 0, remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    if cfg.family == "encdec":
        return _forward_train_encdec(params, cfg, batch, remat)
    x, positions, tokens = _embed_inputs(params, cfg, batch)
    if cfg.family == "ssm":
        x, aux_total = _run_xlstm(params, x, cfg)
    else:
        x, outs = _run_stack(params["blocks"], x, positions, cfg, window,
                             want_kv=False, remat=remat)
        auxs = outs[3] if not isinstance(outs, list) else jnp.stack([o[3] for o in outs])
        aux_total = jnp.sum(auxs)
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    if cfg.family == "vlm":  # strip image positions before unembedding
        x = x[:, -batch["tokens"].shape[1]:]
    logits = L.unembed(params["embed"], x, cfg)
    loss = cross_entropy(logits, batch["targets"], batch["mask"])
    total = loss + 0.01 * aux_total
    return total, {"loss": loss, "aux": aux_total}


def _run_xlstm(params, x, cfg):
    kinds = xlstm_layer_kinds(cfg)
    for kind, p in zip(kinds, params["blocks"]):
        if kind == "mlstm":
            out, _ = XL.mlstm_forward(p, x, cfg)
            x = x + out
        else:
            x, _ = XL.slstm_forward(p, x, cfg)  # residuals internal
    return x, jnp.zeros((), jnp.float32)


def _run_blocks(body, x, blocks):
    """Iterate a (scanned|unrolled) homogeneous stack, discarding per-layer
    outputs."""
    if isinstance(blocks, list):
        for lp in blocks:
            x, _ = body(lp, x)
        return x
    x, _ = jax.lax.scan(lambda c, lp: body(lp, c), x, blocks)
    return x


def _forward_train_encdec(params, cfg, batch, remat):
    frames = batch["enc_frames"].astype(L.adtype(cfg))
    enc = frames + params["enc_pos"]["pos"][None, : frames.shape[1]]
    b = enc.shape[0]
    enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1], dtype=jnp.int32), (b, enc.shape[1]))

    def enc_block(p, x):
        xn = L.apply_norm(p["ln_attn"], x, cfg.norm)
        a, _ = L.attn_forward(p["attn"], xn, enc_pos, cfg, causal=False)
        x = x + a
        xn = L.apply_norm(p["ln_mlp"], x, cfg.norm)
        return x + L.apply_mlp(p["mlp"], xn, cfg.activation), ()

    enc = _run_blocks(enc_block, enc, params["enc_blocks"])
    enc = L.apply_norm(params["enc_ln_f"], enc, cfg.norm)

    tokens = batch["tokens"]
    x = L.embed_tokens(params["embed"], tokens, cfg)
    s = x.shape[1]
    x = x + params["dec_pos"]["pos"][None, :s]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def dec_block(p, x):
        xn = L.apply_norm(p["ln_attn"], x, cfg.norm)
        a, _ = L.attn_forward(p["attn"], xn, positions, cfg)
        x = x + a
        xn = L.apply_norm(p["ln_cross"], x, cfg.norm)
        c, _ = L.attn_forward(p["cross"], xn, positions, cfg, kv_override=(enc, enc))
        x = x + c
        xn = L.apply_norm(p["ln_mlp"], x, cfg.norm)
        return x + L.apply_mlp(p["mlp"], xn, cfg.activation), ()

    body = dec_block
    if remat:
        body = jax.checkpoint(
            dec_block, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x = _run_blocks(body, x, params["blocks"])
    x = L.apply_norm(params["ln_f"], x, cfg.norm)
    logits = L.unembed(params["embed"], x, cfg)
    loss = cross_entropy(logits, batch["targets"], batch["mask"])
    return loss, {"loss": loss, "aux": jnp.zeros((), jnp.float32)}
