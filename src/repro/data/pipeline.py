"""Deterministic synthetic token pipeline, sharded by host.

Determinism contract: batch contents are a pure function of
(seed, step, shard, n_shards).  A restarted job therefore re-reads EXACTLY
the sequence of batches it would have seen — which is what makes the
bridge-level restart-resume and the checkpoint-level resume composable and
testable (loss curves continue identically after a kill).

Task ``affine``: t[i+1] = (a * t[i] + c) mod vocab with fixed co-prime
``a`` — a bijection a model learns quickly, so example drivers show real
loss decrease.  Task ``uniform``: i.i.d. tokens (for throughput benches).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    task: str = "affine"   # affine | uniform
    seed: int = 0

    def __post_init__(self):
        if self.global_batch <= 0 or self.seq_len <= 0:
            raise ValueError("batch/seq must be positive")


def _affine_coeffs(vocab: int, seed: int):
    # pick a multiplier co-prime with vocab (odd works for even vocab; search)
    rng = np.random.RandomState(seed ^ 0x5EED)
    while True:
        a = int(rng.randint(1, max(vocab, 2)))
        if np.gcd(a, vocab) == 1:
            return a, int(rng.randint(0, vocab))


class SyntheticDataset:
    """Stateless batch source: ``batch(step, shard, n_shards)``."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._a, self._c = _affine_coeffs(cfg.vocab, cfg.seed)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        if cfg.global_batch % n_shards != 0:
            raise ValueError(f"global_batch {cfg.global_batch} % {n_shards} != 0")
        b = cfg.global_batch // n_shards
        # Stateless per-(step, shard) stream: independent of how many other
        # shards exist or ran before — elastic-rescale safe as long as
        # (step, global position) pairs are preserved.
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step * 9_973 + shard * 7 + 1) % (2**31 - 1))
        if cfg.task == "uniform":
            toks = rng.randint(0, cfg.vocab, size=(b, cfg.seq_len + 1)).astype(np.int32)
        elif cfg.task == "affine":
            start = rng.randint(0, cfg.vocab, size=(b, 1)).astype(np.int64)
            seqs = [start]
            for _ in range(cfg.seq_len):
                seqs.append((self._a * seqs[-1] + self._c) % cfg.vocab)
            toks = np.concatenate(seqs, axis=1).astype(np.int32)
        else:
            raise ValueError(cfg.task)
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": np.ones((b, cfg.seq_len), np.float32),
        }

    def batches(self, start_step: int = 0, shard: int = 0, n_shards: int = 1
                ) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, shard, n_shards)
            step += 1


def dataset_for(cfg: ModelConfig, shape: ShapeConfig, task: str = "affine",
                seed: int = 0) -> SyntheticDataset:
    return SyntheticDataset(DataConfig(vocab=cfg.vocab, seq_len=shape.seq_len,
                                       global_batch=shape.global_batch,
                                       task=task, seed=seed))


def with_frontend_stubs(batch: Dict[str, np.ndarray], cfg: ModelConfig,
                        seed: int = 0) -> Dict[str, np.ndarray]:
    """Attach [vlm]/[audio] stub embeddings (precomputed patch/frame embeds)."""
    rng = np.random.RandomState(seed + 17)
    b = batch["tokens"].shape[0]
    if cfg.family == "vlm" and cfg.n_img_tokens:
        batch = dict(batch, img_embeds=rng.randn(
            b, cfg.n_img_tokens, cfg.d_model).astype(np.float32) * 0.02)
    if cfg.family == "encdec":
        batch = dict(batch, enc_frames=rng.randn(
            b, cfg.enc_frames, cfg.d_model).astype(np.float32) * 0.02)
    return batch
