"""The v1beta1 API redesign: versioned CRD + conversion, typed adapter
capabilities, job arrays, retry/TTL/dependencies, and the ``Bridge`` facade.
"""
import json
import time

import pytest

from repro.core import (API_V1ALPHA1, API_V1BETA1, ArraySpec, Bridge,
                        BridgeEnvironment, BridgeJob, Capability,
                        ConversionError, DONE, FAILED, KILLED, PENDING,
                        RetryPolicy, ValidationError, convert,
                        resolve_adapter)


@pytest.fixture(scope="module")
def env():
    with BridgeEnvironment(default_duration=0.05) as e:
        yield e


@pytest.fixture()
def fresh_env():
    with BridgeEnvironment(default_duration=0.05) as e:
        yield e


# ---------------------------------------------------------------------------
# conversion layer
# ---------------------------------------------------------------------------


def _alpha_docs(env):
    """v1alpha1 documents covering every spec shape the seed tests/examples
    use: plain, s3 script, staging + upload, params, kill, unknown_after."""
    specs = [
        env.make_spec("slurm", script="run"),
        env.make_spec("slurm", script="b:k.sh", scriptlocation="s3"),
        env.make_spec("lsf", script="analyse", additionaldata="inputs:d.csv",
                      jobproperties={"OutputFileName": "o.txt"},
                      uploadfiles="o.txt", uploadbucket="outputs"),
        env.make_spec("quantum", script="OPENQASM 3;",
                      jobproperties={"shots": "2048"}),
        env.make_spec("ray", script="python t.py",
                      jobparams={"k": "v"}, unknown_after=7),
        env.make_spec("jaxlocal", script="{}", kill=True),
    ]
    return [BridgeJob(name=f"cr-{i}", spec=s).to_dict(API_V1ALPHA1)
            for i, s in enumerate(specs)]


def test_v1alpha1_roundtrip_bit_for_bit(env):
    for doc in _alpha_docs(env):
        up = convert(doc, API_V1BETA1)
        assert up["apiVersion"] == API_V1BETA1
        down = convert(up, API_V1ALPHA1)
        assert json.dumps(down, sort_keys=True) == json.dumps(doc, sort_keys=True)
        # both versions parse to the same internal object
        assert BridgeJob.from_dict(up).spec == BridgeJob.from_dict(doc).spec


def test_lossy_downgrade_rejected(env):
    spec = env.make_spec("slurm", script="x",
                         array=ArraySpec(count=3), retry=RetryPolicy(limit=2))
    doc = BridgeJob(name="arr", spec=spec).to_dict()
    assert doc["apiVersion"] == API_V1BETA1
    with pytest.raises(ConversionError, match="cannot downgrade"):
        convert(doc, API_V1ALPHA1)
    with pytest.raises(ConversionError):
        BridgeJob(name="arr", spec=spec).to_dict(API_V1ALPHA1)


def test_alpha_doc_with_beta_fields_rejected(env):
    doc = BridgeJob(name="j", spec=env.make_spec("slurm", script="x")).to_dict()
    doc["spec"]["array"] = {"count": 4}
    with pytest.raises(ValidationError, match="v1beta1-only"):
        BridgeJob.from_dict(doc)


def test_array_spec_validation(env):
    with pytest.raises(ValidationError, match="count"):
        env.make_spec("slurm", script="x", array=ArraySpec(count=0)).validate()
    with pytest.raises(ValidationError, match="indexed_params"):
        env.make_spec("slurm", script="x", array=ArraySpec(
            count=3, indexed_params=[{}])).validate()


# ---------------------------------------------------------------------------
# typed capabilities
# ---------------------------------------------------------------------------


def test_capability_matrix(env):
    caps = {kind: env.bridge.capabilities(image)
            for kind, image in (("slurm", "slurmpod:0.1"),
                                ("lsf", "lsfpod:0.1"),
                                ("quantum", "quantumpod:0.1"),
                                ("ray", "raypod:0.1"),
                                ("jaxlocal", "jaxpod:0.1"))}
    # slurmrestd 21.08: arrays yes, file staging no (paper §5.2)
    assert Capability.NATIVE_ARRAYS in caps["slurm"]
    assert Capability.UPLOAD not in caps["slurm"]
    # LSF Application Center: staging yes, and bsub -J "name[1-N]" arrays
    assert {Capability.UPLOAD, Capability.DOWNLOAD} <= caps["lsf"]
    assert Capability.NATIVE_ARRAYS in caps["lsf"]
    # ray: logs, not arbitrary files
    assert Capability.LOGS in caps["ray"]
    assert Capability.DOWNLOAD not in caps["ray"]
    # quantum results land in object storage, no file verbs at all
    assert not {Capability.UPLOAD, Capability.DOWNLOAD} & caps["quantum"]
    # jaxlocal speaks the slurm dialect
    assert caps["jaxlocal"] == caps["slurm"]
    for c in caps.values():
        assert {Capability.CANCEL, Capability.CANCEL_QUEUED,
                Capability.QUEUE_LOAD} <= c


def test_adapter_lookup_uniform_error(env):
    with pytest.raises(KeyError, match="no controller implementation"):
        resolve_adapter(env.adapters, "nosuchpod:9.9")
    with pytest.raises(KeyError, match="no controller implementation"):
        env.bridge.capabilities("nosuchpod:9.9")


# ---------------------------------------------------------------------------
# job arrays: one CR -> N remote jobs, on two different backends
# ---------------------------------------------------------------------------


def test_job_array_native_slurm(env):
    """slurm declares NATIVE_ARRAYS: ONE submission call fans out 4 tasks."""
    spec = env.make_spec(
        "slurm", script="member", updateinterval=0.02,
        array=ArraySpec(count=4,
                        indexed_params=[{"IDX": str(i)} for i in range(4)]))
    handle = env.bridge.submit("arr-slurm", spec)
    job = handle.wait(timeout=30)
    assert job.status.state == DONE
    assert job.status.index_states == {str(i): DONE for i in range(4)}
    ids = job.status.job_id.split(",")
    assert len(ids) == 4
    members = [env.clusters["slurm"].jobs[i] for i in ids]
    assert sorted(m.params["IDX"] for m in members) == ["0", "1", "2", "3"]
    # the slurm dialect stamped its native array marker on every task
    assert all("SLURM_ARRAY_TASK_ID" in m.params for m in members)


def test_job_array_native_lsf(env):
    """lsf now declares NATIVE_ARRAYS: ONE bsub -J "bridge[1-N]"-style call
    fans out 4 elements, each stamped with its 1-based LSB_JOBINDEX."""
    spec = env.make_spec(
        "lsf", script="member", updateinterval=0.02,
        array=ArraySpec(count=4,
                        indexed_params=[{"IDX": str(i)} for i in range(4)]))
    handle = env.bridge.submit("arr-lsf", spec)
    job = handle.wait(timeout=30)
    assert job.status.state == DONE
    assert job.status.index_states == {str(i): DONE for i in range(4)}
    ids = job.status.job_id.split(",")
    assert len(ids) == 4
    members = [env.clusters["lsf"].jobs[i] for i in ids]
    assert sorted(m.params["IDX"] for m in members) == ["0", "1", "2", "3"]
    assert sorted(m.params["LSB_JOBINDEX"] for m in members) == [
        "1", "2", "3", "4"]


def test_job_array_facade_fanout_lsf_dialect(env):
    """An adapter withholding NATIVE_ARRAYS (the pre-Application-Center
    fan-out shape) still works: the controller fans out via N submits and
    injects the bridge's own index marker."""
    from repro.core.backends import base as B
    from repro.core.backends.lsf import LSFAdapter

    class NoNativeArrays(LSFAdapter):
        capabilities = LSFAdapter.capabilities - {B.Capability.NATIVE_ARRAYS}

    env.operator.adapters[NoNativeArrays.image] = NoNativeArrays
    env.bridge.adapters[NoNativeArrays.image] = NoNativeArrays
    spec = env.make_spec(
        "lsf", script="member", updateinterval=0.02,
        array=ArraySpec(count=4,
                        indexed_params=[{"IDX": str(i)} for i in range(4)]))
    handle = env.bridge.submit("arr-lsf-fan", spec)
    job = handle.wait(timeout=30)
    assert job.status.state == DONE
    assert job.status.index_states == {str(i): DONE for i in range(4)}
    ids = job.status.job_id.split(",")
    assert len(ids) == 4
    members = [env.clusters["lsf"].jobs[i] for i in ids]
    assert sorted(m.params["IDX"] for m in members) == ["0", "1", "2", "3"]
    # facade-side fan-out injects the bridge's own index marker
    assert all("BRIDGE_ARRAY_INDEX" in m.params for m in members)


def test_array_failed_index_fails_aggregate(env):
    """DONE only when ALL indices complete; one failure -> FAILED, and the
    per-index map shows exactly which index died."""
    spec = env.make_spec(
        "slurm", script="member", updateinterval=0.02,
        array=ArraySpec(count=3,
                        indexed_params=[{}, {"FailMe": "true"}, {}]))
    job = env.bridge.submit("arr-fail", spec).wait(timeout=30)
    assert job.status.state == FAILED
    assert job.status.index_states["1"] == FAILED
    assert job.status.index_states["0"] == DONE
    assert job.status.index_states["2"] == DONE
    assert "[1]" in job.status.message


def test_array_kill_cancels_every_index(env):
    spec = env.make_spec(
        "lsf", script="sleepy", updateinterval=0.02,
        jobproperties={"WallSeconds": "10"}, array=ArraySpec(count=2))
    handle = env.bridge.submit("arr-kill", spec)
    deadline = time.time() + 10
    while time.time() < deadline:
        if len(handle.status().job_id.split(",")) == 2:
            break
        time.sleep(0.01)
    handle.cancel()
    job = handle.wait(timeout=30)
    assert job.status.state == KILLED
    assert set(job.status.index_states.values()) == {KILLED}


# ---------------------------------------------------------------------------
# retry / dependencies / TTL policies
# ---------------------------------------------------------------------------


def test_retry_resubmits_failed_index(env):
    """A persistently failing job is resubmitted ``limit`` times, then the
    FAILED state propagates; every attempt is a distinct remote job."""
    spec = env.make_spec("slurm", script="will-fail", updateinterval=0.02,
                         jobparams={"FailMe": "true"},
                         retry=RetryPolicy(limit=1))
    job = env.bridge.submit("retryjob", spec).wait(timeout=30)
    assert job.status.state == FAILED
    cm = env.statestore.get("default/retryjob-bridge-cm")
    assert json.loads(cm.get("retry_attempts")) == {"0": 1}
    attempts = [j for j in env.clusters["slurm"].jobs.values()
                if j.script == "will-fail"]
    assert len(attempts) == 2  # original + one resubmission


def test_retry_recovers_from_transient_submit_failure(env):
    """Submission retry: the script appears in S3 between attempts."""
    spec = env.make_spec("slurm", script="late:script.sh", scriptlocation="s3",
                         updateinterval=0.02,
                         retry=RetryPolicy(limit=20, backoff_seconds=0.05))
    handle = env.bridge.submit("latescript", spec)
    time.sleep(0.15)
    env.s3.put("late", "script.sh", b"#!/bin/bash\ntrue\n")
    job = handle.wait(timeout=30)
    assert job.status.state == DONE


def test_count1_array_params_not_dropped(env):
    """A degenerate count=1 array with indexed_params is still a beta spec:
    serialized as v1beta1 and its overlay params reach the remote job."""
    spec = env.make_spec("slurm", script="one", updateinterval=0.02,
                         array=ArraySpec(count=1, indexed_params=[{"K": "V"}]))
    assert BridgeJob(name="a1", spec=spec).to_dict()["apiVersion"] == API_V1BETA1
    job = env.bridge.submit("arr-one", spec).wait(timeout=30)
    assert job.status.state == DONE
    assert env.clusters["slurm"].jobs[job.status.job_id].params["K"] == "V"


def test_kill_cancels_remaining_retry_budget(env):
    """A killed CR must reach a terminal state even with retry budget left —
    the kill supersedes resubmission."""
    spec = env.make_spec("slurm", script="fail-forever", updateinterval=0.02,
                         jobparams={"FailMe": "true"},
                         retry=RetryPolicy(limit=10_000))
    handle = env.bridge.submit("retry-kill", spec)
    deadline = time.time() + 10
    while time.time() < deadline and not handle.status().job_id:
        time.sleep(0.01)
    handle.cancel()
    job = handle.wait(timeout=30)  # would TimeoutError if retries kept going
    assert job.status.state in (FAILED, KILLED)


def test_kill_during_submit_retry(env):
    """Cancelling a CR stuck in submission retries stops it from ever
    submitting once the blocker clears."""
    spec = env.make_spec("slurm", script="never:appears.sh",
                         scriptlocation="s3", updateinterval=0.02,
                         retry=RetryPolicy(limit=10_000,
                                           backoff_seconds=0.05))
    handle = env.bridge.submit("submit-kill", spec)
    time.sleep(0.15)
    handle.cancel()
    job = handle.wait(timeout=30)
    assert job.status.state == KILLED
    assert job.status.job_id == ""
    assert not any(j.script == "never:appears.sh"
                   for j in env.clusters["slurm"].jobs.values())


def test_dependencies_gate_submission(env):
    first = env.make_spec("slurm", script="first", updateinterval=0.02,
                          jobproperties={"WallSeconds": "0.4"})
    second = env.make_spec("lsf", script="second", updateinterval=0.02,
                           dependencies=["dep-first"])
    h2 = env.bridge.submit("dep-second", second)
    time.sleep(0.2)  # no dependency exists yet -> must be held back
    assert h2.status().state == PENDING
    assert "waiting for dependency" in h2.status().message
    assert h2.status().job_id == ""
    env.bridge.submit("dep-first", first)
    job2 = h2.wait(timeout=30)
    job1 = env.bridge.handle("dep-first").job()
    assert job1.status.state == DONE and job2.status.state == DONE
    # the dependent was only ever submitted AFTER the dependency finished
    dep_end = job1.status.end_time
    started = min(j.submit_time for j in env.clusters["lsf"].jobs.values()
                  if j.script == "second")
    assert started >= dep_end


def test_failed_dependency_fails_dependent(env):
    bad = env.make_spec("slurm", script="doomed", updateinterval=0.02,
                        jobproperties={"FailMe": "true"})
    child = env.make_spec("slurm", script="never-runs", updateinterval=0.02,
                          dependencies=["dep-bad"])
    env.bridge.submit("dep-bad", bad)
    h = env.bridge.submit("dep-child", child)
    job = h.wait(timeout=30)
    assert job.status.state == FAILED
    assert "dependency 'dep-bad' ended FAILED" in job.status.message
    assert job.status.job_id == ""  # never submitted remotely
    assert not any(j.script == "never-runs"
                   for j in env.clusters["slurm"].jobs.values())


def test_cancel_reaches_dependency_gated_job(env):
    """A job held PENDING on an absent dependency must still be killable."""
    spec = env.make_spec("slurm", script="held", updateinterval=0.02,
                         dependencies=["never-created"])
    handle = env.bridge.submit("gated-kill", spec)
    deadline = time.time() + 10
    while time.time() < deadline:
        if "waiting for dependency" in handle.status().message:
            break
        time.sleep(0.01)
    handle.cancel()
    job = handle.wait(timeout=30)
    assert job.status.state == KILLED
    assert job.status.job_id == ""  # never submitted remotely


def test_native_array_retry_keeps_index_marker(env):
    """A retried index of a slurm native array carries the same
    SLURM_ARRAY_TASK_ID as its original run."""
    spec = env.make_spec(
        "slurm", script="marker", updateinterval=0.02,
        array=ArraySpec(count=3,
                        indexed_params=[{}, {"FailMe": "true"}, {}]),
        retry=RetryPolicy(limit=1))
    job = env.bridge.submit("arr-remark", spec).wait(timeout=30)
    assert job.status.state == FAILED  # index 1 fails both attempts
    attempts = [j for j in env.clusters["slurm"].jobs.values()
                if j.script == "marker"]
    assert len(attempts) == 4  # 3 original + 1 retry of index 1
    assert all(j.params.get("SLURM_ARRAY_TASK_ID") for j in attempts)
    assert sum(1 for j in attempts
               if j.params["SLURM_ARRAY_TASK_ID"] == "1") == 2


def test_partial_fanout_abort_cancels_submitted_indices(env):
    """If fan-out fails permanently mid-array, already-submitted indices are
    cancelled instead of running orphaned."""
    from repro.core.backends import base as B
    from repro.core.controller import ControllerPod
    from repro.core import URLS

    submitted, cancelled = [], []

    class FlakyAdapter(B.ResourceAdapter):
        image = "flakypod"
        capabilities = frozenset({B.Capability.CANCEL,
                                  B.Capability.CANCEL_QUEUED})

        def submit(self, script, properties, params):
            if len(submitted) == 1:  # second index hits a quota error
                raise B.SubmitError("quota exceeded")
            jid = f"fk-{len(submitted)}"
            submitted.append(jid)
            return jid

        def cancel(self, job_id):
            cancelled.append(job_id)

    cm = env.statestore.create("default/flaky-cm", {
        "resourceURL": URLS["slurm"], "image": "flakypod:0.1",
        "resourcesecret": "slurm-secret", "updateinterval": "0.01",
        "jobscript": "x", "scriptlocation": "inline", "additionaldata": "",
        "jobproperties": "{}", "jobparams": "{}", "unknown_after": "5",
        "id": "", "jobStatus": "PENDING", "kill": "false", "message": "",
        "array_count": "3", "indexed_params": "[]",
    })
    pod = ControllerPod(name="default/flaky-pod", configmap=cm,
                        secrets=env.secrets, objectstore=env.s3,
                        directory=env.directory,
                        adapters={"flakypod": FlakyAdapter}, min_sleep=0.002)
    pod.start()
    pod.join(timeout=10)
    assert pod.exit_code == 1
    assert cm.get("jobStatus") == FAILED
    assert cancelled == ["fk-0"], "the fanned-out index must be cancelled"
    env.statestore.delete("default/flaky-cm")


def test_ttl_garbage_collects_cr(fresh_env):
    env = fresh_env
    spec = env.make_spec("slurm", script="x", updateinterval=0.02,
                         ttl_seconds_after_finished=0.3)
    handle = env.bridge.submit("ttljob", spec)
    job = handle.wait(timeout=30)
    assert job.status.state == DONE
    deadline = time.time() + 10
    while time.time() < deadline and handle.job() is not None:
        time.sleep(0.02)
    assert handle.job() is None, "TTL should auto-delete the CR"
    assert not env.statestore.exists("default/ttljob-bridge-cm")


# ---------------------------------------------------------------------------
# the Bridge facade: kill-while-QUEUED, pod-restart-resume, watch, outputs
# ---------------------------------------------------------------------------


def test_kill_while_queued_via_bridge(fresh_env):
    """Cancel a job that never left the remote queue (CANCEL_QUEUED path)."""
    env = fresh_env
    # saturate every slurm slot so the bridged job stays QUEUED
    for _ in range(env.clusters["slurm"].slots):
        env.clusters["slurm"].submit("hog", {"WallSeconds": "10"}, {})
    handle = env.bridge.submit("queued-kill", env.make_spec(
        "slurm", script="starved", updateinterval=0.02,
        jobproperties={"WallSeconds": "5"}))
    deadline = time.time() + 10
    while time.time() < deadline and not handle.status().job_id:
        time.sleep(0.01)
    remote = env.clusters["slurm"].jobs[handle.status().job_id]
    assert remote.state == "QUEUED"
    handle.cancel()
    job = handle.wait(timeout=30)
    assert job.status.state == KILLED
    assert remote.start_time is None, "job must have been killed in-queue"


def test_pod_restart_resume_via_bridge(fresh_env):
    """Operator restarts a killed pod; the new pod resumes from the config
    map and never resubmits — observed purely through the facade."""
    env = fresh_env
    handle = env.bridge.submit("resume", env.make_spec(
        "slurm", script="long", updateinterval=0.02,
        jobproperties={"WallSeconds": "1.0"}))
    deadline = time.time() + 10
    while time.time() < deadline and not handle.status().job_id:
        time.sleep(0.005)
    first_id = handle.status().job_id
    assert first_id
    env.operator.pods["default/resume"].kill_pod()
    job = handle.wait(timeout=30)
    assert job.status.state == DONE
    assert job.status.restarts >= 1
    assert job.status.job_id == first_id, "restarted pod must NOT resubmit"
    assert len(env.clusters["slurm"].jobs) == 1


def test_watch_streams_status_changes(env):
    handle = env.bridge.submit("watchme", env.make_spec(
        "slurm", script="w", updateinterval=0.02,
        jobproperties={"WallSeconds": "0.3"}))
    states = [s.state for s in handle.watch(timeout=30)]
    assert states[-1] == DONE
    assert states[0] != DONE  # saw it in flight
    assert states == sorted(set(states), key=states.index)  # no duplicates


def test_outputs_via_bridge(env):
    handle = env.bridge.submit("outjob", env.make_spec(
        "lsf", script="produce", updateinterval=0.02,
        jobproperties={"OutputFileName": "res.out"},
        uploadfiles="res.out", uploadbucket="outbkt"))
    assert handle.wait(timeout=30).status.state == DONE
    outs = handle.outputs()
    assert len(outs) == 1
    (key, data), = outs.items()
    assert key.endswith("res.out") and b"ok" in data


def test_bridge_submit_accepts_versioned_documents(env):
    """The facade takes a raw CR document in either API version."""
    doc = {
        "apiVersion": API_V1BETA1, "kind": "BridgeJob",
        "spec": {
            "resourceURL": "https://slurm.hpc.example.com",
            "image": "slurmpod:0.1", "resourcesecret": "slurm-secret",
            "updateinterval": 0.02,
            "jobdata": {"jobscript": "from-doc", "scriptlocation": "inline"},
            "array": {"count": 2},
        },
    }
    job = env.bridge.submit("from-doc", doc).wait(timeout=30)
    assert job.status.state == DONE
    assert len(job.status.job_id.split(",")) == 2


# ---------------------------------------------------------------------------
# satellite: SimulatedCluster thread reaping
# ---------------------------------------------------------------------------


def test_cluster_reaps_finished_worker_threads():
    from repro.core.backends.base import SimulatedCluster, TERMINAL

    cluster = SimulatedCluster("reap", slots=4, default_duration=0.01)
    try:
        jobs = [cluster.submit("t", {}, {}) for _ in range(12)]
        deadline = time.time() + 10
        while time.time() < deadline:
            if (all(j.state in TERMINAL for j in jobs)
                    and len(cluster._threads) == 0):
                break
            time.sleep(0.01)
        assert all(j.state in TERMINAL for j in jobs)
        assert len(cluster._threads) == 0, "terminal threads must be reaped"
    finally:
        cluster.shutdown()
