"""S3-compatible object store analogue (local-dir backend).

Used exactly as the paper uses S3 (§4): job scripts fetched from
``bucket:key``, additional input data staged to the external resource, and
output files uploaded on completion.  The API mirrors the minimal S3 surface
the bridge needs: put/get/list/delete + bucket namespace.
"""
from __future__ import annotations

import os
import tempfile
import threading
from typing import Dict, Iterator, List, Optional, Tuple


class NoSuchKey(KeyError):
    pass


class ObjectStore:
    def __init__(self, root: Optional[str] = None, endpoint: str = "s3.local"):
        self.endpoint = endpoint
        self._root = root
        self._mem: Dict[Tuple[str, str], bytes] = {}
        self._lock = threading.RLock()
        if root:
            os.makedirs(root, exist_ok=True)

    # -- S3 surface -------------------------------------------------------

    def put(self, bucket: str, key: str, data: bytes) -> None:
        if isinstance(data, str):
            data = data.encode()
        with self._lock:
            if self._root:
                path = self._path(bucket, key)
                os.makedirs(os.path.dirname(path), exist_ok=True)
                fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path))
                try:
                    with os.fdopen(fd, "wb") as f:
                        f.write(data)
                    os.replace(tmp, path)
                finally:
                    if os.path.exists(tmp):
                        os.remove(tmp)
            self._mem[(bucket, key)] = bytes(data)

    def get(self, bucket: str, key: str) -> bytes:
        with self._lock:
            if self._root:
                try:
                    with open(self._path(bucket, key), "rb") as f:
                        return f.read()
                except FileNotFoundError:
                    raise NoSuchKey(f"s3://{bucket}/{key}")
            try:
                return self._mem[(bucket, key)]
            except KeyError:
                raise NoSuchKey(f"s3://{bucket}/{key}")

    def get_text(self, bucket: str, key: str) -> str:
        return self.get(bucket, key).decode()

    def exists(self, bucket: str, key: str) -> bool:
        try:
            self.get(bucket, key)
            return True
        except NoSuchKey:
            return False

    def delete(self, bucket: str, key: str) -> None:
        with self._lock:
            if self._root:
                try:
                    os.remove(self._path(bucket, key))
                except FileNotFoundError:
                    pass
            self._mem.pop((bucket, key), None)

    def list(self, bucket: str, prefix: str = "") -> List[str]:
        with self._lock:
            if self._root:
                broot = os.path.join(self._root, self._safe(bucket))
                out = []
                for dirpath, _, files in os.walk(broot):
                    for f in files:
                        rel = os.path.relpath(os.path.join(dirpath, f), broot)
                        key = rel.replace(os.sep, "/")
                        if key.startswith(prefix):
                            out.append(key)
                return sorted(out)
            return sorted(k for (b, k) in self._mem if b == bucket and k.startswith(prefix))

    # -- helpers ------------------------------------------------------------

    @staticmethod
    def parse_ref(ref: str) -> Tuple[str, str]:
        """'bucket:key' -> (bucket, key), as in the paper's Fig. 1 yaml."""
        if ":" not in ref:
            raise ValueError(f"object ref {ref!r} is not 'bucket:key'")
        bucket, key = ref.split(":", 1)
        return bucket, key

    def _safe(self, s: str) -> str:
        return s.replace("/", "__")

    def _path(self, bucket: str, key: str) -> str:
        return os.path.join(self._root, self._safe(bucket), *key.split("/"))
