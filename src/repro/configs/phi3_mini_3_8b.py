"""phi3-mini-3.8b [dense]: RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    source="arXiv:2404.14219",
)

SMOKE = ModelConfig(
    name="phi3-mini-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    activation="swiglu",
    dtype="float32",
)
