"""BridgeEnvironment — a cluster-in-a-box wiring of every component.

One call builds: resource registry + state store + secrets + object store +
the four simulated external resource managers (SLURM, LSF, Quantum, Ray) +
the real ``jaxlocal`` trainer backend + the operator.  Tests, examples and
benchmarks all start here, so the wiring itself is exercised everywhere.
"""
from __future__ import annotations

import dataclasses
import tempfile
from typing import Dict, Optional

from repro.core.api import Bridge
from repro.core.backends import base as B
from repro.core.backends import jaxlocal as JX
from repro.core.backends import lsf as LSFB
from repro.core.backends import quantum as QB
from repro.core.backends import ray as RAYB
from repro.core.backends import slurm as SLB
from repro.core.objectstore import ObjectStore
from repro.core.operator import BridgeOperator, default_adapters
from repro.core.registry import ResourceRegistry
from repro.core.resource import (ArraySpec, AutoscaleSpec, BridgeJob,
                                 BridgeJobSpec,
                                 BridgeServiceSpec, HealthProbeSpec, JobData,
                                 PlacementSpec, RetryPolicy, S3Storage)
from repro.core.rest import FaultProfile, ResourceManagerDirectory
from repro.core.secrets import SecretStore
from repro.core.statestore import StateStore

URLS = {
    "slurm": "https://slurm.hpc.example.com",
    "lsf": "https://lsf.hpc.example.com",
    "quantum": "https://quantum.cloud.example.com",
    "ray": "https://ray.cluster.example.com",
    "jaxlocal": "https://jax.pod0.example.com",
}
IMAGES = {
    "slurm": "slurmpod:0.1",
    "lsf": "lsfpod:0.1",
    "quantum": "quantumpod:0.1",
    "ray": "raypod:0.1",
    "jaxlocal": "jaxpod:0.1",
}
TOKENS = {k: f"{k}-token-0123" for k in URLS}


class BridgeEnvironment:
    def __init__(self, root: Optional[str] = None, *, durable: bool = False,
                 slots: int = 4, default_duration: float = 0.05,
                 fault_profiles: Optional[Dict[str, FaultProfile]] = None,
                 operator_kwargs: Optional[dict] = None):
        if durable and root is None:
            root = tempfile.mkdtemp(prefix="bridge-env-")
        self.root = root
        self.registry = ResourceRegistry()
        self.statestore = StateStore(root=f"{root}/configmaps" if durable else None)
        self.secrets = SecretStore()
        self.s3 = ObjectStore(root=f"{root}/s3" if durable else None,
                              endpoint="s3.local")
        self.directory = ResourceManagerDirectory()
        self.adapters = default_adapters()
        self.fault_profiles = dict(fault_profiles or {})

        self.clusters: Dict[str, B.SimulatedCluster] = {
            "slurm": B.SimulatedCluster("slurm", slots=slots,
                                        default_duration=default_duration,
                                        start_numbering=1000),
            "lsf": B.SimulatedCluster("lsf", slots=slots,
                                      default_duration=default_duration,
                                      start_numbering=2000),
            "quantum": B.SimulatedCluster("quantum", slots=slots,
                                          default_duration=default_duration,
                                          start_numbering=3000),
            "ray": B.SimulatedCluster("ray", slots=slots,
                                      default_duration=default_duration,
                                      start_numbering=4000),
            "jaxlocal": JX.make_jaxlocal_cluster(self.s3, slots=max(slots, 2)),
        }
        self.clusters["quantum"].payload = QB.quantum_payload(self.s3, "qresults")

        makers = {"slurm": SLB.make_server, "lsf": LSFB.make_server,
                  "quantum": QB.make_server, "ray": RAYB.make_server,
                  "jaxlocal": JX.make_server}
        self.servers = {}
        for kind, make in makers.items():
            fp = self.fault_profiles.get(kind)
            srv = make(self.clusters[kind], token=TOKENS[kind], fault=fp)
            self.servers[kind] = srv
            self.directory.register(URLS[kind], srv)
            self.secrets.create(f"{kind}-secret", {"token": TOKENS[kind]})

        self.operator = BridgeOperator(
            self.registry, self.statestore, self.secrets, self.s3,
            self.directory, self.adapters, **(operator_kwargs or {}))
        # the one client facade every consumer goes through
        self.bridge = Bridge.from_env(self)

    # -- convenience -----------------------------------------------------------

    def start(self) -> "BridgeEnvironment":
        self.operator.start()
        return self

    def stop(self) -> None:
        self.operator.stop()
        for c in self.clusters.values():
            c.shutdown()

    def __enter__(self) -> "BridgeEnvironment":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def make_spec(self, kind: str, *, script: str = "", scriptlocation: str = "inline",
                  jobproperties: Optional[Dict[str, str]] = None,
                  jobparams: Optional[Dict[str, str]] = None,
                  additionaldata: str = "", updateinterval: float = 0.02,
                  uploadfiles: str = "", uploadbucket: str = "",
                  kill: bool = False, unknown_after: int = 5,
                  array: Optional[ArraySpec] = None,
                  retry: Optional[RetryPolicy] = None,
                  ttl_seconds_after_finished: Optional[float] = None,
                  dependencies: Optional[list] = None,
                  placement: Optional[PlacementSpec] = None) -> BridgeJobSpec:
        """Spec targeting one of the five built-in backends.  The last five
        kwargs are v1beta1 features; omitting them yields a v1alpha1 spec.
        ``placement`` makes ``kind`` just the fallback target — the
        scheduler assigns the actual slice endpoints."""
        s3 = None
        if scriptlocation == "s3" or uploadfiles or additionaldata:
            s3 = S3Storage(s3secret="s3-secret", endpoint=self.s3.endpoint,
                           uploadfiles=uploadfiles, uploadbucket=uploadbucket)
        return BridgeJobSpec(
            resourceURL=URLS[kind], image=IMAGES[kind],
            resourcesecret=f"{kind}-secret", updateinterval=updateinterval,
            jobdata=JobData(jobscript=script, scriptlocation=scriptlocation,
                            additionaldata=additionaldata,
                            jobparams=dict(jobparams or {})),
            jobproperties=dict(jobproperties or {}), s3storage=s3,
            kill=kill, unknown_after=unknown_after,
            array=array, retry=retry,
            ttl_seconds_after_finished=ttl_seconds_after_finished,
            dependencies=list(dependencies or []),
            placement=placement)

    def make_service_spec(self, kind: str, *, replicas: int = 1,
                          script: str = "", scriptlocation: str = "inline",
                          jobproperties: Optional[Dict[str, str]] = None,
                          jobparams: Optional[Dict[str, str]] = None,
                          updateinterval: float = 0.02,
                          health: Optional[HealthProbeSpec] = None,
                          placement: Optional[PlacementSpec] = None,
                          unknown_after: int = 5,
                          autoscale: Optional[AutoscaleSpec] = None,
                          ) -> BridgeServiceSpec:
        """BridgeService spec whose replica template targets one of the
        built-in backends (``placement`` makes ``kind`` just the fallback
        target, exactly like ``make_spec``)."""
        template = self.make_spec(kind, script=script,
                                  scriptlocation=scriptlocation,
                                  jobproperties=jobproperties,
                                  jobparams=jobparams,
                                  updateinterval=updateinterval)
        return BridgeServiceSpec(template=template, replicas=replicas,
                                 placement=placement,
                                 health=health or HealthProbeSpec(),
                                 updateinterval=updateinterval,
                                 unknown_after=unknown_after,
                                 autoscale=autoscale)

    def submit(self, name: str, spec: BridgeJobSpec,
               namespace: str = "default") -> BridgeJob:
        """Create the CR through the facade; returns the stored CR (use
        ``env.bridge.submit`` directly when you want the ``JobHandle``)."""
        handle = self.bridge.submit(name, spec, namespace=namespace)
        return handle.job()
