"""gemma-2b [dense]: GeGLU, head_dim=256, MQA (kv=1).  [arXiv:2403.08295; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=256_000,
    head_dim=256,
    activation="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2403.08295",
)

SMOKE = ModelConfig(
    name="gemma-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab=256,
    head_dim=32,
    activation="geglu",
    tie_embeddings=True,
    embed_scale=True,
    dtype="float32",
)
