"""Load-aware scheduling + speculative execution (paper §7 future work)."""
import time

import pytest

from repro.core import (BridgeEnvironment, Candidate, DONE, IMAGES,
                        LoadAwareScheduler, URLS)


@pytest.fixture()
def env():
    with BridgeEnvironment(default_duration=0.05) as e:
        yield e


def _candidates():
    return [Candidate(URLS[k], IMAGES[k], f"{k}-secret")
            for k in ("slurm", "lsf", "ray")]


def _sched(env):
    # the scheduler is a pure Bridge client now — one facade, no hand-wiring
    return LoadAwareScheduler(env.bridge, _candidates())


def test_pick_least_loaded(env):
    sched = _sched(env)
    # saturate slurm with long jobs
    for _ in range(8):
        env.clusters["slurm"].submit("hog", {"WallSeconds": "10"}, {})
    ranked = sched.rank()
    assert ranked[0][1].resourceURL != URLS["slurm"]
    assert ranked[-1][1].resourceURL == URLS["slurm"]


def test_place_rewrites_spec(env):
    sched = _sched(env)
    for _ in range(8):
        env.clusters["slurm"].submit("hog", {"WallSeconds": "10"}, {})
    spec = env.make_spec("slurm", script="payload")
    placed = sched.place(spec)
    assert placed.resourceURL != URLS["slurm"]
    assert placed.jobdata.jobscript == "payload"  # payload untouched


def test_unreachable_candidate_skipped(env):
    sched = _sched(env)
    env.servers["lsf"].fault.begin_outage()
    ranked = sched.rank()
    assert all(c.resourceURL != URLS["lsf"] for _, c in ranked)
    env.servers["lsf"].fault.end_outage()


def test_speculative_execution_straggler_mitigation(env):
    """Launch on the two least-loaded backends; slow one gets killed."""
    sched = _sched(env)
    # make slurm slow (straggler) but still reachable
    env.clusters["slurm"].default_duration = 5.0
    spec = env.make_spec("slurm", script="payload", updateinterval=0.02)
    winner = sched.submit_speculative("spec-job", spec, n=2, timeout=30)
    assert winner.status.state == DONE
    # loser was killed (or still being killed) — eventually terminal
    others = [j for j in env.registry.list() if j.name != winner.name
              and j.name.startswith("spec-job")]
    deadline = time.time() + 20
    while time.time() < deadline:
        others = [j for j in env.registry.list() if j.name != winner.name
                  and j.name.startswith("spec-job")]
        if all(j.status.terminal() for j in others):
            break
        time.sleep(0.02)
    assert all(j.status.terminal() for j in others)
