from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update, opt_pspecs,
                               cosine_schedule, global_norm, clip_by_global_norm)
