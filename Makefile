# Single entry points so local and CI invocations cannot drift.
.PHONY: test test-compat deps-dev

# tier-1: the ROADMAP.md verify command, verbatim (via the shared wrapper)
test:
	bash tools/run_tier1.sh

# fast feedback on the JAX substrate seam only
test-compat:
	PYTHONPATH=src python -m pytest -q tests/test_compat.py

deps-dev:
	pip install -r requirements-dev.txt
