"""Quickstart: submit a job to a simulated SLURM cluster through the Bridge
Operator, exactly like the paper's Fig. 1 yaml, and watch it complete.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core import BridgeEnvironment


def main() -> None:
    with BridgeEnvironment(default_duration=0.3) as env:
        # the Fig. 1 BridgeJob, as a spec
        env.s3.put("mys3bucket", "slurmbatch.sh",
                   b"#!/bin/bash\n#SBATCH -N1\nsrun ./simulate\n")
        spec = env.make_spec(
            "slurm",
            script="mys3bucket:slurmbatch.sh", scriptlocation="s3",
            jobproperties={
                "NodesNumber": "1", "Queue": "V100", "Tasks": "2",
                "slurmJobName": "test",
                "ErrorFileName": "slurmjob.err",
                "OutputFileName": "slurmjob.out",
            },
            updateinterval=0.05,
        )
        env.submit("slurmjob-test", spec)
        print("BridgeJob created; operator reconciling...")
        last = ""
        while True:
            job = env.registry.get("slurmjob-test")
            if job.status.state != last:
                last = job.status.state
                print(f"  status={last:10s} remote_id={job.status.job_id!r}")
            if job.status.terminal():
                break
            time.sleep(0.02)
        print(f"final: {job.status.state}, "
              f"ran {job.status.end_time - job.status.start_time:.2f}s "
              f"on the external resource")
        assert job.status.state == "DONE"


if __name__ == "__main__":
    main()
