"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --requests 12 \
      --max-batch 4 --max-new 8
"""
import argparse
import time

import numpy as np

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.serving import ServingEngine
from repro.steps import init_model


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="gemma-2b", choices=ARCH_IDS)
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-new", type=int, default=8)
    p.add_argument("--prefill-len", type=int, default=16)
    p.add_argument("--max-len", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("serve targets decoder LMs; whisper decode is "
                         "exercised via tests/test_arch_smoke.py")
    _, params = init_model(cfg, seed=args.seed, max_seq=args.max_len)
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_len=args.max_len, prefill_len=args.prefill_len)
    rng = np.random.RandomState(args.seed)
    t0 = time.time()
    ids = [eng.submit(list(rng.randint(1, cfg.vocab, size=args.prefill_len)),
                      max_new_tokens=args.max_new)
           for _ in range(args.requests)]
    results = eng.run_until_idle()
    dt = time.time() - t0
    for rid in ids[:4]:
        print(f"[serve] req {rid}: {results[rid]}")
    toks = eng.stats["tokens"]
    print(f"[serve] {args.requests} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {eng.stats['decode_ticks']} ticks, "
          f"{eng.stats['prefills']} prefills)")


if __name__ == "__main__":
    main()
