"""Dry-run machinery smoke: lower+compile a handful of representative cells
on the production 16x16 mesh, in a subprocess (512 forced host devices must
never leak into the main test process).  The FULL 40-cell x 2-mesh sweep is
run by `python -m repro.launch.dryrun --all --both-meshes` (artifacts are
committed under artifacts/dryrun/ and summarized in EXPERIMENTS.md)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
from repro.launch.dryrun import run_cell
arch, shape, multi = sys.argv[1], sys.argv[2], sys.argv[3] == "multi"
rec = run_cell(arch, shape, multi_pod=multi, verbose=False)
print("RESULT " + json.dumps({k: rec[k] for k in
    ("arch", "shape", "mesh", "hlo_flops_per_dev", "n_chips")}))
"""


def _run(arch, shape, mesh="single", timeout=540):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", SCRIPT, arch, shape, mesh],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[7:])


def test_dryrun_train_cell_single_pod():
    rec = _run("gemma-2b", "train_4k")
    assert rec["n_chips"] == 256
    assert rec["hlo_flops_per_dev"] > 1e13


def test_dryrun_decode_cell_single_pod():
    rec = _run("granite-3-8b", "decode_32k")
    assert rec["n_chips"] == 256


def test_dryrun_multi_pod_mesh():
    rec = _run("phi3-mini-3.8b", "train_4k", mesh="multi")
    assert rec["n_chips"] == 512
    assert rec["mesh"] == "2x16x16"


def test_dryrun_long_context_ssm():
    rec = _run("xlstm-125m", "long_500k")
    assert rec["n_chips"] == 256
