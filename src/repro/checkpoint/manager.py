"""Checkpointing: commit-marked, reshard-on-load, async save, keep-last-k.

Layout under an ObjectStore prefix (works over local dirs or the in-memory
store — the same store the bridge uses for S3 staging):

    <prefix>/step_000123/leaf_0000.npy ... leaf_NNNN.npy
    <prefix>/step_000123/MANIFEST.json   <- written LAST (commit marker)

A checkpoint without MANIFEST.json is invisible to ``latest_step`` — a save
interrupted by a node failure can never be restored from partially.

Reshard-on-load: leaves are stored as full (unsharded) arrays; ``restore``
device_puts them with the CURRENT mesh's shardings, so an elastic restart may
change the mesh shape freely.  (On a real multi-host pod each host would save
its addressable shards; the manifest format already records per-leaf shapes
so that extension is additive.)
"""
from __future__ import annotations

import io
import json
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.objectstore import NoSuchKey, ObjectStore

MANIFEST = "MANIFEST.json"


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _dump_npy(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _load_npy(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


class CheckpointManager:
    def __init__(self, store: ObjectStore, bucket: str, prefix: str,
                 keep: int = 3):
        self.store = store
        self.bucket = bucket
        self.prefix = prefix.rstrip("/")
        self.keep = keep
        self._async_thread: Optional[threading.Thread] = None
        self._async_err: Optional[BaseException] = None

    # -- save ----------------------------------------------------------------

    def _to_host(self, tree: Any) -> List[Tuple[str, np.ndarray, str]]:
        """(keypath, numpy array [bf16 stored as uint16 view], dtype tag)."""
        out = []
        for keypath, leaf in _leaf_paths(tree):
            dtype_tag = str(leaf.dtype)
            arr = np.asarray(jax.device_get(leaf))
            if dtype_tag == "bfloat16":
                arr = arr.view(np.uint16)
            out.append((keypath, arr, dtype_tag))
        return out

    def _write(self, step: int, host_leaves: List[Tuple[str, np.ndarray, str]],
               extra: Optional[Dict[str, Any]]) -> None:
        stepdir = self._stepdir(step)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for i, (keypath, arr, dtype_tag) in enumerate(host_leaves):
            key = f"{stepdir}/leaf_{i:05d}.npy"
            self.store.put(self.bucket, key, _dump_npy(arr))
            manifest["leaves"].append({"path": keypath, "key": key,
                                       "dtype": dtype_tag,
                                       "shape": list(arr.shape)})
        # commit marker LAST
        self.store.put(self.bucket, f"{stepdir}/{MANIFEST}",
                       json.dumps(manifest).encode())
        self._gc()

    def save(self, step: int, tree: Any, extra: Optional[Dict[str, Any]] = None) -> None:
        self._write(step, self._to_host(tree), extra)

    def save_async(self, step: int, tree: Any,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot to host memory synchronously, write in the background —
        the train loop resumes while bytes stream out (compute/IO overlap)."""
        self.wait()  # one in flight at a time
        host_leaves = self._to_host(tree)

        def work():
            try:
                self._write(step, host_leaves, extra)
            except BaseException as e:  # surfaced on next wait()
                self._async_err = e

        self._async_thread = threading.Thread(target=work, daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_err is not None:
            err, self._async_err = self._async_err, None
            raise err

    # -- restore ------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = []
        for key in self.store.list(self.bucket, self.prefix + "/"):
            if key.endswith("/" + MANIFEST):
                part = key[len(self.prefix) + 1:].split("/")[0]
                if part.startswith("step_"):
                    steps.append(int(part[5:]))
        return max(steps) if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None
                ) -> Tuple[Any, Dict[str, Any]]:
        """``like``: pytree (concrete or ShapeDtypeStruct) fixing the treedef.
        ``shardings``: optional matching tree of NamedSharding for reshard-on-load."""
        stepdir = self._stepdir(step)
        manifest = json.loads(self.store.get(self.bucket, f"{stepdir}/{MANIFEST}"))
        flat_like, treedef = jax.tree_util.tree_flatten(like)
        entries = manifest["leaves"]
        if len(entries) != len(flat_like):
            raise ValueError(f"checkpoint has {len(entries)} leaves, "
                             f"model expects {len(flat_like)}")
        flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
                   if shardings is not None else [None] * len(flat_like))
        out = []
        for e, lk, sh in zip(entries, flat_like, flat_sh):
            arr = _load_npy(self.store.get(self.bucket, e["key"]))
            if e["dtype"] == "bfloat16":
                arr = arr.view(jax.numpy.bfloat16.dtype)
            if tuple(arr.shape) != tuple(lk.shape):
                raise ValueError(f"{e['path']}: shape {arr.shape} != {lk.shape}")
            out.append(jax.device_put(arr, sh) if sh is not None else
                       jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]

    def restore_latest(self, like: Any, shardings: Any = None
                       ) -> Optional[Tuple[int, Any, Dict[str, Any]]]:
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, like, shardings)
        return step, tree, extra

    # -- internals --------------------------------------------------------------

    def _stepdir(self, step: int) -> str:
        return f"{self.prefix}/step_{step:08d}"

    def _gc(self) -> None:
        steps = sorted({int(k[len(self.prefix) + 1:].split("/")[0][5:])
                        for k in self.store.list(self.bucket, self.prefix + "/")
                        if k.endswith("/" + MANIFEST)
                        and k[len(self.prefix) + 1:].startswith("step_")})
        for old in steps[:-self.keep] if self.keep > 0 else []:
            stepdir = self._stepdir(old)
            # delete manifest FIRST (uncommit), then leaves
            self.store.delete(self.bucket, f"{stepdir}/{MANIFEST}")
            for key in self.store.list(self.bucket, stepdir + "/"):
                self.store.delete(self.bucket, key)
