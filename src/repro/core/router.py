"""Request routing for BridgeService — the data-plane half of serving.

``ServiceHandle`` is the kubectl-style control surface over one BridgeService
CR (scale / kill / wait-ready, mirroring ``JobHandle``).  ``ServiceEndpoint``
is the request router: it load-balances invocations across the replicas the
service reports READY, re-resolving ``status.endpoints`` from the registry on
every request so that a condemned replica is drained the same tick the
control plane flips its ``ready`` flag.

Routing policy is least-outstanding-requests: among ready replicas, pick the
one with the fewest in-flight invocations (ties broken by total request
count, then replica index).  Adapter connections are cached per
``(resourceURL, image, resourcesecret)`` target, so every endpoint on the
same resource manager shares one ``Channel`` — connection reuse is the
channel memo's job, not the router's.

Delivery contract: a request is retried on another replica when the attempt
fails in a way that indicts the REPLICA (transport error, 404 gone,
503 unready, 5xx crash) — so killing a replica mid-traffic loses no accepted
request.  The failed replica is locally suspended for a short TTL to stop
the router hammering it before the control plane condemns it.  The flip side
is at-least-once execution across replicas on failure: a replica that dies
AFTER executing but before replying will have its request re-executed
elsewhere.  Status codes that indict the REQUEST (4xx other than 404) are
raised to the caller unretried.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

from repro.core.backends import base as B
from repro.core.resource import (BridgeService, BridgeServiceSpec,
                                 BridgeServiceStatus, ValidationError)
from repro.core.rest import TransportError


class NoReadyReplicas(RuntimeError):
    """No replica answered within the request budget."""


@dataclasses.dataclass(frozen=True)
class ServiceHandle:
    """A client-side reference to one BridgeService CR."""
    bridge: Any
    name: str
    namespace: str = "default"

    def service(self) -> Optional[BridgeService]:
        return self.bridge.registry.get(self.name, self.namespace)

    def status(self) -> BridgeServiceStatus:
        svc = self.service()
        if svc is None:
            raise KeyError(
                f"BridgeService {self.namespace}/{self.name} not found")
        return svc.status

    def endpoints(self) -> List[dict]:
        """``status.endpoints`` — one dict per replica:
        {replica, slice, resourceURL, image, resourcesecret, job_id, ready}."""
        return [dict(e) for e in self.status().endpoints]

    def ready_replicas(self) -> int:
        return self.status().ready_replicas

    def wait_ready(self, replicas: Optional[int] = None,
                   timeout: float = 30.0) -> BridgeService:
        """Block until at least ``replicas`` (default: spec.replicas) report
        ready, or raise TimeoutError.  A terminal service can never become
        ready and fails fast."""
        deadline = time.time() + timeout
        svc = None
        while time.time() < deadline:
            svc = self.service()
            if svc is not None:
                want = replicas if replicas is not None else svc.spec.replicas
                if svc.status.ready_replicas >= want:
                    return svc
                if svc.status.terminal():
                    raise NoReadyReplicas(
                        f"BridgeService {self.namespace}/{self.name} is "
                        f"terminal ({svc.status.state})")
            time.sleep(0.01)
        raise TimeoutError(
            f"BridgeService {self.namespace}/{self.name} not ready after "
            f"{timeout}s (ready={svc.status.ready_replicas if svc else '?'})")

    def scale(self, replicas: int) -> "ServiceHandle":
        """Resize the service to ``replicas``; the reconciler submits or
        condemns exactly the delta (scale-down drains the highest replica
        indices first)."""
        if replicas < 1:
            raise ValidationError("service replicas must be >= 1")

        def guarded(spec: BridgeServiceSpec) -> BridgeServiceSpec:
            cur = self.service()
            if cur is not None and cur.status.terminal():
                raise ValidationError(
                    f"cannot scale terminal BridgeService "
                    f"{self.namespace}/{self.name} ({cur.status.state})")
            return dataclasses.replace(spec, replicas=replicas)

        self.bridge.registry.update_spec(self.name, guarded, self.namespace)
        return self

    def wait_reconciled(self, timeout: float = 30.0) -> BridgeService:
        return self.bridge.wait_reconciled(self.name, self.namespace,
                                           timeout=timeout)

    def cancel(self) -> None:
        """Kill the service: cancel every replica, settle the CR KILLED."""
        self.bridge.registry.update_spec(
            self.name, lambda s: dataclasses.replace(s, kill=True),
            self.namespace)

    def wait(self, timeout: float = 30.0) -> BridgeService:
        """Block until terminal (only a kill makes a service terminal)."""
        return self.bridge.wait(self.name, self.namespace, timeout=timeout)

    def delete(self) -> None:
        self.bridge.delete(self.name, self.namespace)

    def router(self, **kwargs) -> "ServiceEndpoint":
        return ServiceEndpoint(self.bridge, self.name, self.namespace,
                               **kwargs)


class ServiceEndpoint:
    """Load-balancing request router over one BridgeService's replicas."""

    def __init__(self, bridge: Any, name: str, namespace: str = "default",
                 request_timeout: float = 30.0,
                 suspend_ttl: float = 0.5,
                 latency_window: int = 256):
        self.bridge = bridge
        self.name = name
        self.namespace = namespace
        self.request_timeout = request_timeout
        self.suspend_ttl = suspend_ttl
        self._latency_window = latency_window
        self._mu = threading.Lock()
        # adapter per target: all endpoints behind one manager share a Channel
        self._adapters: Dict[tuple, B.ResourceAdapter] = {}
        # job_id -> suspended-until (local short fuse after a failed attempt)
        self._down: Dict[str, float] = {}
        # job_id -> live counters for THIS replica incarnation
        self._stats: Dict[str, Dict[str, Any]] = {}

    # -- endpoint resolution ----------------------------------------------

    def _ready_endpoints(self) -> List[dict]:
        svc = self.bridge.registry.get(self.name, self.namespace)
        if svc is None:
            raise KeyError(
                f"BridgeService {self.namespace}/{self.name} not found")
        now = time.time()
        eps = []
        for e in svc.status.endpoints:
            if not e.get("ready") or not e.get("job_id"):
                continue
            if self._down.get(e["job_id"], 0.0) > now:
                continue
            eps.append(e)
        return eps

    def _adapter_for(self, ep: dict) -> B.ResourceAdapter:
        key = (ep["resourceURL"], ep["image"], ep["resourcesecret"])
        with self._mu:
            ad = self._adapters.get(key)
        if ad is None:
            ad = self.bridge.connect_adapter(*key)
            with self._mu:
                ad = self._adapters.setdefault(key, ad)
        return ad

    def _entry(self, ep: dict) -> Dict[str, Any]:
        jid = ep["job_id"]
        with self._mu:
            st = self._stats.get(jid)
            if st is None:
                st = self._stats[jid] = {
                    "replica": ep["replica"], "job_id": jid,
                    "requests": 0, "errors": 0, "outstanding": 0,
                    "latencies": deque(maxlen=self._latency_window),
                }
        return st

    def _pick(self, eps: List[dict]) -> dict:
        """Least outstanding requests; ties fall to fewest total requests,
        then lowest replica index (deterministic)."""
        def load(ep):
            st = self._entry(ep)
            return (st["outstanding"], st["requests"], ep["replica"])
        return min(eps, key=load)

    # -- the request path --------------------------------------------------

    @staticmethod
    def _replica_fault(exc: Exception) -> bool:
        """True when the failure indicts the replica (retry elsewhere)."""
        if isinstance(exc, TransportError):
            return True
        if isinstance(exc, B.InvokeError):
            return exc.status == 404 or exc.status >= 500
        return False

    def request(self, payload: Any,
                timeout: Optional[float] = None) -> Any:
        """Route one invocation to the least-loaded ready replica.

        Replica-fault failures are retried on another replica until the
        request budget runs out; request-fault failures (4xx) raise
        immediately.  With no ready replica, the call parks and re-resolves
        until one appears or the budget is spent."""
        deadline = time.time() + (timeout if timeout is not None
                                  else self.request_timeout)
        last_exc: Optional[Exception] = None
        while True:
            eps = self._ready_endpoints()
            if not eps:
                if time.time() >= deadline:
                    raise NoReadyReplicas(
                        f"no ready replica for {self.namespace}/{self.name} "
                        f"within the request budget"
                    ) from last_exc
                time.sleep(0.01)
                continue
            ep = self._pick(eps)
            st = self._entry(ep)
            adapter = self._adapter_for(ep)
            with self._mu:
                st["requests"] += 1
                st["outstanding"] += 1
            t0 = time.time()
            try:
                result = adapter.invoke(ep["job_id"], payload)
            except Exception as exc:
                with self._mu:
                    st["outstanding"] -= 1
                    st["errors"] += 1
                if not self._replica_fault(exc):
                    raise
                last_exc = exc
                # short local suspension: stop re-picking a replica the
                # control plane has not yet condemned
                with self._mu:
                    self._down[ep["job_id"]] = time.time() + self.suspend_ttl
                if time.time() >= deadline:
                    raise NoReadyReplicas(
                        f"request to {self.namespace}/{self.name} exhausted "
                        f"its budget retrying failed replicas") from exc
                continue
            with self._mu:
                st["outstanding"] -= 1
                st["latencies"].append(time.time() - t0)
            return result

    __call__ = request

    # -- observability -----------------------------------------------------

    def stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-replica-incarnation counters, keyed by remote job id:
        {replica, job_id, requests, errors, outstanding, p50_s, p99_s}."""
        out: Dict[str, Dict[str, Any]] = {}
        with self._mu:
            for jid, st in self._stats.items():
                lat = sorted(st["latencies"])
                out[jid] = {
                    "replica": st["replica"], "job_id": jid,
                    "requests": st["requests"], "errors": st["errors"],
                    "outstanding": st["outstanding"],
                    "p50_s": lat[len(lat) // 2] if lat else None,
                    "p99_s": lat[min(len(lat) - 1,
                                     int(len(lat) * 0.99))] if lat else None,
                }
        return out
