"""Load-aware scheduling + speculative execution (paper §7 future work),
plus sharded-placement planning (plan_slices) and the TTL-cached concurrent
LoadProbe behind rank()."""
import time

import pytest

from repro.core import (BridgeEnvironment, Candidate, DONE, FaultProfile,
                        IMAGES, LoadAwareScheduler, plan_slices, URLS)


@pytest.fixture()
def env():
    with BridgeEnvironment(default_duration=0.05) as e:
        yield e


def _candidates():
    return [Candidate(URLS[k], IMAGES[k], f"{k}-secret")
            for k in ("slurm", "lsf", "ray")]


def _sched(env):
    # the scheduler is a pure Bridge client now — one facade, no hand-wiring
    return LoadAwareScheduler(env.bridge, _candidates())


def test_pick_least_loaded(env):
    sched = _sched(env)
    # saturate slurm with long jobs
    for _ in range(8):
        env.clusters["slurm"].submit("hog", {"WallSeconds": "10"}, {})
    ranked = sched.rank()
    assert ranked[0][1].resourceURL != URLS["slurm"]
    assert ranked[-1][1].resourceURL == URLS["slurm"]


def test_place_rewrites_spec(env):
    sched = _sched(env)
    for _ in range(8):
        env.clusters["slurm"].submit("hog", {"WallSeconds": "10"}, {})
    spec = env.make_spec("slurm", script="payload")
    placed = sched.place(spec)
    assert placed.resourceURL != URLS["slurm"]
    assert placed.jobdata.jobscript == "payload"  # payload untouched


def test_unreachable_candidate_skipped(env):
    sched = _sched(env)
    env.servers["lsf"].fault.begin_outage()
    ranked = sched.rank()
    assert all(c.resourceURL != URLS["lsf"] for _, c in ranked)
    env.servers["lsf"].fault.end_outage()


def test_rank_caches_probes_within_ttl(env):
    """Satellite: rank() must not re-pay N HTTP round-trips per call — the
    probe's TTL cache answers repeat rankings within the window."""
    sched = LoadAwareScheduler(env.bridge, _candidates(), load_ttl=30.0)
    req0 = {k: env.servers[k].request_count for k in ("slurm", "lsf", "ray")}
    sched.rank()
    after_first = {k: env.servers[k].request_count for k in req0}
    assert all(after_first[k] > req0[k] for k in req0), "first rank probes"
    sched.rank()
    sched.rank()
    assert {k: env.servers[k].request_count for k in req0} == after_first, (
        "repeat rank() within the TTL must be served from the cache")
    sched.probe.invalidate()
    sched.rank()
    assert all(env.servers[k].request_count > after_first[k] for k in req0)


def test_failed_probe_invalidates_cache_entry(env):
    """Satellite: a FAILED probe must drop the candidate's cache entry, not
    negative-cache it — once the target recovers, the very next query sees
    the live value instead of serving None for the rest of the TTL window."""
    sched = LoadAwareScheduler(env.bridge, _candidates(), load_ttl=30.0)
    probe = sched.probe
    cand = _candidates()[0]  # slurm
    assert probe.query(cand) is not None, "baseline probe reaches the target"
    probe.invalidate()
    env.servers["slurm"].fault.begin_outage()
    assert probe.query(cand) is None, "outage observed"
    env.servers["slurm"].fault.end_outage()
    # with a 30s TTL, a negative-cached failure would pin None here; the fix
    # re-probes immediately because the failed entry was invalidated
    assert probe.query(cand) is not None, (
        "recovered target still served from a stale failed-probe entry")


def test_rank_probes_candidates_concurrently():
    """Satellite: a many-candidate rank() costs ~one round-trip time, not
    the sum of serialized probes."""
    latency = 0.15
    fp = {k: FaultProfile(latency=latency) for k in ("slurm", "lsf", "ray")}
    with BridgeEnvironment(default_duration=0.05, fault_profiles=fp) as env:
        sched = LoadAwareScheduler(env.bridge, _candidates())
        t0 = time.time()
        ranked = sched.rank()
        elapsed = time.time() - t0
        assert len(ranked) == 3
        assert elapsed < 2.5 * latency, (
            f"rank() took {elapsed:.3f}s for 3 candidates at {latency}s "
            f"latency each — probes are serialized")


# ---------------------------------------------------------------------------
# sharded placement: plan_slices
# ---------------------------------------------------------------------------


def _cand(n, weight=1.0):
    return Candidate(f"https://{n}.example.com", "slurmpod:0.1",
                     f"{n}-secret", weight=weight)


def _q(queued, running, slots):
    return {"queued": queued, "running": running, "slots": slots}


def test_plan_spread_splits_load_proportionally():
    """spread: shares follow FREE slots (slots - queued - running), with
    contiguous ranges covering exactly [0, count)."""
    plan = plan_slices(64, [_cand("a"), _cand("b")],
                       [_q(0, 0, 8), _q(0, 0, 4)], strategy="spread")
    assert [(p["start"], p["count"]) for p in plan] == [(0, 43), (43, 21)]
    assert plan[0]["resourceURL"] == "https://a.example.com"
    # a busy resource gets proportionally less
    plan = plan_slices(12, [_cand("a"), _cand("b")],
                       [_q(2, 4, 8), _q(0, 0, 4)], strategy="spread")
    assert [(p["resourceURL"].startswith("https://a"), p["count"])
            for p in plan] == [(False, 8), (True, 4)]  # free 4 vs free 2


def test_plan_spread_full_clusters_fall_back_to_slots():
    plan = plan_slices(9, [_cand("a"), _cand("b")],
                       [_q(8, 8, 8), _q(4, 4, 4)], strategy="spread")
    assert sorted(p["count"] for p in plan) == [3, 6]


def test_plan_weighted_uses_static_weights():
    plan = plan_slices(16, [_cand("a", weight=1.0), _cand("b", weight=3.0)],
                       [_q(0, 0, 4), _q(0, 0, 4)], strategy="weighted")
    by_url = {p["resourceURL"]: p["count"] for p in plan}
    assert by_url["https://a.example.com"] == 4
    assert by_url["https://b.example.com"] == 12


def test_plan_single_takes_least_loaded():
    plan = plan_slices(10, [_cand("a"), _cand("b")],
                       [_q(6, 2, 8), _q(0, 1, 4)], strategy="single")
    assert plan == [{"resourceURL": "https://b.example.com",
                     "image": "slurmpod:0.1", "resourcesecret": "b-secret",
                     "start": 0, "count": 10}]


def test_plan_drops_unreachable_and_respects_max_slices():
    # unreachable candidate (load None) is excluded when others answer
    plan = plan_slices(8, [_cand("a"), _cand("b"), _cand("c")],
                       [None, _q(0, 0, 4), _q(0, 0, 4)], strategy="spread")
    assert all(not p["resourceURL"].startswith("https://a") for p in plan)
    assert sum(p["count"] for p in plan) == 8
    # max_slices caps the number of resources used (highest shares win)
    plan = plan_slices(8, [_cand("a"), _cand("b"), _cand("c")],
                       [_q(0, 0, 2), _q(0, 0, 8), _q(0, 0, 4)],
                       strategy="spread", max_slices=2)
    assert len(plan) == 2
    assert {p["resourceURL"] for p in plan} == {
        "https://b.example.com", "https://c.example.com"}
    # nothing reachable at all: optimistic equal split (retry path surfaces
    # real failures later), never an empty plan
    plan = plan_slices(4, [_cand("a"), _cand("b")], [None, None],
                       strategy="spread")
    assert sum(p["count"] for p in plan) == 4 and len(plan) == 2


def test_speculative_execution_straggler_mitigation(env):
    """Launch on the two least-loaded backends; slow one gets killed."""
    sched = _sched(env)
    # make slurm slow (straggler) but still reachable
    env.clusters["slurm"].default_duration = 5.0
    spec = env.make_spec("slurm", script="payload", updateinterval=0.02)
    winner = sched.submit_speculative("spec-job", spec, n=2, timeout=30)
    assert winner.status.state == DONE
    # loser was killed (or still being killed) — eventually terminal
    others = [j for j in env.registry.list() if j.name != winner.name
              and j.name.startswith("spec-job")]
    deadline = time.time() + 20
    while time.time() < deadline:
        others = [j for j in env.registry.list() if j.name != winner.name
                  and j.name.startswith("spec-job")]
        if all(j.status.terminal() for j in others):
            break
        time.sleep(0.02)
    assert all(j.status.terminal() for j in others)
