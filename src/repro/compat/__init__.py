"""repro.compat — the version-portable JAX substrate layer.

The paper's Bridge Operator is agnostic to the external resource behind
it (§5.1); this package makes the compute substrate equally agnostic to
the installed JAX.  It is the SINGLE allowed entry point for every
version-sensitive JAX API in this tree:

  * :func:`shard_map`        — ``jax.shard_map`` vs
    ``jax.experimental.shard_map.shard_map``; ``check_vma`` vs
    ``check_rep`` kwarg;
  * :func:`use_mesh`         — ``jax.sharding.set_mesh`` vs
    ``jax.sharding.use_mesh`` vs the ``with mesh:`` context;
  * :func:`mosaic_params`    — ``pltpu.CompilerParams`` vs
    ``pltpu.TPUCompilerParams`` vs omitting compiler params entirely;
  * :func:`jit_sharded`      — ``jax.jit`` over PartitionSpec pytrees
    (new JAX takes raw specs under a current mesh; old JAX needs them
    bound to ``NamedSharding`` first);
  * capability probes        — :func:`has_tpu`, :func:`pallas_available`,
    :func:`pallas_interpret_default`, :func:`resolve_interpret`,
    :func:`best_kernel_path` — so kernels pick pallas-TPU,
    pallas-interpret, or the pure-XLA reference path at runtime.

Rules of the seam (enforced by tests/test_compat.py's source scan):
  1. no module under ``src/repro/`` outside this package may reference
     ``jax.shard_map``, ``set_mesh``, or ``*CompilerParams`` directly;
  2. resolution is by API probing, never by version-string comparison;
  3. when the JAX pin moves and an API churns again, absorb it HERE —
     call sites must not grow version checks.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.compat.capability import (best_kernel_path, has_tpu,
                                     pallas_available,
                                     pallas_interpret_default,
                                     resolve_interpret)
from repro.compat.jitting import (cost_analysis_dict, jit_sharded,
                                  resolve_shardings)
from repro.compat.meshctx import use_mesh, use_mesh_source
from repro.compat.pallas import compiler_params_source, mosaic_params
from repro.compat.shard import shard_map, shard_map_source
from repro.compat.versions import at_least, jax_version, jax_version_tuple

__all__ = [
    "at_least", "best_kernel_path", "compiler_params_source",
    "cost_analysis_dict", "describe",
    "has_tpu", "jax_version", "jax_version_tuple", "jit_sharded",
    "mosaic_params", "pallas_available", "pallas_interpret_default",
    "resolve_interpret", "resolve_shardings", "shard_map",
    "shard_map_source", "use_mesh", "use_mesh_source",
]


def describe() -> Dict[str, Any]:
    """How every seam resolved on this JAX — for logs and bug reports."""
    return {
        "jax_version": jax_version(),
        "shard_map": shard_map_source(),
        "use_mesh": use_mesh_source(),
        "compiler_params": compiler_params_source(),
        "pallas_available": pallas_available(),
        "has_tpu": has_tpu(),
        "best_kernel_path": best_kernel_path(),
    }
