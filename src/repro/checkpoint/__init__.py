from repro.checkpoint.manager import MANIFEST, CheckpointManager
