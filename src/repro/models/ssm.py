"""Mamba-style selective-scan SSM mixer (hymba's parallel-head partner).

Training/prefill uses an associative scan over time (work-efficient, O(S log S)
depth); decode carries (conv_state, ssm_state) and is O(1) per token.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef
from repro.models.layers import adtype

Params = Dict[str, Any]


def ssm_defs(cfg) -> Params:
    s = cfg.ssm
    d, di, n, k = cfg.d_model, s.d_inner(cfg.d_model), s.d_state, s.d_conv
    dt = adtype(cfg)
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": ParamDef((d, 2 * di), ("embed", "inner"), dtype=dt),
        "conv_w": ParamDef((k, di), (None, "inner"), init="scaled", scale=0.5, dtype=dt),
        "conv_b": ParamDef((di,), ("inner",), init="zeros", dtype=dt),
        "x_proj": ParamDef((di, dt_rank + 2 * n), ("inner", None), dtype=dt),
        "dt_proj": ParamDef((dt_rank, di), (None, "inner"), dtype=dt),
        "dt_bias": ParamDef((di,), ("inner",), init="scaled", scale=1.0, dtype=jnp.float32),
        "A_log": ParamDef((di, n), ("inner", None), init="scaled", scale=1.0, dtype=jnp.float32),
        "D": ParamDef((di,), ("inner",), init="ones", dtype=jnp.float32),
        "out_proj": ParamDef((di, d), ("inner", "embed"), dtype=dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x: (B,S,di); w: (k,di).  Returns (y, new_state)
    where state holds the last k-1 inputs (B,k-1,di)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+k-1, di)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y, new_state


def _sel_params(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (..., di) -> (delta (...,di), B (...,n), C (...,n)) all f32."""
    n = cfg.ssm.d_state
    dt_rank = p["dt_proj"].shape[0]
    proj = x @ p["x_proj"]  # (..., dt_rank + 2n)
    dt_in, bc = proj[..., :dt_rank], proj[..., dt_rank:]
    delta = jax.nn.softplus(dt_in.astype(jnp.float32) @ p["dt_proj"].astype(jnp.float32)
                            + p["dt_bias"])
    B, C = bc[..., :n].astype(jnp.float32), bc[..., n:].astype(jnp.float32)
    return delta, B, C


def ssm_forward(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence selective scan.  x: (B,S,d) -> (y (B,S,d), final state)."""
    xz = x @ p["in_proj"]
    di = xz.shape[-1] // 2
    xs, z = xz[..., :di], xz[..., di:]
    xs, conv_state = _causal_conv(xs, p["conv_w"], p["conv_b"])
    xs = jax.nn.silu(xs)
    delta, B, C = _sel_params(p, xs, cfg)
    A = -jnp.exp(p["A_log"])  # (di, n)
    xf = xs.astype(jnp.float32)

    impl = getattr(cfg.ssm, "scan_impl", "assoc")
    if impl in ("chunked", "chunked_u"):
        y, h_last = _chunked_selective_scan(delta, B, C, xf, A,
                                            chunk=cfg.ssm.chunk,
                                            unroll=(impl == "chunked_u"))
    else:
        # discretize: a_t = exp(delta_t*A) (B,S,di,n); b_t = delta_t*B_t*x_t
        dA = jnp.exp(delta[..., None] * A)  # (B,S,di,n)
        dBx = delta[..., None] * B[:, :, None, :] * xf[..., None]

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", h, C)
        h_last = h[:, -1]
    y = y + p["D"] * xf
    y = y.astype(x.dtype) * jax.nn.silu(z)
    final = {"conv": conv_state, "ssm": h_last}  # (B,di,n)
    return y @ p["out_proj"], final


def _chunked_selective_scan(delta, B, C, xf, A, chunk: int,
                            unroll: bool = False):
    """Stream the recurrence in (B,chunk,di,N) tiles: the discretized dA/dBx
    tensors never materialize at full length (the assoc baseline writes
    O(S·di·N) f32 to HBM; this path writes O(chunk·di·N) per step and
    carries h).  Within a chunk the scan is associative + a prefix
    correction for the carried state."""
    b, s, di = xf.shape
    n = B.shape[-1]
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
    nb = delta.shape[1] // c

    def to_chunks(t):
        return t.reshape(b, nb, c, *t.shape[2:]).swapaxes(0, 1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    def body(h_in, args):
        d_c, B_c, C_c, x_c = args          # (B,c,di) / (B,c,n) / .. / (B,c,di)
        dA = jnp.exp(d_c[..., None] * A)   # (B,c,di,n)
        dBx = d_c[..., None] * B_c[:, :, None, :] * x_c[..., None]
        pa, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        hs = hs + pa * h_in[:, None]       # prefix correction
        y_c = jnp.einsum("bsdn,bsn->bsd", hs, C_c)
        return hs[:, -1], y_c

    h0 = jnp.zeros((b, di, n), jnp.float32)
    if unroll:
        # explicit chunk loop so HLO cost analysis sees every chunk
        h, ys_l = h0, []
        dc, Bc, Cc, xc = (to_chunks(delta), to_chunks(B), to_chunks(C),
                          to_chunks(xf))
        for i in range(nb):
            h, y_c = body(h, (dc[i], Bc[i], Cc[i], xc[i]))
            ys_l.append(y_c)
        h_last, ys = h, jnp.stack(ys_l)
    else:
        h_last, ys = jax.lax.scan(
            body, h0, (to_chunks(delta), to_chunks(B), to_chunks(C),
                       to_chunks(xf)))
    y = ys.swapaxes(0, 1).reshape(b, nb * c, di)[:, :s]
    return y, h_last


def ssm_decode(p: Params, x: jax.Array, state: Dict[str, jax.Array], cfg
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token step.  x: (B,1,d); state: {conv (B,k-1,di), ssm (B,di,n)}."""
    xz = x @ p["in_proj"]
    di = xz.shape[-1] // 2
    xs, z = xz[..., :di], xz[..., di:]
    xs, conv_state = _causal_conv(xs, p["conv_w"], p["conv_b"], state["conv"])
    xs = jax.nn.silu(xs)
    delta, B, C = _sel_params(p, xs[:, 0], cfg)  # (B,di),(B,n),(B,n)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(delta[..., None] * A)  # (B,di,n)
    h = state["ssm"] * dA + delta[..., None] * B[:, None, :] * xs[:, 0].astype(jnp.float32)[..., None]
    y = jnp.einsum("bdn,bn->bd", h, C) + p["D"] * xs[:, 0].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None]
    return y @ p["out_proj"], {"conv": conv_state, "ssm": h}


def init_ssm_state(cfg, batch: int) -> Dict[str, jax.Array]:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di), adtype(cfg)),
        "ssm": jnp.zeros((batch, di, s.d_state), jnp.float32),
    }
