"""Batched serving engine with continuous batching (slot refill).

A fixed pool of ``max_batch`` decode slots shares one batched KV cache.
Requests queue up; a free slot is filled by prefilling the request at batch=1
and scattering its cache into the slot (per-leaf dynamic_update on the batch
axis).  Decode ticks advance every active slot one token; finished slots are
refilled immediately — decode never drains the whole batch to admit work.

Prompt padding: attention-family caches are position-indexed, so prompts are
right-padded to ``prefill_len`` and masked via the cache's valid-length
(``pos``); the first generated token is produced by re-decoding the last
prompt token (idempotent KV write), which sidesteps the padded-last-position
logits problem.  Recurrent families (ssm/hybrid) fold pads into their state,
so the engine requires exact-length prompts for them.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decoding as DEC

Params = Dict[str, Any]


@dataclasses.dataclass
class Request:
    id: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _batch_axis(keypath: str) -> int:
    """Batch axis per cache leaf (see decoding.py cache layouts)."""
    for marker in ("'k'", "'v'", "'conv'", "'ssm'", "'cross_k'", "'cross_v'"):
        if marker in keypath:
            return 1  # (L, B, ...)
    return 0  # pos (B,), xlstm block states (B, ...)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Params, *, max_batch: int = 4,
                 max_len: int = 128, prefill_len: int = 32):
        if cfg.family == "encdec":
            raise NotImplementedError("serving engine targets decoder LMs")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill_len = prefill_len
        self._ids = itertools.count()
        self.pending: deque = deque()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.finished: Dict[int, Request] = {}
        self.stats = {"prefills": 0, "decode_ticks": 0, "tokens": 0}

        self.cache = DEC.init_cache(cfg, max_batch, max_len)
        self._cache_axes = [
            _batch_axis(jax.tree_util.keystr(p))
            for p, _ in jax.tree_util.tree_flatten_with_path(self.cache)[0]]

        self._prefill = jax.jit(
            lambda params, toks: DEC.prefill(params, cfg, {"tokens": toks},
                                             max_len=max_len))
        self._decode = jax.jit(
            lambda params, cache, toks: DEC.decode_step(params, cfg, cache, toks))

        def insert(cache, cache1, slot):
            flat, tdef = jax.tree_util.tree_flatten(cache)
            flat1 = jax.tree_util.tree_leaves(cache1)
            out = []
            for leaf, leaf1, ax in zip(flat, flat1, self._cache_axes):
                idx = [0] * leaf.ndim
                idx[ax] = slot
                out.append(jax.lax.dynamic_update_slice(leaf, leaf1.astype(
                    leaf.dtype), tuple(idx)))
            return jax.tree_util.tree_unflatten(tdef, out)

        self._insert = jax.jit(insert)

    # -- public ------------------------------------------------------------

    def submit(self, prompt: List[int], max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        if self.cfg.family in ("ssm", "hybrid") and len(prompt) != self.prefill_len:
            raise ValueError(
                f"recurrent family {self.cfg.family!r} needs exact-length "
                f"prompts ({self.prefill_len}); got {len(prompt)}")
        if len(prompt) > self.prefill_len:
            raise ValueError(f"prompt longer than prefill_len={self.prefill_len}")
        rid = next(self._ids)
        self.pending.append(Request(rid, list(prompt), max_new_tokens, eos_id))
        return rid

    def run_until_idle(self, max_ticks: int = 10_000) -> Dict[int, List[int]]:
        for _ in range(max_ticks):
            if not self.step():
                break
        return {rid: r.generated for rid, r in self.finished.items()}

    def step(self) -> bool:
        """One engine tick: admit into free slots, then decode.  Returns
        False when fully idle."""
        admitted = False
        for i, slot in enumerate(self.slots):
            if slot is None and self.pending:
                self._admit(i, self.pending.popleft())
                admitted = True
        active = [r for r in self.slots if r is not None]
        if not active:
            return admitted
        self._decode_tick()
        return True

    # -- internals ------------------------------------------------------------

    def _admit(self, slot: int, req: Request) -> None:
        plen = len(req.prompt)
        toks = np.zeros((1, self.prefill_len), np.int32)
        toks[0, :plen] = req.prompt
        logits1, cache1 = self._prefill(self.params, jnp.asarray(toks))
        if self.cfg.family in ("ssm", "hybrid"):
            # recurrent state is NOT idempotent: take the first token from
            # the prefill logits directly (prompts are exact-length here)
            first = int(np.asarray(jnp.argmax(logits1[:, -1, :], axis=-1))[0])
            req.generated.append(first)
            req._next_input = first  # type: ignore[attr-defined]
            self.stats["tokens"] += 1
            if (len(req.generated) >= req.max_new_tokens
                    or (req.eos_id is not None and first == req.eos_id)):
                req.done = True
                self.finished[req.id] = req
                self.stats["prefills"] += 1
                return
        else:
            # rewind one token: the first decode re-processes the last prompt
            # token (idempotent kv write), yielding the first new-token logits
            cache1["pos"] = jnp.full((1,), plen - 1, jnp.int32)
            req._next_input = req.prompt[-1]  # type: ignore[attr-defined]
        self.cache = self._insert(self.cache, cache1, slot)
        self.slots[slot] = req
        self.stats["prefills"] += 1

    def _decode_tick(self) -> None:
        toks = np.zeros((self.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None:
                toks[i, 0] = req._next_input  # type: ignore[attr-defined]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        self.stats["decode_ticks"] += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.generated.append(tok)
            req._next_input = tok  # type: ignore[attr-defined]
            self.stats["tokens"] += 1
            pos = int(np.asarray(self.cache["pos"])[i])
            if (len(req.generated) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or pos >= self.max_len - 1):
                req.done = True
                self.finished[req.id] = req
                self.slots[i] = None
