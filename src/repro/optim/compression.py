"""Int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce at 1000+ node scale).

Mechanism (1-bit-Adam-family, at 8 bits):
  * quantize grads to int8 with a power-of-two-free shared scale,
  * exchange at int8 width — reduce-scatter + all-gather built from
    all_to_all/all_gather so the WIRE format really is 1 byte/elem
    (a plain psum would widen to f32 on the wire),
  * keep the quantization residual in an error-feedback buffer that is
    added to the next step's gradient — unbiased over time, provably
    convergent for SGD-family optimizers.

Byte math per element per direction vs bf16 ring all-reduce:
  bf16 psum  ≈ 2 x 2B = 4B     int8 RS+AG ≈ 2 x 1B = 2B   (2x saving)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, scale: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grad: jax.Array, error: jax.Array,
                           scale: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(grad + carried error) -> (q, scale, new_error)."""
    corrected = grad.astype(jnp.float32) + error
    q, scale = quantize_int8(corrected, scale)
    new_error = corrected - dequantize_int8(q, scale)
    return q, scale, new_error


def compressed_mean(x: jax.Array, error: jax.Array, axis_name: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """Mean of ``x`` across ``axis_name`` with int8 on-wire format.

    Must be called INSIDE shard_map/pmap.  Implementation: shared scale
    (pmax), int8 reduce-scatter via all_to_all, local f32 accumulation,
    int8 all-gather of the reduced shard.  Returns (mean_f32, new_error).
    Leading dim must be divisible by the axis size (pad upstream).
    """
    n = jax.lax.psum(1, axis_name)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    err_flat = jnp.pad(error.reshape(-1), (0, pad))

    # shared scale so shards can sum in integer space coherently
    amax = jax.lax.pmax(jnp.max(jnp.abs(flat.astype(jnp.float32) + err_flat)),
                        axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    corrected = flat.astype(jnp.float32) + err_flat
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_error = corrected - q.astype(jnp.float32) * scale

    # reduce-scatter at int8: each peer receives its 1/n slice of every shard
    qs = q.reshape(n, flat.shape[0] // n)
    recv = jax.lax.all_to_all(qs, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)                      # (n, len/n)
    local_sum = jnp.sum(recv.astype(jnp.float32), axis=0) * scale / n

    # all-gather the reduced shard at int8 (re-quantized, second feedback-free
    # stage: quantization error here is averaged noise, not accumulated bias).
    # Each shard quantizes with its own scale; gather the scales alongside.
    q2, scale2 = quantize_int8(local_sum)
    gathered = jax.lax.all_gather(q2, axis_name, axis=0, tiled=False)   # (n, len/n)
    scales = jax.lax.all_gather(scale2, axis_name, axis=0, tiled=False)  # (n,)
    mean = (gathered.astype(jnp.float32) * scales[:, None]).reshape(-1)
    mean = mean[: x.size].reshape(x.shape)
    return mean, new_error[: x.size].reshape(x.shape)


def init_error_tree(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_mean_tree(grads: Any, errors: Any, axis_name: str
                         ) -> Tuple[Any, Any]:
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errors)
    out = [compressed_mean(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    means = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return means, new_err
