"""Version-portable Pallas-TPU compiler parameters.

API churn absorbed here:
  * class rename: ``pltpu.CompilerParams`` (new) vs
    ``pltpu.TPUCompilerParams`` (old);
  * field drift: unknown fields are filtered against the resolved
    class so a renamed/removed knob degrades to "unset" instead of a
    ``TypeError`` at kernel-build time;
  * absence: if neither class exists (ancient/exotic builds) the
    kernels simply run without Mosaic params.

Kernels splat the result into ``pl.pallas_call``::

    pl.pallas_call(kernel, ..., **mosaic_params(
        dimension_semantics=("parallel", "arbitrary")))
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Any, Dict, Optional


@functools.lru_cache(maxsize=None)
def _params_cls():
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:
        return None
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    return None


@functools.lru_cache(maxsize=None)
def _accepted_fields() -> frozenset:
    cls = _params_cls()
    if cls is None:
        return frozenset()
    if dataclasses.is_dataclass(cls):
        return frozenset(f.name for f in dataclasses.fields(cls))
    try:
        return frozenset(inspect.signature(cls).parameters)
    except (TypeError, ValueError):
        return frozenset()


def compiler_params_source() -> Optional[str]:
    cls = _params_cls()
    return None if cls is None else f"pltpu.{cls.__name__}"


def mosaic_params(**fields: Any) -> Dict[str, Any]:
    """Build the ``compiler_params=`` kwarg dict for ``pl.pallas_call``.

    Returns ``{"compiler_params": <params obj>}`` on JAX versions that
    support it, ``{}`` otherwise — callers ``**``-splat either way.
    Fields the resolved class doesn't know are dropped (best-effort
    tuning hints, not correctness knobs).
    """
    cls = _params_cls()
    if cls is None:
        return {}
    accepted = _accepted_fields()
    kept = {k: v for k, v in fields.items() if k in accepted}
    return {"compiler_params": cls(**kept)}
