"""ServiceProtocol — the reconcile loop behind a BridgeService CR.

A BridgeJob runs to DONE; a BridgeService keeps ``spec.replicas`` remote
jobs ALIVE.  The protocol subclasses ``JobProtocol`` so everything the batch
machinery already guarantees keeps holding here — submit-if-no-id resume
from the config map, the persisted condemned set, per-slice polling chains,
at-most-once cancel delivery, ``LoadProbe``-routed scale-up — and changes
exactly the lifecycle semantics:

  * a replica is a long-lived serve-mode job (the operator injects
    ``Serve: true`` into its jobproperties): it NEVER counts as terminal
    progress.  A replica observed terminal (crashed, completed, cancelled
    out-of-band) is replaced in place with a fresh remote submission;
  * every RUNNING replica is health-checked through the adapter's REST
    channel (``Capability.SERVE``) each tick.  ``failure_threshold``
    consecutive failed probes condemn it — the SAME persisted condemned set
    elastic scale-down uses — after which it is cancelled, drained, and
    resubmitted under the existing at-most-once invariants.  Before its
    first successful probe a replica gets the larger
    ``startup_failure_threshold`` budget (model servers load weights);
  * ``spec.replicas`` patches reuse the elastic reconcile verbatim:
    scale-down condemns the highest indices (drained then DROPPED, not
    replaced), scale-up routes the delta through ``LoadProbe`` to the
    least-loaded slice;
  * the only terminal state is a kill: ``spec.kill`` cancels every replica
    and the CR ends KILLED once all are down.

Each tick publishes ``ready_replicas`` and a per-replica ``endpoints`` list
into the config map (mirrored to ``status`` by the operator).  An endpoint's
``ready`` flag flips false in the SAME tick its replica is condemned — that
is the contract the request router (core/router.py) drains on — and because
endpoints live in the config map they survive operator/controller pod death
like every other piece of bridge state.

Cadence: services pin ``FixedCadence`` regardless of the operator's
configured mode.  Adaptive backoff and watch-skip both exist to AVOID
touching a quiescent endpoint, but the health probe is the workload here —
the probe period IS the detection SLA (recovery budget ≈ failure_threshold ×
updateinterval + resubmit latency), so ticks must not stretch.
"""
from __future__ import annotations

import json
import math
import time
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.backends import base as B
from repro.core.controller import (JobProtocol, PlacementSlice, TickObs,
                                   _CANON_TO_BRIDGE, _encode_pairs)
from repro.core.objectstore import NoSuchKey
from repro.core.resource import (DONE, FAILED, KILLED, RUNNING, SUBMITTED,
                                 UNKNOWN)
from repro.core.rest import TransportError
from repro.core.statestore import slice_key


class ServiceProtocol(JobProtocol):
    """One BridgeService's reconcile state machine (see module docstring)."""

    # hysteresis: a load ratio within ±10% of 1.0 proposes no change, so the
    # autoscaler does not flap around the target between two counts
    AUTOSCALE_TOLERANCE = 0.1

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._fail_threshold = 3
        self._startup_threshold = 10
        # consecutive failed health probes per live jid
        self._hfail: Dict[str, int] = {}
        # last probe answer per jid (readiness), and jids that have EVER
        # answered healthy (switches startup budget -> steady-state budget)
        self._hok: Dict[str, bool] = {}
        self._hever: Set[str] = set()
        # per-replica-index replacement counts, persisted in the cm
        self._replaced: Dict[str, int] = {}
        self._prev_ready: Dict[Optional[int], List[int]] = {}
        # load-driven autoscaling (spec.autoscale; OFF unless the operator
        # wrote the autoscale_* keys into the cm)
        self._as_enabled = False
        self._as_min = 1
        self._as_max = 1
        self._as_target_out: Optional[float] = None
        self._as_target_p99: Optional[float] = None
        self._as_up_cd = 5.0
        self._as_down_cd = 30.0
        # last scale times persist in the cm so cooldowns survive pod death
        self._as_last_up = 0.0
        self._as_last_down = 0.0

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> bool:
        cm_data = self.cm.data
        self._fail_threshold = max(
            int(cm_data.get("health_failure_threshold", "3") or 3), 1)
        self._startup_threshold = max(
            int(cm_data.get("health_startup_threshold", "10") or 10),
            self._fail_threshold)
        self._replaced = {
            k: int(v) for k, v in
            json.loads(cm_data.get("replica_restarts", "{}") or "{}").items()}
        self._as_enabled = "autoscale_min" in cm_data
        if self._as_enabled:
            self._as_min = max(int(cm_data.get("autoscale_min", "1") or 1), 1)
            self._as_max = max(int(cm_data.get("autoscale_max", "1") or 1),
                               self._as_min)
            tout = cm_data.get("autoscale_target_outstanding", "")
            self._as_target_out = float(tout) if tout else None
            tp99 = cm_data.get("autoscale_target_p99", "")
            self._as_target_p99 = float(tp99) if tp99 else None
            self._as_up_cd = float(
                cm_data.get("autoscale_up_cooldown", "5") or 5)
            self._as_down_cd = float(
                cm_data.get("autoscale_down_cooldown", "30") or 30)
            persisted = json.loads(
                cm_data.get("autoscale_status", "{}") or "{}")
            self._as_last_up = float(persisted.get("last_scale_up", 0.0))
            self._as_last_down = float(persisted.get("last_scale_down", 0.0))
        if not super().start():
            return False
        # the watch fast path skips status polls on quiescent endpoints;
        # a service's health probes must run EVERY tick regardless (and a
        # service never registers for watcher pokes either)
        self._watch_enabled = False
        self.wakeup_enabled = False
        return True

    def make_cadence(self):
        from repro.core.monitor import FixedCadence
        return FixedCadence(self.poll)

    # -- health probing ----------------------------------------------------

    def _probe_replica(self, sl: PlacementSlice, jid: str) -> bool:
        """One health probe over the adapter REST channel.  The slice's
        status poll just succeeded, so a transport failure here is scored as
        an unhealthy answer (the replica, not the manager, is the suspect)."""
        if not sl.adapter.supports(B.Capability.SERVE):
            # dialects without a health route: liveness (RUNNING) is all the
            # signal there is, treat the replica as healthy
            return True
        try:
            return sl.adapter.probe_health(jid)
        except (TransportError, B.SubmitError):
            return False

    # -- one monitor tick --------------------------------------------------

    def tick(self, slice_k: Optional[int] = None) -> bool:
        cm_now = self.cm.data
        kill_requested = cm_now.get("kill", "false") == "true"
        desired = max(int(cm_now.get("array_count", "1") or "1"), 1)

        stall_msg = None
        if not kill_requested:
            stall_msg = self._reconcile_scale(cm_now, desired)

        with self._mu:
            targets = (self._slices if slice_k is None
                       else [self._slices[slice_k]])
            snapshot = [(sl, [list(p) for p in sl.pairs]) for sl in targets]

        # status poll + health probes run OUTSIDE the state lock
        polled: List[Tuple[PlacementSlice, list, list, Dict[str, bool]]] = []
        failed: List[Tuple[PlacementSlice, Exception]] = []
        for sl, pairs in snapshot:
            if not pairs:
                polled.append((sl, pairs, [], {}))
                continue
            try:
                infos = self._poll_statuses(sl.adapter,
                                            [jid for _, jid in pairs])
            except (TransportError, B.SubmitError) as e:
                failed.append((sl, e))
                continue
            health: Dict[str, bool] = {}
            if not kill_requested:
                for (idx, jid), info in zip(pairs, infos):
                    if (info.get("state") == B.RUNNING
                            and jid not in self._cancel_sent):
                        health[jid] = self._probe_replica(sl, jid)
            polled.append((sl, pairs, infos, health))

        with self._mu:
            imap = self._index_map()
            for sl, pairs, infos, health in polled:
                sl.failures = 0
                sl.last_error = ""
                for (idx, jid), info in zip(pairs, infos):
                    cur = imap.get(idx)
                    if cur is not None and cur[1] == jid:
                        self._infos[idx] = info
                for jid, ok in health.items():
                    self._hok[jid] = ok
                    if ok:
                        self._hever.add(jid)
                        self._hfail[jid] = 0
                    else:
                        self._hfail[jid] = self._hfail.get(jid, 0) + 1
            for sl, e in failed:
                sl.failures += 1
                sl.last_error = str(e)
            if not polled:
                for sl, e in failed:
                    if sl.failures >= self._unknown_after:
                        where = f"slice {sl.k} " if self._sliced else ""
                        self._push(
                            {"jobStatus": UNKNOWN,
                             "message": f"{where}resource unreachable: {e}"})
                self._obs[slice_k] = TickObs(unknown=True, busy=True)
                return False
            return self._evaluate_service(
                cm_now, desired, kill_requested, stall_msg,
                {sl.k for sl, _, _, _ in polled}, chain=slice_k,
                had_failures=bool(failed))

    # -- post-poll evaluation (holds self._mu) -----------------------------

    def _condemn(self, jid: str) -> None:
        self._condemned.add(jid)
        self._push({"condemned": ",".join(sorted(self._condemned))})

    def _forget_jid(self, jid: str) -> None:
        self._condemned.discard(jid)
        self._cancel_sent.discard(jid)
        self._hfail.pop(jid, None)
        self._hok.pop(jid, None)
        self._hever.discard(jid)

    def _drop_replica(self, sl: PlacementSlice, idx: int, jid: str) -> None:
        """Scale-down GC: the drained replica's index position disappears."""
        sl.pairs = [p for p in sl.pairs if p[0] != idx]
        self._forget_jid(jid)
        self._infos.pop(idx, None)
        self._replaced.pop(str(idx), None)
        updates: Dict[str, Any] = {"id": ",".join(self._global_ids())}
        if self._condemned:
            updates["condemned"] = ",".join(sorted(self._condemned))
        else:
            self.cm.prune(["condemned"])
            self._last_pushed.pop("condemned", None)
        if self._sliced:
            updates[slice_key(sl.k, "id")] = _encode_pairs(sl.pairs)
        updates["replica_restarts"] = json.dumps(self._replaced)
        self._push(updates)

    def _respawn_replica(self, sl: PlacementSlice, idx: int, old_jid: str,
                         cm_now: Dict[str, str], desired: int) -> bool:
        """Replace a dead replica in place: fresh remote submission under the
        SAME global index on the SAME slice.  Only ever called once the old
        remote job is terminal — the at-most-once-while-live invariant is
        what the condemn/cancel/drain sequence upstream guarantees.
        Transient submit failure leaves the dead pair for the next tick."""
        try:
            script = self._fetch_script(cm_now)
            properties = json.loads(cm_now.get("jobproperties", "{}"))
            params = self._index_params(cm_now, idx, desired)
            new_id = (sl.adapter.resubmit_index(script, properties, params,
                                                idx)
                      if desired > 1
                      else sl.adapter.submit(script, properties, params))
        except (B.SubmitError, TransportError, NoSuchKey, KeyError,
                ValueError):
            return False
        for p in sl.pairs:
            if p[0] == idx:
                p[1] = new_id
                break
        self._forget_jid(old_jid)
        self._infos.pop(idx, None)
        self._replaced[str(idx)] = self._replaced.get(str(idx), 0) + 1
        updates: Dict[str, Any] = {"id": ",".join(self._global_ids()),
                                   "replica_restarts":
                                   json.dumps(self._replaced)}
        if not self._condemned:
            self.cm.prune(["condemned"])
            self._last_pushed.pop("condemned", None)
        else:
            updates["condemned"] = ",".join(sorted(self._condemned))
        if self._sliced:
            updates[slice_key(sl.k, "id")] = _encode_pairs(sl.pairs)
        self._push(updates)
        return True

    # -- load-driven autoscaling (spec.autoscale) --------------------------

    def _autoscale_signals(self, cm_now: Dict[str, str],
                           now: float) -> Tuple[int, Optional[float], int]:
        """Merge every router's ``loadreport_*`` cm entry into the decision
        inputs: total outstanding requests across LIVE replicas, the worst
        per-replica p99, and the fresh-report count.  Reports older than the
        TTL they carry are dropped AND pruned from the cm — a router that
        went away must neither freeze the load signal nor leak its key."""
        fresh: List[Dict[str, Any]] = []
        expired: List[str] = []
        for key, raw in cm_now.items():
            if not key.startswith("loadreport_"):
                continue
            try:
                rep = json.loads(raw)
                stale = now - float(rep.get("ts", 0.0)) > float(
                    rep.get("ttl", 1.0))
            except (ValueError, TypeError):
                stale = True
            if stale:
                expired.append(key)
            else:
                fresh.append(rep)
        if expired:
            self.cm.prune(expired)
        live = {jid for _, jid in self._index_map().values()}
        outstanding = 0
        p99: Optional[float] = None
        for rep in fresh:
            for jid, r in (rep.get("replicas") or {}).items():
                if jid not in live:
                    continue  # a replaced incarnation's counters are noise
                outstanding += int(r.get("outstanding", 0) or 0)
                v = r.get("p99_s")
                if v is not None:
                    p99 = float(v) if p99 is None else max(p99, float(v))
        return outstanding, p99, len(fresh)

    def _autoscale_desired(self, desired: int, outstanding: int,
                           p99: Optional[float], reports: int) -> int:
        """HPA-style proportional scaling: each target proposes
        ``ceil(current × observed/target)`` (held inside the ±tolerance
        band), the most demanding proposal wins, clamped to [min, max].
        Zero fresh reports means no client is talking — the idle floor."""
        if not reports:
            return self._as_min
        ratios: List[float] = []
        if self._as_target_out is not None:
            ratios.append(outstanding / (desired * self._as_target_out))
        if self._as_target_p99 is not None and p99 is not None:
            ratios.append(p99 / self._as_target_p99)
        cands = [desired if abs(r - 1.0) <= self.AUTOSCALE_TOLERANCE
                 else math.ceil(desired * r) for r in ratios]
        want = max(cands) if cands else desired
        return max(self._as_min, min(self._as_max, want))

    def _autoscale_tick(self, cm_now: Dict[str, str], desired: int,
                        imap: Dict[int, Any], states: Dict[int, str],
                        unreachable: list) -> None:
        """One autoscale decision (chain 0 only, never during a kill).
        Holding still while a drain, failover, or unfinished reconcile is in
        flight keeps exactly one scaling intent live at a time; cooldowns
        rate-limit each direction on top.  The chosen count rides the SAME
        ``array_count`` key a manual ``scale()`` patch uses, so next tick's
        elastic reconcile applies it verbatim."""
        now = time.time()
        outstanding, p99, reports = self._autoscale_signals(cm_now, now)
        want = self._autoscale_desired(desired, outstanding, p99, reports)
        blocked = (bool(self._condemned) or bool(unreachable)
                   or self._failover_lock.locked()
                   or len(imap) != desired
                   or any(states.get(i) in (DONE, FAILED, KILLED)
                          for i in imap))
        applied = desired
        if want != desired and not blocked:
            if (want > desired
                    and now - self._as_last_up >= self._as_up_cd):
                self._as_last_up = now
                applied = want
            elif (want < desired
                    and now - max(self._as_last_up, self._as_last_down)
                    >= self._as_down_cd):
                self._as_last_down = now
                applied = want
        if applied != desired:
            # cm.update directly (not _push): the operator also writes this
            # key on generation bumps, so _last_pushed must follow, never
            # gate, what the autoscaler decides
            self.cm.update({"array_count": str(applied)})
            self._last_pushed["array_count"] = str(applied)
        self._push({"autoscale_status": json.dumps({
            "desired": applied,
            "min": self._as_min, "max": self._as_max,
            "signals": {"outstanding": outstanding,
                        "p99_s": None if p99 is None else round(p99, 4),
                        "reports": reports},
            "last_scale_up": round(self._as_last_up, 3),
            "last_scale_down": round(self._as_last_down, 3),
        })})

    def _evaluate_service(self, cm_now: Dict[str, str], desired: int,
                          kill_requested: bool, stall_msg: Optional[str],
                          ticked: Set[int], chain: Optional[int] = None,
                          had_failures: bool = False) -> bool:
        imap = self._index_map()
        states = {
            i: (_CANON_TO_BRIDGE[self._infos[i]["state"]]
                if i in self._infos else SUBMITTED)
            for i in imap}

        if not kill_requested:
            # 1. condemn replicas whose consecutive failed probes exhausted
            #    their budget (startup budget until the first healthy answer)
            for i in sorted(imap):
                sl, jid = imap[i]
                if jid in self._condemned or states[i] != RUNNING:
                    continue
                budget = (self._fail_threshold if jid in self._hever
                          else self._startup_threshold)
                if self._hfail.get(jid, 0) >= budget:
                    self._condemn(jid)

            # 2. deliver cancels for the condemned (health OR scale-down),
            #    on the slices this tick polled
            for sl in self._slices:
                if sl.k not in ticked or not sl.adapter.supports(
                        B.Capability.CANCEL):
                    continue
                cq = sl.adapter.supports(B.Capability.CANCEL_QUEUED)
                for idx, jid in sorted(sl.pairs, reverse=True):
                    if jid in self._condemned:
                        self._try_cancel(sl.adapter, jid,
                                         states.get(idx, SUBMITTED), cq)

            # 3. act on every TERMINAL replica: an index position beyond the
            #    desired count was condemned by scale-down and is dropped;
            #    anything else — condemned-and-drained or died on its own —
            #    is respawned in place (services replace forever; there is
            #    no retry budget to exhaust because staying up is the spec)
            for i in sorted(imap, reverse=True):
                sl, jid = imap[i]
                if states[i] not in (DONE, FAILED, KILLED):
                    continue
                if sl.k not in ticked:
                    continue  # that slice's chain owns the action
                if i >= desired:
                    self._drop_replica(sl, i, jid)
                    states.pop(i, None)
                elif self._respawn_replica(sl, i, jid, cm_now, desired):
                    states[i] = SUBMITTED
            imap = self._index_map()

        indices = sorted(imap)
        ready = [i for i in indices
                 if imap[i][1] not in self._condemned
                 and imap[i][1] not in self._cancel_sent
                 and states.get(i) == RUNNING
                 and self._hok.get(imap[i][1], False)]

        # 4. endpoints: one entry per tracked replica; ``ready`` flips false
        #    the same tick the replica is condemned (the router's drain cue)
        ready_set = set(ready)
        endpoints = []
        for i in indices:
            sl, jid = imap[i]
            endpoints.append({
                "replica": i, "slice": sl.k, "resourceURL": sl.url,
                "image": sl.image, "resourcesecret": sl.secret,
                "job_id": jid, "ready": i in ready_set,
            })

        unreachable = [sl for sl in self._slices
                       if sl.failures >= self._unknown_after]

        if self._as_enabled and not kill_requested and chain in (None, 0):
            self._autoscale_tick(cm_now, desired, imap, states, unreachable)

        finished = kill_requested and all(
            states.get(i) in (DONE, FAILED, KILLED) for i in indices)
        message = stall_msg or f"{len(ready)}/{desired} replicas ready"
        if finished:
            agg = KILLED
        elif kill_requested:
            # draining: cancels are out (or going out below) but replicas
            # are still alive remotely — that is in-progress teardown, not
            # a service waiting to come up
            draining = sum(1 for i in indices
                           if states.get(i) not in (DONE, FAILED, KILLED))
            agg = RUNNING
            message = f"kill requested, draining {draining} replicas"
        else:
            agg = RUNNING if ready else SUBMITTED
        if unreachable and not finished:
            agg = UNKNOWN
            message = "; ".join(
                (f"slice {sl.k} " if self._sliced else "")
                + f"resource unreachable: {sl.last_error}"
                for sl in unreachable)

        updates: Dict[str, Any] = {
            "jobStatus": agg, "message": message,
            "ready_replicas": str(len(ready)),
            "endpoints": json.dumps(endpoints),
            "index_states": json.dumps({str(i): states.get(i, SUBMITTED)
                                        for i in indices}),
        }
        if self._sliced:
            updates["placements"] = json.dumps(
                self._placements_snapshot(states))
        starts = [self._infos[i].get("start_time") for i in indices
                  if self._infos.get(i, {}).get("start_time")]
        if starts:
            updates["start_time"] = str(min(starts))
        if finished:
            ends = [self._infos[i].get("end_time") for i in indices
                    if self._infos.get(i, {}).get("end_time")]
            updates["end_time"] = str(max(ends) if ends else time.time())
        if (cm_now.get("generation") and not self._condemned
                and not kill_requested and len(indices) == desired):
            updates["observed_generation"] = cm_now["generation"]
        self._push(updates)

        self._obs[chain] = TickObs(
            changed=(states != self._prev_states.get(chain)
                     or ready != self._prev_ready.get(chain)),
            # a service at full readiness is still "busy": the health probe
            # is the workload, so the cadence must never back off (enforced
            # twice — make_cadence pins FixedCadence anyway)
            busy=True,
            unknown=had_failures or bool(unreachable))
        self._prev_states[chain] = dict(states)
        self._prev_ready[chain] = list(ready)

        if kill_requested:
            for sl in self._slices:
                if sl.k not in ticked or not sl.adapter.supports(
                        B.Capability.CANCEL):
                    continue
                cq = sl.adapter.supports(B.Capability.CANCEL_QUEUED)
                for idx, jid in list(sl.pairs):
                    self._try_cancel(sl.adapter, jid,
                                     states.get(idx, SUBMITTED), cq)

        if finished:
            self._exit(1)  # a killed service is KILLED, never DONE
            return True
        return False
