"""Roofline-term extraction from compiled dry-run artifacts.

Sources (per the brief):
  * ``compiled.cost_analysis()``   -> HLO flops / bytes (PER-DEVICE program:
    XLA SPMD emits one partitioned module, so these are per-chip numbers).
  * ``compiled.as_text()``         -> collective ops; cost_analysis does not
    cover them, so we parse result shapes + replica groups per instruction.

Two collective-byte conventions are recorded:
  * ``operand`` — the brief's "sum operand sizes of every collective".
  * ``wire``    — ring-algorithm bytes actually serialized per device
                  (all-reduce 2(g-1)/g, all-gather/reduce-scatter (g-1)/g,
                  all-to-all (g-1)/g, collective-permute 1x).

Hardware model: TPU v5e (see repro.launch.mesh.HW).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.launch.mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of one 'f32[a,b]{...}' or '(f32[..], bf16[..])' string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    operand_bytes: Dict[str, int] = field(default_factory=dict)
    wire_bytes: Dict[str, int] = field(default_factory=dict)

    @property
    def total_operand(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_wire(self) -> int:
        return sum(self.wire_bytes.values())

    def to_dict(self) -> Dict[str, Any]:
        return {"counts": self.counts, "operand_bytes": self.operand_bytes,
                "wire_bytes": self.wire_bytes,
                "total_operand": self.total_operand,
                "total_wire": self.total_wire}


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Per-device collective bytes from a partitioned HLO module."""
    st = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line)
        if not m:
            continue
        if "-done(" in line:  # async pair: count the -start only
            continue
        shape_str, op = m.group(1), m.group(2)
        res = _shape_bytes(shape_str)
        g = _group_size(line)
        if op == "all-reduce":
            operand = res
            wire = int(2 * (g - 1) / g * res)
        elif op == "all-gather":
            operand = res // max(g, 1)
            wire = int((g - 1) / g * res)
        elif op == "reduce-scatter":
            operand = res * g
            wire = (g - 1) * res
        elif op == "all-to-all":
            operand = res
            wire = int((g - 1) / g * res)
        else:  # collective-permute
            operand = res
            wire = res
        st.counts[op] = st.counts.get(op, 0) + 1
        st.operand_bytes[op] = st.operand_bytes.get(op, 0) + operand
        st.wire_bytes[op] = st.wire_bytes.get(op, 0) + wire
    return st


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll: CollectiveStats) -> Dict[str, float]:
    """The three roofline terms in SECONDS (per step, per chip)."""
    t_compute = flops_per_dev / HW["peak_flops_bf16"]
    t_memory = bytes_per_dev / HW["hbm_bw"]
    t_coll_operand = coll.total_operand / HW["ici_bw"]
    t_coll_wire = coll.total_wire / HW["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll_operand, "collective_wire_s": t_coll_wire}
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["dominant"] = dom
    # roofline fraction: useful-compute share of the binding resource
    bound = max(t_compute, t_memory, t_coll_operand)
    terms["roofline_fraction"] = t_compute / bound if bound > 0 else 0.0
    return terms


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train, 2·N·D inference (N = active
    params, D = tokens processed globally this step)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "vlm":
            tokens += shape.global_batch * cfg.n_img_tokens \
                - shape.global_batch * cfg.n_img_tokens  # text-only targets
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def memory_stats_dict(mem) -> Dict[str, int]:
    if mem is None:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        if hasattr(mem, k):
            out[k] = int(getattr(mem, k))
    if out:
        out["peak_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0))
    return out
