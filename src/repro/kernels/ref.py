"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q: (B,Hq,Sq,D); k,v: (B,Hkv,Sk,D); GQA by head repetition.
    Returns (B,Hq,Sq,D).  f32 softmax, output in q.dtype."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / jnp.sqrt(d)
    if causal:
        iq = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 0)
        ik = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
        s = jnp.where(ik <= iq, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)
                      ).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """q: (B,Hq,D); k,v: (B,Hkv,M,D); lengths: (B,) valid slots.
    Returns (B,Hq,D)."""
    b, hq, d = q.shape
    hkv, m = k.shape[1], k.shape[2]
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhmd->bhm", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / jnp.sqrt(d)
    mask = jax.lax.broadcasted_iota(jnp.int32, (b, 1, m), 2) < lengths[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhm,bhmd->bhd", p, vr.astype(jnp.float32)).astype(q.dtype)


def ssm_discretize(delta: jax.Array, B: jax.Array, x: jax.Array,
                   A: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """ZOH discretization: dA_t = exp(delta_t*A); dBx_t = delta_t*B_t*x_t.

    delta, x: (B,S,di); B: (B,S,N); A: (di,N) -> dA, dBx (B,S,di,N).
    The single definition of the math that _ssm_fused_kernel computes
    per-timestep in VMEM — keep the two in lockstep."""
    dA = jnp.exp(delta[..., None] * A)
    dBx = delta[..., None] * B[:, :, None, :] * x[..., None]
    return dA, dBx


def ssm_scan_ref(dA: jax.Array, dBx: jax.Array, C: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = dA_t * h_{t-1} + dBx_t;  y_t = <h_t, C_t>.

    dA, dBx: (B,S,di,N) f32;  C: (B,S,N) f32.
    Returns (y (B,S,di) f32, h_last (B,di,N) f32)."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, C)
    return y, h[:, -1]
